"""Tests of the paper's algorithms against the message-schedule oracle.

These verify the *claims of the paper* (Theorem 1 and the costs of the
two baselines) on a faithful rank-by-rank simulation, for every p up to
260 and a sample of larger p, under the free monoid (the most
discriminating associative operator — catches reordering, duplication
and omission, and does not assume commutativity).
"""

import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal container: property tests skip
    from helpers import fake_hypothesis

    given, settings, st = fake_hypothesis()

from repro.core import oracle

ALL_P = list(range(1, 261)) + [511, 512, 513, 1023, 1024, 1025, 4096, 4097]


@pytest.mark.parametrize("p", ALL_P)
def test_123_correct_and_theorem1(p):
    stats = oracle.verify(p, "123")
    # Theorem 1: q = ceil(log2(p-1) + log2(4/3)) rounds ...
    assert stats.rounds == oracle.q_123(p)
    # ... and q-1 applications of ⊕ on the result path (last rank).
    assert stats.result_path_ops == max(0, stats.rounds - 1)
    # No rank applies ⊕ more than q times (mid ranks add one send-side
    # prep in round 1 — see EXPERIMENTS.md §Fidelity).
    assert stats.max_ops <= stats.rounds


@pytest.mark.parametrize("p", ALL_P)
def test_1doubling_correct_and_costs(p):
    stats = oracle.verify(p, "1doubling")
    assert stats.rounds == oracle.rounds_1doubling(p)
    if p > 2:
        expected_ops = math.ceil(math.log2(p - 1))
        assert stats.result_path_ops == expected_ops
        assert stats.max_ops == expected_ops
        # pays exactly one more round than 123-doubling for most p
        assert stats.rounds >= oracle.q_123(p)


@pytest.mark.parametrize("p", ALL_P)
def test_two_op_correct_and_costs(p):
    stats = oracle.verify(p, "two_op")
    assert stats.rounds == oracle.rounds_two_op(p)
    if p > 2:
        # max over ranks of total ⊕ is 2*ceil(log2 p) - 2 (send-prep +
        # combine per round after round 0); the paper quotes
        # 2*ceil(log2 p) - 1 as the upper bound.
        assert stats.max_ops <= 2 * math.ceil(math.log2(p)) - 1


@pytest.mark.parametrize("p", ALL_P)
def test_123_round_advantage(p):
    """The new algorithm never loses to 1-doubling, and saves a round
    whenever frac(log2(p-1)) > log2(3/2) — e.g. p=36: 6 vs 7 rounds."""
    if p <= 2:
        return
    q = oracle.q_123(p)
    assert q <= oracle.rounds_1doubling(p)
    assert q >= oracle.rounds_two_op(p)  # never beats log2 p lower bound - 1
    assert q >= math.ceil(math.log2(p - 1))  # the paper's lower bound


def test_paper_table_counts_p36():
    """The paper's own cluster: p=36 nodes."""
    assert oracle.q_123(36) == 6
    assert oracle.rounds_1doubling(36) == 7
    assert oracle.rounds_two_op(36) == 6
    st_123 = oracle.verify(36, "123")
    st_two = oracle.verify(36, "two_op")
    assert st_123.result_path_ops == 5  # q-1
    assert st_two.max_ops == 8  # ~2 log p: more ⊕ for the same rounds


def test_message_counts_monotone():
    """123-doubling sends no more messages than 1-doubling."""
    for p in range(2, 200):
        m123 = oracle.verify(p, "123").messages
        m1 = oracle.verify(p, "1doubling").messages
        assert m123 <= m1 + p  # at most the extra round-1 sends


# --------------------------- property-based ---------------------------


@settings(max_examples=60, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    algorithm=st.sampled_from(["123", "1doubling", "two_op"]),
)
def test_property_random_matrix_monoid(p, seed, algorithm):
    """Non-commutative 2x2 integer-matrix monoid with random inputs:
    result must equal the sequential left fold exactly."""
    rng = np.random.default_rng(seed)
    inputs = [rng.integers(-3, 4, size=(2, 2)).astype(object) for _ in range(p)]
    op = lambda lo, hi: hi @ lo  # lo applied first
    identity = np.eye(2, dtype=object)
    got, _ = oracle.SIMULATORS[algorithm](inputs, op, identity)
    acc = identity
    for r in range(p):
        assert np.array_equal(got[r], acc), (algorithm, p, r)
        acc = inputs[r] @ acc


@settings(max_examples=60, deadline=None)
@given(p=st.integers(min_value=2, max_value=100_000))
def test_property_round_count_formula(p):
    """Coverage argument: the window width reached by the 123 skip
    schedule covers p-1 inputs after its last round and not before (the
    schedule is tight), and its length equals Theorem 1's q."""
    skips = oracle.skips_123(p)
    # window width after round k: 1, 3, then doubling (3·2^(k-1))
    widths = []
    for i in range(len(skips)):
        widths.append(1 if i == 0 else (3 if i == 1 else 2 * skips[i]))
    assert widths[-1] >= p - 1  # rank p-1 complete after the last round
    if len(widths) >= 2:
        assert widths[-2] < p - 1  # ... and not a round earlier
    assert len(skips) == oracle.q_123(p)
