"""Launch-layer tests: shape grid, applicability, input specs, and the
end-to-end train/serve drivers on CPU."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh


def test_shape_grid_is_complete():
    assert set(steps_lib.SHAPES) == {
        "train_4k", "prefill_32k", "decode_32k", "long_500k"}
    s = steps_lib.SHAPES["train_4k"]
    assert (s.seq, s.batch) == (4096, 256)
    s = steps_lib.SHAPES["long_500k"]
    assert (s.seq, s.batch) == (524288, 1)


def test_applicability_matrix():
    skips = []
    for a in configs.ARCHITECTURES:
        cfg = configs.get(a)
        for sname, s in steps_lib.SHAPES.items():
            ok, reason = steps_lib.applicable(cfg, s)
            if not ok:
                skips.append((a, sname))
    # exactly: 8 non-subquadratic archs skip long_500k; hubert also
    # skips decode_32k (encoder-only)
    assert ("jamba_1_5_large_398b", "long_500k") not in [
        (a, s) for a, s in skips]
    assert ("rwkv6_1_6b", "long_500k") not in [(a, s) for a, s in skips]
    assert ("hubert_xlarge", "decode_32k") in skips
    assert ("llama3_8b", "long_500k") in skips
    assert len(skips) == 9  # 8 long_500k + 1 decode_32k


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_moe_a2_7b",
                                  "jamba_1_5_large_398b", "hubert_xlarge",
                                  "pixtral_12b"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_shapes(arch, shape):
    """Abstract specs build without touching devices, for a tiny mesh."""
    cfg = configs.get(arch)
    s = steps_lib.SHAPES[shape]
    ok, _ = steps_lib.applicable(cfg, s)
    if not ok:
        pytest.skip("cell skipped by design")
    mesh = make_host_mesh(1, 1)
    args, shardings, donate = steps_lib.input_specs(cfg, s, mesh)
    flat_args = jax.tree.leaves(args)
    flat_sh = jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_args) == len(flat_sh)
    for a in flat_args:
        assert hasattr(a, "shape") and hasattr(a, "dtype")
    if shape == "train_4k":
        batch = args[2]
        if cfg.frontend == "audio":
            assert batch["embeds"].shape == (256, 4096, cfg.d_model)
        elif cfg.frontend == "vision":
            assert batch["tokens"].shape == (256, 4096 - cfg.n_prefix)
        else:
            assert batch["tokens"].shape == (256, 4096)


def test_kv_dup():
    mesh = make_host_mesh(1, 1)
    assert steps_lib.kv_dup(configs.get("llama3_8b"), mesh) == 1

    class FakeMesh:
        shape = {"model": 16}

    # llama3: kv=8, H=32 -> dup 2 gives 16 kv heads (shards, divides H)
    assert steps_lib.kv_dup(configs.get("llama3_8b"), FakeMesh()) == 2
    assert steps_lib.kv_shardable(configs.get("llama3_8b"), FakeMesh())
    assert steps_lib.kv_dup(configs.get("qwen2_moe_a2_7b"), FakeMesh()) == 1
    # starcoder: kv=2, H=24 — no dup makes kv*dup % 16 == 0 AND divide 24
    # -> dup 1 + sequence-over-model cache fallback
    assert steps_lib.kv_dup(configs.get("starcoder2_3b"), FakeMesh()) == 1
    assert not steps_lib.kv_shardable(configs.get("starcoder2_3b"),
                                      FakeMesh())
    assert not steps_lib.kv_shardable(configs.get("granite_moe_3b_a800m"),
                                      FakeMesh())


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import train

    losses = train([
        "--arch", "granite_3_2b", "--smoke", "--steps", "12",
        "--batch", "2", "--seq", "64", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "6", "--log-every", "6"])
    assert len(losses) == 12
    assert all(np.isfinite(l) for l in losses)
    # resume picks up from the final checkpoint
    losses2 = train([
        "--arch", "granite_3_2b", "--smoke", "--steps", "14",
        "--batch", "2", "--seq", "64", "--ckpt-dir", str(tmp_path)])
    assert len(losses2) == 2  # steps 12..13 only


def test_serve_driver_end_to_end():
    from repro.launch.serve import serve

    out = serve(["--arch", "granite_3_2b", "--smoke", "--batch", "2",
                 "--prompt-len", "8", "--gen", "4"])
    assert out.shape == (2, 4)
    assert (out >= 0).all()
