"""Compiled-artifact fidelity: the paper's round counts survive XLA.

Lower+compile each exscan algorithm on an 8-device mesh and count the
``collective-permute`` ops in the optimized HLO — they must equal the
theoretical round counts (Theorem 1 etc.).  This is the same parse the
roofline harness uses, so it also locks the §Roofline collective
accounting against regressions.
"""

import pytest

from helpers import run_with_devices

_CODE = """
import jax, numpy as np, re
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex
from repro.launch import roofline as rl

p = 8
mesh = Mesh(np.array(jax.devices()).reshape(p), ("x",))
x = np.arange(p * 4, dtype=np.int32).reshape(p, 4)

for alg in ("123", "1doubling", "two_op", "ring"):
    f = jax.jit(shard_map(lambda v: ex.exscan(v, "x", "add", alg),
                          mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    compiled = f.lower(x).compile()
    stats = rl.parse_collectives(compiled.as_text())
    got = stats.op_counts.get("collective-permute", 0)
    want = ex.expected_rounds(alg, p)
    assert got == want, (alg, got, want)
    print("OK", alg, got)

# native = one all-gather, zero permutes
f = jax.jit(shard_map(lambda v: ex.exscan(v, "x", "add", "native"),
                      mesh=mesh, in_specs=P("x"), out_specs=P("x")))
stats = rl.parse_collectives(f.lower(x).compile().as_text())
assert stats.op_counts.get("collective-permute", 0) == 0
assert stats.op_counts.get("all-gather", 0) >= 1
print("OK native")
"""


def test_hlo_round_counts_match_theory():
    out = run_with_devices(_CODE, 8, x64=False)
    assert out.count("OK") == 5
