"""Multi-process hierarchical runtime tests (ISSUE-7).

Three layers, cheapest first:

1. :class:`RankExecutor` over :class:`LocalTransport` (threads, one
   process): every registered exclusive algorithm, non-commutative and
   non-segmentable monoids, the pipelined segmented ring, composed
   hierarchical schedules and the multi-output fused scan_total all
   reproduce the :class:`SimulatorExecutor` bit-for-bit with matching
   stats — the message-passing executor IS the simulator's semantics.
2. :func:`plan_hierarchical` (no subprocesses): per-tier algorithm
   divergence under the default dci/ici pricing, axis-tagged explain
   rows, ``factor_ranks`` validation.
3. A real :class:`WorkerPool` (module-scoped — workers cost ~2s of
   jax import each, so every test reuses one 2-proc x 2-rank pool):
   bit-identity across OS processes, stats drift vs the plan,
   cross-process traffic accounting, hop timing, and the "dci"
   calibration path fitting from pool timings.
"""

import subprocess
import sys

import numpy as np
import pytest

from helpers import SRC

from repro.core import monoid as monoid_lib
from repro.core import scan_api, schedule as schedule_lib, tune
from repro.core.scan_api import ScanSpec, plan, plan_hierarchical
from repro.core.schedule import SimulatorExecutor, collect_stats
from repro.dist import (LocalTransport, RankExecutor,
                        run_ranks_threaded)
from repro.dist.launcher import WorkerPool, run_plan

# ---------------------------------------------------------------------------
# Layer 1: RankExecutor over LocalTransport == SimulatorExecutor
# ---------------------------------------------------------------------------


def _witness(m_name: str, p: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if m_name == "affine":
        return (rng.standard_normal((p, n)),
                rng.standard_normal((p, n)))
    if m_name == "matmul":
        return rng.standard_normal((p, 3, 3))
    return rng.integers(0, 1 << 30, size=(p, n)).astype(np.int64)


def _assert_dist_matches_sim(sched, x, m, *, commutative=None):
    """Threaded message-passing run == simulator run, bit for bit,
    with identical rank-0 stats aggregates."""
    import jax

    p = sched.p
    xs = [jax.tree.map(lambda a: np.asarray(a)[r], x)
          for r in range(p)]
    dist_st = schedule_lib.CollectiveStats()
    with LocalTransport(p) as tr:
        outs = run_ranks_threaded(tr, sched, xs, m, stats_rank=0,
                                  stats=dist_st)
    with collect_stats() as sim_st:
        want = SimulatorExecutor().execute(sched, x, m)
    n_out = len(sched.outputs)
    if n_out > 1:
        got = tuple(
            jax.tree.map(lambda *vs: np.stack(vs, 0),
                         *[o[j] for o in outs])
            for j in range(n_out))
    else:
        got = jax.tree.map(lambda *vs: np.stack(vs, 0), *outs)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert np.array_equal(g, w), (sched.algorithm, p)
    assert dist_st.rounds == sim_st.rounds
    assert dist_st.op_applications == sim_st.op_applications
    assert dist_st.allgathers == sim_st.allgathers
    assert sum(dist_st.bytes_per_round) == sum(sim_st.bytes_per_round)
    return got


@pytest.mark.parametrize("p", [2, 3, 5, 8])
def test_rank_executor_every_exclusive_algorithm(p):
    for alg in scan_api.algorithms("exclusive"):
        pl = plan(ScanSpec(kind="exclusive", algorithm=alg), p,
                  nbytes=64)
        x = _witness("add", p, 8, seed=p)
        _assert_dist_matches_sim(pl.schedule(), x, monoid_lib.ADD)


@pytest.mark.parametrize("p", [2, 3, 5, 8])
def test_rank_executor_allreduce_and_scan_total(p):
    x = _witness("add", p, 8, seed=p)
    for kind in ("allreduce", "scan_total"):
        pl = plan(ScanSpec(kind=kind, monoid="add"), p, nbytes=64)
        _assert_dist_matches_sim(pl.schedule(), x, monoid_lib.ADD)


def test_rank_executor_segmented_ring_noncommutative():
    # affine is non-commutative: combine ORDER must match the
    # simulator in every seg_shift round, not just the final value
    for p, S in ((4, 4), (5, 8)):
        pl = plan(ScanSpec(kind="exclusive", algorithm="ring",
                           segments=S, monoid="affine"), p,
                  nbytes=S * 16)
        x = _witness("affine", p, S * 2, seed=p)
        _assert_dist_matches_sim(pl.schedule(), x,
                                 monoid_lib.get("affine"))


def test_rank_executor_noncommutative_and_matmul():
    pl = plan(ScanSpec(kind="exclusive", algorithm="123",
                       monoid="affine"), 6, nbytes=64)
    _assert_dist_matches_sim(pl.schedule(), _witness("affine", 6, 4),
                             monoid_lib.get("affine"))
    pl = plan(ScanSpec(kind="exclusive", algorithm="two_op",
                       monoid="matmul"), 5, nbytes=72)
    _assert_dist_matches_sim(pl.schedule(), _witness("matmul", 5, 0),
                             monoid_lib.get("matmul"))


@pytest.mark.parametrize("p", [2, 3, 4, 6, 7, 12, 16, 17])
def test_rank_executor_block_builders_battery(p):
    """Block-distributed mid-m builders (Träff 2026 halving/quartering
    + reduce-scatter exscan) over sockets: bit-identical to the
    simulator — stats included — for a commutative integer monoid AND
    the non-commutative affine monoid, at pow-2 and awkward p alike."""
    for alg in ("halving", "quartering", "reduce_scatter"):
        pl = plan(ScanSpec(kind="exclusive", algorithm=alg), p,
                  nbytes=64)
        _assert_dist_matches_sim(
            pl.schedule(), _witness("add", p, 8, seed=p),
            monoid_lib.ADD)
        pl = plan(ScanSpec(kind="exclusive", algorithm=alg,
                           monoid="affine"), p, nbytes=64)
        _assert_dist_matches_sim(
            pl.schedule(), _witness("affine", p, 8, seed=p),
            monoid_lib.get("affine"))
        if p in (4, 7):  # scan_total variants ride the same block IR
            pl = plan(ScanSpec(kind="scan_total", algorithm=alg,
                               monoid="add"), p, nbytes=64)
            _assert_dist_matches_sim(
                pl.schedule(), _witness("add", p, 8, seed=p),
                monoid_lib.ADD)


@pytest.mark.parametrize("p_inter,p_intra,nbytes",
                         [(3, 4, 262_144), (2, 4, 1_048_576)])
def test_rank_executor_composed_hierarchical(p_inter, p_intra, nbytes):
    spec = ScanSpec(kind="exclusive", monoid="add")
    pl = plan_hierarchical(spec, p_inter=p_inter, p_intra=p_intra,
                           nbytes=nbytes)
    # shrink the payload: the PLAN is priced at `nbytes` (to pin the
    # per-tier divergence) but the executed witness stays small
    S = max((sp.segments for sp in pl.sub_plans), default=1)
    x = _witness("add", pl.p, 4 * S, seed=1)
    _assert_dist_matches_sim(pl.schedule(), x, monoid_lib.ADD)


def test_rank_executor_composed_scan_total_multi_output():
    spec = ScanSpec(kind="scan_total", monoid="add")
    pl = plan_hierarchical(spec, p_inter=3, p_intra=4, nbytes=256)
    x = _witness("add", pl.p, 8, seed=2)
    got = _assert_dist_matches_sim(pl.schedule(), x, monoid_lib.ADD)
    assert isinstance(got, tuple) and len(got) == 2


@pytest.mark.parametrize("p", [3, 5, 6, 7, 12])
def test_rank_executor_scan_total_non_pow2(p):
    """Satellite: the non-pow-2 scan_total reroute (fused_doubling —
    the (rounds, ⊕)-minimal doubling with_total) over the
    message-passing executor, completing the four-executor battery
    (simulator/SPMD/Pallas legs live in test_schedule.py)."""
    pl = plan(ScanSpec(kind="scan_total", monoid="add",
                       algorithm="fused_doubling"), p, nbytes=64)
    sched = pl.schedule()
    assert sched.algorithm == "fused_doubling"
    x = _witness("add", p, 8, seed=p)
    got = _assert_dist_matches_sim(sched, x, monoid_lib.ADD)
    assert isinstance(got, tuple) and len(got) == 2
    ref = np.zeros_like(x)
    ref[1:] = np.cumsum(x[:-1], axis=0)
    assert np.array_equal(got[0], ref)
    assert np.array_equal(got[1], np.broadcast_to(x.sum(0), x.shape))


def test_local_transport_counts_and_masked_consume():
    # a butterfly at p=8 sends on every edge every round; the masked
    # receivers must still consume frames (no cross-round aliasing),
    # which the bit-identity above proves — here pin the accounting
    p = 8
    pl = plan(ScanSpec(kind="allreduce", algorithm="butterfly"), p,
              nbytes=64)
    x = _witness("add", p, 8)
    xs = [x[r] for r in range(p)]
    with LocalTransport(p) as tr:
        run_ranks_threaded(tr, pl.schedule(), xs, monoid_lib.ADD)
        stats = tr.stats()
    assert stats["cross_msgs"] == 0  # one process: all local
    assert stats["local_msgs"] == p * pl.rounds
    assert stats["local_bytes"] == p * pl.rounds * x[0].nbytes


# ---------------------------------------------------------------------------
# Layer 2: hierarchical planning (no subprocesses)
# ---------------------------------------------------------------------------


def test_plan_hierarchical_tiers_diverge():
    spec = ScanSpec(kind="exclusive", monoid="add")
    pl = plan_hierarchical(spec, p_inter=3, p_intra=4, nbytes=262_144)
    inner, outer = pl.sub_plans[0], pl.sub_plans[-1]
    assert inner.spec.axes == ("local",)
    assert outer.spec.axes == ("proc",)
    assert inner.algorithm != outer.algorithm
    assert (inner.algorithm, outer.algorithm) == ("halving", "ring")
    # the opposite regime sends the pricier proc tier round-frugal
    # while the intra tier stays on the mid-m block builder
    pl2 = plan_hierarchical(spec, p_inter=2, p_intra=4,
                            nbytes=1_048_576)
    assert (pl2.sub_plans[0].algorithm,
            pl2.sub_plans[-1].algorithm) == ("halving", "123")


def test_plan_hierarchical_explain_tags_both_axes():
    spec = ScanSpec(kind="exclusive", monoid="add")
    pl = plan_hierarchical(spec, p_inter=3, p_intra=4, nbytes=262_144)
    rows = pl.explain()
    axes = {r["axis"] for r in rows}
    assert axes == {"local", "proc"}
    # each tier has exactly one chosen row per sub-problem, and the
    # runner-up rows say WHY they lost
    for axis in axes:
        chosen = [r for r in rows if r["axis"] == axis and r["chosen"]]
        losers = [r for r in rows if r["axis"] == axis
                  and not r["chosen"]]
        assert chosen and losers
        assert all("vs" in r["why"] for r in losers)


def test_plan_hierarchical_routes_inter_axis_to_dci():
    # under the default profile the proc axis must price at the dci
    # tier even though DEFAULT_PROFILE only routes "pod" there
    spec = ScanSpec(kind="exclusive", monoid="add")
    pl = plan_hierarchical(spec, p_inter=2, p_intra=2, nbytes=1024)
    rows = pl.explain()

    def alpha_per_round(axis):
        r = next(x for x in rows if x["axis"] == axis and x["chosen"]
                 and x["rounds"] > 0)
        return r["cost_alpha"] / r["rounds"]

    # dci α (10e-6/hop) > ici α (1e-6/hop) under the default profile
    assert alpha_per_round("proc") > alpha_per_round("local")


def test_factor_ranks():
    assert scan_api.factor_ranks(12, 3) == (3, 4)
    assert scan_api.factor_ranks(8, 1) == (1, 8)
    with pytest.raises(ValueError, match="divide"):
        scan_api.factor_ranks(10, 3)
    with pytest.raises(ValueError, match="nprocs >= 1"):
        scan_api.factor_ranks(8, 0)
    with pytest.raises(ValueError, match="nprocs >= 1"):
        scan_api.factor_ranks(8, -2)


def test_plan_hierarchical_rejects_degenerate_tiers():
    spec = ScanSpec(kind="exclusive", monoid="add")
    with pytest.raises(ValueError):
        plan_hierarchical(spec, p_inter=0, p_intra=4)


# ---------------------------------------------------------------------------
# Layer 3: real worker processes (module-scoped pool: 2 procs x 2)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pool():
    with WorkerPool(2, 2, timeout=180) as pl:
        yield pl


def test_pool_bit_identity_and_stats(pool):
    spec = ScanSpec(kind="exclusive", monoid="add")
    pl = plan_hierarchical(spec, p_inter=2, p_intra=2, nbytes=4096)
    sched = pl.schedule()
    x = _witness("add", pool.p, 512, seed=3)
    res = pool.run(sched, x)
    with collect_stats() as st:
        want = SimulatorExecutor().execute(sched, x, monoid_lib.ADD)
    assert np.array_equal(res.outputs, want)
    assert res.stats["rounds"] == st.rounds == pl.rounds
    assert res.stats["op_applications"] == st.op_applications
    assert sum(res.stats["bytes_per_round"]) == \
        sum(st.bytes_per_round)
    assert res.transport["cross_bytes"] > 0
    assert res.transport["cross_msgs"] > 0


def test_pool_noncommutative_across_processes(pool):
    # combine order across a REAL process boundary
    pl = plan(ScanSpec(kind="exclusive", algorithm="123",
                       monoid="affine"), pool.p, nbytes=64)
    x = _witness("affine", pool.p, 8, seed=4)
    res = pool.run(pl.schedule(), x, monoid="affine")
    want = SimulatorExecutor().execute(pl.schedule(), x,
                                       monoid_lib.get("affine"))
    for g, w in zip(res.outputs, want):
        assert np.array_equal(g, w)


def test_pool_repeats_and_hop_timing(pool):
    pl = plan(ScanSpec(kind="exclusive"), pool.p, nbytes=256)
    x = _witness("add", pool.p, 32, seed=5)
    res = pool.run(pl.schedule(), x, repeats=3)
    assert len(res.seconds) == 3
    assert all(s > 0 for s in res.seconds)
    # per-rank walltimes (the straggler detector's input): one row per
    # repeat, one positive entry per global rank
    assert len(res.rank_seconds) == 3
    for per_rank in res.rank_seconds:
        assert len(per_rank) == pool.p
        assert all(s > 0 for s in per_rank)
    hop = pool.measure_hop(8192, repeats=4)
    assert hop > 0
    # the sweep helper the dist bench exports into BENCH_dist.json
    hops = tune.measure_hops(pool, sizes=(8, 4096), repeats=2)
    assert [h["nbytes"] for h in hops] == [8, 4096]
    assert all(h["seconds"] > 0 for h in hops)


def test_pool_observe_dist_feeds_autotuner(pool):
    from repro.core.autotune import AutoTuner

    pl = plan(ScanSpec(kind="exclusive"), pool.p, nbytes=256)
    x = _witness("add", pool.p, 32, seed=8)
    res = pool.run(pl.schedule(), x, repeats=2)
    tuner = AutoTuner(install=False)
    rep = tuner.observe_dist(res, pl.schedule(), 256)
    assert len(tuner.reservoir("dci")) == 1
    assert len(rep.rank_seconds) == pool.p
    assert rep.inflation >= 1.0


def test_pool_run_plan_wrapper(pool):
    spec = ScanSpec(kind="scan_total", monoid="add")
    pl = plan_hierarchical(spec, p_inter=2, p_intra=2, nbytes=1024)
    x = _witness("add", pool.p, 128, seed=6)
    res = run_plan(pool, pl, x)
    want = SimulatorExecutor().execute(pl.schedule(), x,
                                       monoid_lib.ADD)
    assert isinstance(res.outputs, tuple) and len(res.outputs) == 2
    for g, w in zip(res.outputs, want):
        assert np.array_equal(g, w)


def test_pool_schedule_p_mismatch_raises(pool):
    pl = plan(ScanSpec(kind="exclusive"), pool.p + 1, nbytes=64)
    with pytest.raises(ValueError, match="pool"):
        pool.run(pl.schedule(), _witness("add", pool.p + 1, 4))


def test_calibrate_dist_fits_dci_from_pool(pool):
    prof = tune.calibrate_dist(pool, ms=(4096, 65_536), repeats=2)
    assert prof.source == "calibrated"
    names = dict(prof.tiers)
    assert set(names) == {"dci", "ici"}
    dci = names["dci"]
    assert dci.source == "calibrated"
    # real IPC hops cost SOMETHING: at least one fitted constant
    # must be strictly positive (nnls can zero individual coords)
    assert dci.alpha > 0 or dci.beta > 0 or dci.gamma > 0
    assert dict(prof.axis_tiers)["proc"] == "dci"
    assert prof.mesh_fingerprint == tune.dist_fingerprint(2, 2)
    assert prof.default_tier == "ici"
    assert dict(prof.residuals)["dci"] >= 0
    # the fitted profile round-trips through the store
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        tune.save_profile(prof, d)
        back = tune.load_profile(prof.mesh_fingerprint, d)
    assert back is not None
    assert dict(back.tiers)["dci"].alpha == dci.alpha


def test_worker_error_propagates_with_context(pool):
    # a schedule whose p disagrees with the scattered block makes the
    # WORKER raise; the pool must surface it as a coordinator error,
    # not a hang (guards the error-reply path in worker_main)
    sched = plan(ScanSpec(kind="exclusive"), pool.p,
                 nbytes=64).schedule()
    bad = [("run", {"schedule": sched, "monoid": "nope",
                    "xs": [np.zeros(4)] * pool.p_intra,
                    "collect": False, "repeats": 1})
           for _ in range(pool.nprocs)]
    with pytest.raises(RuntimeError, match="worker 0 failed"):
        pool._request(bad)
    # the pool stays usable after the failed task (replies drained)
    pl = plan(ScanSpec(kind="exclusive"), pool.p, nbytes=64)
    x = _witness("add", pool.p, 8, seed=7)
    res = pool.run(pl.schedule(), x)
    want = SimulatorExecutor().execute(pl.schedule(), x,
                                       monoid_lib.ADD)
    assert np.array_equal(res.outputs, want)


def test_launcher_cli_smoke():
    # the CI gate, end to end in a subprocess (small payload)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.dist.launcher", "--nprocs", "2",
         "--p-intra", "2", "--m", "65536", "--smoke"],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ,
             "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "bit-identical to simulator: True" in proc.stdout
