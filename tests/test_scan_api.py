"""Planner (ScanSpec/ScanPlan) tests.

Covers the ISSUE-1 acceptance criteria — ScanPlan's predicted
rounds/⊕/all-gather counts exactly match ``collect_stats()``
measurements of the traced programs for every registered algorithm at
p in 2..17 (subprocess on 17 fake devices), the "auto" choice flips
from 123-doubling to the ring as payload bytes grow, plan caching, the
multi-axis sub-plan rewrite, the deprecation shim on ModelConfig —
plus the ISSUE-2 large-m acceptance: "auto" selects the *segmented*
ring and the traced program measures exactly the p−2+S rounds and
rounds·m/S serialized bytes the plan predicts (schedule-IR tests live
in test_schedule.py).
"""

import dataclasses

import pytest

from helpers import run_with_devices

from repro.core.scan_api import (
    CostModel, ScanSpec, algorithms, plan, plan_cache_clear)


# ---------------------------------------------------------------------------
# Pure planner behavior (no devices)
# ---------------------------------------------------------------------------


def test_registry_covers_all_kinds():
    assert algorithms("exclusive") == (
        "123", "1doubling", "halving", "native", "quartering",
        "reduce_scatter", "ring", "two_op")
    assert algorithms("inclusive") == ("hillis_steele",)
    assert algorithms("allreduce") == ("butterfly",)


def test_plan_matches_theory_round_counts():
    from repro.core import oracle

    for p in range(1, 40):
        assert plan(ScanSpec(algorithm="123"), p).rounds == oracle.q_123(p)
        assert plan(ScanSpec(algorithm="1doubling"), p).rounds == \
            oracle.rounds_1doubling(p)
        assert plan(ScanSpec(algorithm="two_op"), p).rounds == \
            oracle.rounds_two_op(p)
        assert plan(ScanSpec(algorithm="ring"), p).rounds == max(0, p - 1)
        assert plan(ScanSpec(algorithm="native"), p).rounds == 0


def test_auto_small_payload_picks_123_at_paper_scale():
    # p=36 is the paper's cluster: q=6 rounds beats 1-doubling (7) and
    # ties two-⊕ (6) with fewer ⊕ — the planner must take 123.
    pl = plan(ScanSpec(algorithm="auto"), p=36, nbytes=8)
    assert pl.algorithm == "123"
    # expensive monoid pushes harder toward ⊕-frugal 123
    pl = plan(ScanSpec(algorithm="auto", monoid="affine"), p=36, nbytes=64)
    assert pl.algorithm == "123"


def test_auto_flips_to_ring_as_payload_grows():
    spec = ScanSpec(algorithm="auto")
    small = plan(spec, p=36, nbytes=64)
    large = plan(spec, p=36, nbytes=64 << 20)
    assert small.algorithm == "123"
    assert large.algorithm == "ring"
    # the winner progression over m is monotone through the regimes:
    # a round-frugal small-m family, then the block-distributed mid-m
    # builders, then the segmented ring — never backwards
    regime = {"123": 0, "1doubling": 0, "two_op": 0, "native": 0,
              "halving": 1, "quartering": 1, "reduce_scatter": 1,
              "ring": 2}
    winners = [plan(spec, p=36, nbytes=64 << e).algorithm
               for e in range(0, 21)]
    ranks = [regime[a] for a in winners]
    assert ranks == sorted(ranks), winners
    assert 1 in ranks, winners  # the mid-m band is non-empty at p=36


def test_auto_respects_cost_model_override():
    # a latency-free, bandwidth-free model cares only about ⊕ bytes:
    # among unsegmented algorithms (segments=1 pin), native's p-1
    # whole-payload local folds lose — to 123's q-1, and now to the
    # block builders whose ⊕ touches shrinking m/R row blocks
    ops_only = CostModel(alpha=0.0, beta=0.0, gamma=1.0)
    pl = plan(ScanSpec(algorithm="auto", segments=1), p=36,
              nbytes=64 << 20, cost_model=ops_only)
    assert pl.algorithm in ("123", "1doubling", "halving",
                            "quartering", "reduce_scatter")
    # with segmentation free to vary, the pipelined ring's per-round ⊕
    # touches only m/S bytes — it is legitimately the ⊕-byte-frugal
    # choice for huge payloads
    pl = plan(ScanSpec(algorithm="auto"), p=36, nbytes=64 << 20,
              cost_model=ops_only)
    assert pl.algorithm == "ring" and pl.segments > 1
    # an all-gather-loving model (free bandwidth/ops, latency counts
    # hops: native = p-1 ring hops) still prefers 123's q rounds…
    lat_only = CostModel(alpha=1.0, beta=0.0, gamma=0.0)
    assert plan(ScanSpec(algorithm="auto"), p=36, nbytes=1,
                cost_model=lat_only).algorithm == "123"


def test_plan_cache_returns_same_object():
    plan_cache_clear()
    a = plan(ScanSpec(algorithm="auto"), p=16, nbytes=128)
    b = plan(ScanSpec(algorithm="auto"), p=16, nbytes=128)
    assert a is b
    c = plan(ScanSpec(algorithm="auto"), p=16, nbytes=129)
    assert c is not a


def test_plan_cache_lru_bounded():
    from repro.core.scan_api import (
        PLAN_CACHE_MAXSIZE, plan_cache_info, plan_cache_resize)

    spec = ScanSpec(algorithm="123")
    try:
        plan_cache_resize(4)
        info = plan_cache_info()
        assert info["maxsize"] == 4 and info["size"] == 0
        for nbytes in range(8, 8 + 10):
            plan(spec, p=16, nbytes=nbytes)
        info = plan_cache_info()
        assert info["size"] <= 4  # bounded: old entries evicted
        assert info["misses"] == 10
        # the most recent entry is still resident…
        plan(spec, p=16, nbytes=17)
        assert plan_cache_info()["hits"] == info["hits"] + 1
        # …and the oldest was evicted, so it misses again
        plan(spec, p=16, nbytes=8)
        assert plan_cache_info()["misses"] == 11
        with pytest.raises(ValueError, match="maxsize"):
            plan_cache_resize(0)
    finally:
        plan_cache_resize()
    assert plan_cache_info()["maxsize"] == PLAN_CACHE_MAXSIZE


def test_plan_cache_evictions_and_resize_dropped_count():
    """Satellite: plan_cache_info()["evictions"] counts LRU pressure
    only (planner errors are misses with no entry, never evictions),
    and plan_cache_resize() returns how many cached plans it dropped —
    the number the autotuner reports as drift-invalidated."""
    from repro.core.scan_api import (
        plan_cache_clear, plan_cache_info, plan_cache_resize)

    spec = ScanSpec(algorithm="123")
    try:
        plan_cache_resize(4)
        assert plan_cache_info()["evictions"] == 0
        for nbytes in range(8, 18):  # 10 distinct keys into 4 slots
            plan(spec, p=16, nbytes=nbytes)
        info = plan_cache_info()
        assert info["size"] == 4 and info["evictions"] == 6
        # a planner error is a miss that stores nothing — it must not
        # inflate the eviction count
        with pytest.raises(ValueError):
            plan(ScanSpec(algorithm="nope"), p=8)
        info = plan_cache_info()
        assert info["evictions"] == 6 and info["size"] == 4
        # resize reports exactly the resident plans it dropped…
        assert plan_cache_resize(8) == 4
        assert plan_cache_info()["size"] == 0
        plan(spec, p=16, nbytes=8)
        assert plan_cache_resize(8) == 1
        # …and clear resets the whole ledger
        plan_cache_clear()
        info = plan_cache_info()
        assert (info["hits"], info["misses"], info["size"],
                info["evictions"]) == (0, 0, 0, 0)
    finally:
        plan_cache_resize()


def test_multiaxis_plan_rewrites_into_subplans():
    spec = ScanSpec(kind="exclusive", algorithm="123",
                    axis_name=("pod", "data"))
    pl = plan(spec, p=(2, 8), nbytes=64)
    assert pl.p == 16
    inner, reduce_, outer = pl.sub_plans
    assert inner.spec.kind == "exclusive" and inner.p == 8
    assert reduce_.spec.kind == "allreduce" and reduce_.p == 8
    assert outer.spec.kind == "exclusive" and outer.p == 2
    assert pl.rounds == inner.rounds + reduce_.rounds + outer.rounds
    # +1 for the outer ⊕ combining the two partial prefixes
    assert pl.op_applications == (
        inner.op_applications + reduce_.op_applications
        + outer.op_applications + 1)
    assert "allreduce" in pl.describe()


def test_spec_validation_and_over():
    with pytest.raises(ValueError):
        ScanSpec(kind="bogus")
    spec = ScanSpec(kind="exclusive", monoid="add")
    s2 = spec.over(("pod", "data"), monoid="affine")
    assert s2.axis_name == ("pod", "data") and s2.monoid == "affine"
    assert spec.axis_name is None  # original untouched
    with pytest.raises(ValueError):
        plan(ScanSpec(algorithm="nope"), p=8)
    with pytest.raises(ValueError):
        plan(spec, p=(2, 4))  # one axis, two sizes


def test_host_exscan_twin():
    import numpy as np

    from repro.core.scan_api import host_exscan

    lengths = np.array([3, 1, 4, 1, 5], np.int64)
    np.testing.assert_array_equal(host_exscan(lengths),
                                  np.array([0, 3, 4, 8, 9]))
    np.testing.assert_array_equal(host_exscan(np.array([7])), [0])


def test_modelconfig_scan_spec_shim():
    from repro.models.config import ModelConfig

    base = dict(name="t", family="dense", n_layers=1, d_model=8,
                n_heads=1, n_kv_heads=1, d_ff=16, vocab=32)
    cfg = ModelConfig(**base)
    assert cfg.scan_spec.algorithm == "auto"  # planner by default
    cfg = ModelConfig(**base, scan=ScanSpec(algorithm="ring"))
    assert cfg.scan_spec.algorithm == "ring"
    # deprecated string knob still works, with a warning
    cfg = ModelConfig(**base, exscan_algorithm="native")
    with pytest.warns(DeprecationWarning):
        assert cfg.scan_spec.algorithm == "native"
    cfg2 = dataclasses.replace(cfg, dtype="float32")
    with pytest.warns(DeprecationWarning):
        assert cfg2.scan_spec.algorithm == "native"  # survives replace


# ---------------------------------------------------------------------------
# Plan-vs-measurement property (the acceptance criterion): predicted
# rounds/⊕/all-gathers equal collect_stats() of the traced program for
# EVERY registered algorithm of every kind at p in 2..17.
# ---------------------------------------------------------------------------

_PROPERTY = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex
from repro.core.scan_api import ScanSpec, scan, plan, algorithms

devs = np.array(jax.devices())
checked = 0
for p in range(2, 18):
    mesh = Mesh(devs[:p].reshape(p), ("x",))
    x = np.arange(p * 4, dtype=np.int32).reshape(p, 4)
    ref = np.zeros_like(x)
    ref[1:] = np.cumsum(x[:-1], axis=0)
    for kind in ("exclusive", "inclusive", "allreduce"):
        for alg in algorithms(kind):
            spec = ScanSpec(kind=kind, monoid="add", algorithm=alg,
                            axis_name="x")
            with ex.collect_stats() as st:
                f = jax.jit(shard_map(lambda v: scan(v, spec), mesh=mesh,
                                      in_specs=P("x"), out_specs=P("x")))
                got = np.asarray(f(x))
            pl = plan(spec, p=p, nbytes=16)
            assert st.rounds == pl.rounds, (kind, alg, p, st, pl)
            assert st.op_applications == pl.op_applications, \\
                (kind, alg, p, st, pl)
            assert st.allgathers == pl.allgathers, (kind, alg, p, st, pl)
            if kind == "exclusive":
                assert np.array_equal(got, ref), (alg, p)
            elif kind == "inclusive":
                assert np.array_equal(got, np.cumsum(x, axis=0)), (alg, p)
            else:
                assert np.array_equal(
                    got, np.broadcast_to(x.sum(0, keepdims=True), x.shape)
                ), (alg, p)
            checked += 1
print("OK plans-match-measurements", checked)
"""


def test_plan_predictions_match_measured_stats():
    out = run_with_devices(_PROPERTY, 17, x64=False, timeout=1200)
    assert "OK plans-match-measurements" in out
    # 16 p-values x (8 exclusive + 1 inclusive + 1 allreduce)
    assert "160" in out


# "auto" end-to-end: the traced program uses the planner's pick, and the
# measured round count equals the plan's prediction.
_AUTO = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex
from repro.core.scan_api import ScanSpec, scan, plan

p = 8
mesh = Mesh(np.array(jax.devices())[:p].reshape(p), ("x",))
x = np.arange(p * 4, dtype=np.int32).reshape(p, 4)
ref = np.zeros_like(x)
ref[1:] = np.cumsum(x[:-1], axis=0)
spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto",
                axis_name="x")
with ex.collect_stats() as st:
    f = jax.jit(shard_map(lambda v: scan(v, spec), mesh=mesh,
                          in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
pl = plan(spec, p=p, nbytes=x[0].nbytes)
assert np.array_equal(got, ref)
assert st.rounds == pl.rounds, (st.rounds, pl.rounds)
print("OK auto", pl.algorithm, pl.rounds)
"""


def test_auto_spec_end_to_end():
    out = run_with_devices(_AUTO, 8, x64=False)
    assert "OK auto" in out


# Large-m acceptance (ISSUE-2): "auto" selects the segmented ring; the
# traced SPMD program measures exactly the p−2+S rounds and the
# rounds·m/S (~between m and 2m) serialized bytes the plan predicts,
# with output bit-identical to the oracle.
_SEGMENTED_RING = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex
from repro.core.scan_api import ScanSpec, scan, plan

p = 8
mesh = Mesh(np.array(jax.devices()).reshape(p), ("x",))
rng = np.random.default_rng(0)
x = rng.integers(0, 1 << 30, size=(p, 1 << 19)).astype(np.int64)  # 4MiB
ref = np.zeros_like(x)
ref[1:] = np.cumsum(x[:-1], axis=0)
spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto",
                axis_name="x")
with ex.collect_stats() as st:
    f = jax.jit(shard_map(lambda v: scan(v, spec), mesh=mesh,
                          in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
m = x[0].nbytes
pl = plan(spec, p=p, nbytes=m)
assert pl.algorithm == "ring" and pl.segments > 1, pl
assert np.array_equal(got, ref)  # bit-identical to the oracle
assert st.rounds == pl.rounds == p - 2 + pl.segments, (st.rounds, pl)
measured = sum(st.bytes_per_round)
assert measured == pl.bytes_on_wire, (measured, pl.bytes_on_wire)
assert m < measured < 2 * m, (measured, m)  # pipelined serialization
# pinned segment counts trace exactly p-2+S rounds of m/S bytes
for S in (1, 2, 4, 8):
    sspec = ScanSpec(kind="exclusive", monoid="add", algorithm="ring",
                     segments=S, axis_name="x")
    with ex.collect_stats() as st:
        f = jax.jit(shard_map(lambda v: scan(v, sspec), mesh=mesh,
                              in_specs=P("x"), out_specs=P("x")))
        got = np.asarray(f(x))
    assert np.array_equal(got, ref), S
    assert st.rounds == p - 2 + S, (S, st.rounds)
    assert st.bytes_per_round == [m // S] * st.rounds, S
print("OK segmented ring", pl.segments, pl.rounds,
      round(measured / m, 3))
"""


def test_auto_large_m_runs_true_pipelined_ring():
    out = run_with_devices(_SEGMENTED_RING, 8)
    assert "OK segmented ring" in out


# Legacy wrapper compatibility: the string API must still trace the
# same programs (tests elsewhere pin its round counts; here just the
# import surface and multi-axis path through the planner rewrite).
_LEGACY = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex

x = np.arange(8 * 4, dtype=np.int64).reshape(8, 4)
ref = np.zeros_like(x)
ref[1:] = np.cumsum(x[:-1], axis=0)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
f = shard_map(lambda v: ex.exscan(v, ("pod", "data"), "add", "123"),
              mesh=mesh, in_specs=P(("pod", "data")),
              out_specs=P(("pod", "data")))
with ex.collect_stats() as st:
    got = jax.jit(f)(x)
np.testing.assert_array_equal(np.asarray(got), ref)
from repro.core.scan_api import ScanSpec, plan
pl = plan(ScanSpec(kind="exclusive", algorithm="123",
                   axis_name=("pod", "data")), p=(2, 4), nbytes=32)
assert st.rounds == pl.rounds, (st.rounds, pl.rounds)
assert st.op_applications == pl.op_applications
print("OK legacy multiaxis", st.rounds, st.op_applications)
"""


def test_legacy_wrapper_multiaxis_through_planner():
    out = run_with_devices(_LEGACY, 8)
    assert "OK legacy multiaxis" in out
