"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Sweeps shapes/dtypes (parametrized + hypothesis) per the framework's
kernel contract: every Pallas kernel must match ref.py bit-for-bit for
integer dtypes and to tight tolerances for floats.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal container: property tests skip
    from helpers import fake_hypothesis

    given, settings, st = fake_hypothesis()

from repro.kernels import ops, ref


# ------------------------------ exscan ------------------------------


@pytest.mark.parametrize(
    "n,d",
    [(8, 128), (7, 5), (256, 128), (1000, 33), (64, 1), (513, 300), (1, 1)],
)
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_blelloch_exscan_shapes(n, d, dtype):
    rng = np.random.default_rng(n * 1000 + d)
    if np.issubdtype(dtype, np.integer):
        x = rng.integers(-100, 100, (n, d)).astype(dtype)
    else:
        x = (rng.standard_normal((n, d)) * 10).astype(dtype)
    got = np.asarray(ops.exscan(jnp.asarray(x), interpret=True))
    want = np.asarray(ref.exscan_ref(jnp.asarray(x)))
    if np.issubdtype(dtype, np.integer):
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_blelloch_exscan_1d():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, 37).astype(np.int32)
    got = np.asarray(ops.exscan(jnp.asarray(x), interpret=True))
    np.testing.assert_array_equal(got, np.concatenate([[0], np.cumsum(x)[:-1]]))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=700),
    d=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_blelloch_exscan_property(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-1000, 1000, (n, d)).astype(np.int32)
    got = np.asarray(ops.exscan(jnp.asarray(x), interpret=True))
    want = np.zeros_like(x)
    want[1:] = np.cumsum(x[:-1], axis=0)
    np.testing.assert_array_equal(got, want)


# ------------------------------ ssm scan ------------------------------


@pytest.mark.parametrize("T,D", [(16, 8), (300, 100), (512, 128), (1, 1)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_ssm_scan_shapes(T, D, dtype):
    rng = np.random.default_rng(T * 131 + D)
    a = rng.uniform(0.8, 1.0, (T, D)).astype(dtype)
    b = rng.standard_normal((T, D)).astype(dtype)
    h0 = rng.standard_normal(D).astype(dtype)
    h, hf = ops.ssm_scan(jnp.asarray(a), jnp.asarray(b), jnp.asarray(h0),
                         interpret=True)
    hr, hfr = ref.ssm_scan_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(h0))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr), rtol=2e-4, atol=2e-4)


def test_ssm_chunk_summary_is_affine_monoid_element():
    """h_out == A_total * h_in + B_total for random h_in — the property
    the cross-device exscan composition relies on."""
    rng = np.random.default_rng(7)
    T, D = 130, 70
    a = rng.uniform(0.7, 1.0, (T, D)).astype(np.float32)
    b = rng.standard_normal((T, D)).astype(np.float32)
    at, bt = ops.ssm_chunk_summary(jnp.asarray(a), jnp.asarray(b), interpret=True)
    for _ in range(3):
        h_in = rng.standard_normal(D).astype(np.float32)
        _, hf = ref.ssm_scan_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(h_in))
        np.testing.assert_allclose(
            np.asarray(at) * h_in + np.asarray(bt),
            np.asarray(hf),
            rtol=3e-4,
            atol=3e-4,
        )


@settings(max_examples=15, deadline=None)
@given(
    T=st.integers(min_value=1, max_value=400),
    D=st.integers(min_value=1, max_value=150),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ssm_scan_property(T, D, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (T, D)).astype(np.float32)
    b = rng.standard_normal((T, D)).astype(np.float32)
    h, hf = ops.ssm_scan(jnp.asarray(a), jnp.asarray(b), interpret=True)
    hr, hfr = ref.ssm_scan_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=3e-4, atol=3e-4)


# ------------------------------ moe routing ------------------------------


@pytest.mark.parametrize(
    "T,K,E", [(16, 2, 4), (300, 4, 60), (256, 8, 40), (100, 2, 128), (1, 1, 2)]
)
def test_moe_routing_shapes(T, K, E):
    rng = np.random.default_rng(T * 7 + K * 3 + E)
    assign = rng.integers(0, E, (T, K)).astype(np.int32)
    pos, counts = ops.moe_routing(jnp.asarray(assign), E, interpret=True)
    pr, cr = ref.moe_routing_ref(jnp.asarray(assign), E)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(cr))


@settings(max_examples=20, deadline=None)
@given(
    T=st.integers(min_value=1, max_value=500),
    K=st.integers(min_value=1, max_value=8),
    E=st.integers(min_value=1, max_value=130),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_moe_routing_property(T, K, E, seed):
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, E, (T, K)).astype(np.int32)
    pos, counts = ops.moe_routing(jnp.asarray(assign), E, interpret=True)
    pos, counts = np.asarray(pos), np.asarray(counts)
    # invariants (stronger than allclose): positions within an expert are
    # a permutation of 0..count-1 in arrival order, counts match histogram
    np.testing.assert_array_equal(counts, np.bincount(assign.reshape(-1), minlength=E))
    flat = assign.reshape(-1)
    flat_pos = pos.reshape(-1)
    for e in range(E):
        mine = flat_pos[flat == e]
        np.testing.assert_array_equal(mine, np.arange(len(mine)))
