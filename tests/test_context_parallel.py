"""Context-parallel SSM/WKV prefill via the paper's exscan (8 devices).

The cross-device carry is an exclusive scan under the AFFINE monoid —
validated against the single-device sequential scan for both the
diagonal-SSM form (mamba) and the matrix-state form (rwkv), with all
three paper algorithms.
"""

import pytest

from helpers import run_with_devices

_CP_SSM = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.models.context_parallel import cp_ssm_scan
from repro.models.mamba import ssm_scan_chunked

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
B, S, D = 2, 256, 16
rng = np.random.default_rng(0)
a = jnp.asarray(rng.uniform(0.7, 1.0, (B, S, D)), jnp.float32)
b = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)

ref, _ = ssm_scan_chunked(a, b, jnp.zeros((B, D)))
with jax.set_mesh(mesh):
    got = jax.jit(lambda x, y: cp_ssm_scan(
        x, y, mesh, algorithm="{alg}"))(a, b)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-4, atol=2e-4)
print("OK cp_ssm {alg}")
"""

_CP_WKV = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.models.context_parallel import cp_wkv_scan
from repro.models.rwkv import wkv_scan_chunked

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
B, S, H, hd = 1, 128, 2, 8
rng = np.random.default_rng(1)
w = jnp.asarray(rng.uniform(0.8, 1.0, (B, S, H, hd, 1)), jnp.float32)
kv = jnp.asarray(rng.standard_normal((B, S, H, hd, hd)) * 0.1, jnp.float32)

ref, _ = wkv_scan_chunked(w, kv, jnp.zeros((B, H, hd, hd)))
with jax.set_mesh(mesh):
    got = jax.jit(lambda x, y: cp_wkv_scan(
        x, y, mesh, algorithm="{alg}"))(w, kv)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-4, atol=2e-4)
print("OK cp_wkv {alg}")
"""


@pytest.mark.parametrize("alg", ["123", "1doubling", "two_op"])
def test_cp_ssm_matches_sequential(alg):
    out = run_with_devices(_CP_SSM.format(alg=alg), 8, x64=False)
    assert "OK" in out


@pytest.mark.parametrize("alg", ["123", "1doubling", "two_op"])
def test_cp_wkv_matches_sequential(alg):
    out = run_with_devices(_CP_WKV.format(alg=alg), 8, x64=False)
    assert "OK" in out
