"""Online self-tuning controller tests (repro.core.autotune).

The control loop's parts in isolation, no global state unless a test
restores it: the drift metric, the bounded sliding-window reservoirs,
the refit cadence and its three refusal reasons (not_due / no_samples /
noisy / stable), the gated install with plan-cache flush accounting and
subscriber fan-out, the measured-sample intake (including the
collect_stats cross-check that rejects foreign recordings), the
dist-tier observe path, the EWMA straggler detector, and the
straggler-aware hierarchical replan.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import monoid as monoid_lib
from repro.core import scan_api, schedule as schedule_lib, tune
from repro.core.autotune import (
    AutoTuner, DriftGate, StragglerDetector, relative_drift,
    replan_hierarchical, straggler_adjusted_profile)
from repro.core.scan_api import CostModel, ScanSpec, plan
from repro.launch import mesh as mesh_lib

BASE = mesh_lib.DEFAULT_PROFILE
# (p, m) cells spanning the α- and β-dominated regimes so three
# unknowns see linearly independent feature rows
CELLS = [(p, m) for p in (4, 8) for m in (512, 8192, 262_144)]


def _scale(cm: CostModel, *, alpha=1.0, beta=1.0, gamma=1.0):
    return dataclasses.replace(cm, alpha=cm.alpha * alpha,
                               beta=cm.beta * beta,
                               gamma=cm.gamma * gamma)


def _feed(tuner, truth: CostModel, *, tier="ici", cells=CELLS,
          repeat=2):
    """Record ``repeat`` passes over ``cells``: plans under the BASE
    profile, seconds priced analytically under ``truth`` on the
    executed schedule's exact features — linear in the regressors, so
    the NNLS can recover ``truth`` exactly from a pure window."""
    spec = ScanSpec(kind="exclusive", monoid="add")
    for _ in range(repeat):
        for p, m in cells:
            pl = plan(spec, p, nbytes=m, cost_model=BASE)
            sched = pl.schedule()
            h, w, ob = tune.schedule_features(sched, m,
                                              commutative=True)
            seconds = truth.cost(hops=int(h), serial_bytes=w, ops=0,
                                 payload_bytes=0, op_bytes=ob)
            tuner.record(sched, m, seconds, tier=tier,
                         algorithm=pl.algorithm)


# ---------------------------------------------------------------------------
# Drift metric
# ---------------------------------------------------------------------------


def test_relative_drift_metric():
    cm = BASE.model("ici")
    assert relative_drift(cm, cm) == 0.0
    # a 4x shift on one constant scores 0.75, symmetrically
    assert relative_drift(cm, _scale(cm, alpha=4.0)) == \
        pytest.approx(0.75)
    assert relative_drift(_scale(cm, alpha=4.0), cm) == \
        pytest.approx(0.75)
    # a constant appearing from zero is maximal news; all-zero is none
    zero = CostModel(alpha=0.0, beta=0.0, gamma=0.0)
    assert relative_drift(zero, cm) == 1.0
    assert relative_drift(zero, zero) == 0.0
    # bounded by construction
    assert 0.0 <= relative_drift(cm, _scale(cm, beta=1e6)) <= 1.0


# ---------------------------------------------------------------------------
# Reservoirs + cadence
# ---------------------------------------------------------------------------


def test_reservoir_is_bounded_sliding_window():
    tuner = AutoTuner(BASE, capacity=4, install=False)
    for i in range(10):
        tuner.add_sample(tune.Sample(
            tier="ici", kind="exclusive", algorithm="t", p=4,
            nbytes=64, segments=1, hops=2, serial_bytes=128.0,
            op_bytes=64.0, seconds=float(i), clock="online"))
    res = tuner.reservoir("ici")
    assert len(res) == 4  # bounded…
    assert [s.seconds for s in res] == [6.0, 7.0, 8.0, 9.0]  # …newest
    assert tuner.executions == 10
    assert tuner.reservoir_sizes() == {"ici": 4}
    with pytest.raises(ValueError, match="capacity"):
        AutoTuner(BASE, capacity=0)


def test_refit_cadence_and_empty_reservoirs():
    tuner = AutoTuner(BASE, refit_every=5, install=False)
    assert tuner.maybe_refit().reason == "not_due"
    # force skips the cadence only — with no samples nothing fits
    res = tuner.maybe_refit(force=True)
    assert (res.installed, res.reason) == (False, "no_samples")
    # below the per-tier sample floor the tier does not fit either
    tuner2 = AutoTuner(BASE, install=False,
                       gate=DriftGate(min_samples=12))
    _feed(tuner2, BASE.model("ici"), cells=CELLS[:3], repeat=1)
    assert tuner2.maybe_refit(force=True).reason == "no_samples"


def test_stable_constants_never_install():
    tuner = AutoTuner(BASE, capacity=12, install=False,
                      gate=DriftGate(drift=0.3, min_samples=12))
    _feed(tuner, BASE.model("ici"))
    res = tuner.maybe_refit(force=True)
    assert (res.installed, res.reason) == (False, "stable")
    assert dict(res.drift)["ici"] < 0.3
    assert dict(res.residuals)["ici"] < 1e-6  # exact linear recovery
    assert tuner.installs == 0 and tuner.refits == 1
    assert tuner.history[-1] is res


def test_drift_past_gate_installs_refit_and_notifies():
    tuner = AutoTuner(BASE, capacity=12, install=False,
                      gate=DriftGate(drift=0.3, min_samples=12))
    seen = []
    tuner.subscribe(seen.append)
    shifted = _scale(BASE.model("ici"), alpha=4.0)
    _feed(tuner, shifted)
    res = tuner.maybe_refit(force=True)
    assert (res.installed, res.reason) == (True, "installed")
    assert dict(res.drift)["ici"] == pytest.approx(0.75)
    fit = tuner.profile.model("ici")
    assert fit.alpha == pytest.approx(shifted.alpha, rel=1e-6)
    assert fit.beta == pytest.approx(shifted.beta, rel=1e-6)
    assert tuner.profile.source == "calibrated"
    assert tuner.profile.mesh_fingerprint == "online"
    # the untouched dci tier carries over from the base profile
    assert tuner.profile.model("dci") == BASE.model("dci")
    assert seen == [tuner.profile] and tuner.installs == 1
    # observe-only mode never touched the global profile
    assert mesh_lib.current_profile() is not tuner.profile


def test_noisy_fit_is_rejected():
    tuner = AutoTuner(BASE, capacity=12, install=False,
                      gate=DriftGate(max_residual=0.25,
                                     min_samples=12))
    # half the window priced 100x the other half: no single linear
    # model fits, the relative-RMS residual blows past the gate
    _feed(tuner, _scale(BASE.model("ici"), alpha=100.0, beta=100.0),
          cells=CELLS, repeat=1)
    _feed(tuner, BASE.model("ici"), cells=CELLS, repeat=1)
    res = tuner.maybe_refit(force=True)
    assert (res.installed, res.reason) == (False, "noisy")
    assert dict(res.residuals)["ici"] > 0.25
    assert tuner.installs == 0


def test_unknown_tier_is_always_news():
    tuner = AutoTuner(BASE, capacity=12, install=False,
                      gate=DriftGate(drift=0.5, min_samples=12))
    _feed(tuner, BASE.model("ici"), tier="pcie")
    res = tuner.maybe_refit(force=True)
    assert res.installed and dict(res.drift)["pcie"] == 1.0
    # the new tier lands in the profile after the carried-over ones
    assert tuner.profile.model("pcie").alpha > 0
    assert [n for n, _ in tuner.profile.tiers[:2]] == \
        [n for n, _ in BASE.tiers]


def test_record_rejects_foreign_stats_recording():
    tuner = AutoTuner(BASE, install=False)
    pl = plan(ScanSpec(kind="exclusive", monoid="add"), 8, nbytes=64,
              cost_model=BASE)
    sched = pl.schedule()
    x = np.arange(8 * 8, dtype=np.int64).reshape(8, 8)
    with schedule_lib.collect_stats() as st:
        schedule_lib.SimulatorExecutor().execute(sched, x,
                                                 monoid_lib.ADD)
    # a recording of THIS execution passes the cross-check
    s = tuner.record(sched, 64, 1e-5, stats=st)
    assert s is not None and len(tuner.reservoir("ici")) == 1
    # a recording of some OTHER execution is refused, not fitted
    wrong = schedule_lib.CollectiveStats()
    wrong.rounds = sched.rounds + 1
    assert tuner.record(sched, 64, 1e-5, stats=wrong) is None
    assert len(tuner.reservoir("ici")) == 1
    # batch intake: schedules and sizes must line up
    with pytest.raises(ValueError, match="payload sizes"):
        tuner.record([sched, sched], [64], 1e-5)


def test_install_flushes_plan_cache_and_sets_global_profile():
    prev = mesh_lib.install_profile(None)
    scan_api.plan_cache_clear()
    try:
        with scan_api.use_cost_model(mesh_lib.axis_cost_model):
            spec = ScanSpec(kind="exclusive", monoid="add")
            for m in (64, 4096, 262_144):
                plan(spec.over("pod"), 8, nbytes=m)
        cached = scan_api.plan_cache_info()["size"]
        assert cached >= 3
        tuner = AutoTuner(BASE, install=True)
        shifted = dataclasses.replace(BASE, tiers=tuple(
            (n, _scale(cm, alpha=4.0)) for n, cm in BASE.tiers))
        dropped = tuner.install(shifted)
        assert dropped == cached  # every stale-priced plan flushed
        assert scan_api.plan_cache_info()["size"] == 0
        assert mesh_lib.current_profile() is shifted
        assert tuner.plans_dropped == cached and tuner.installs == 1
    finally:
        mesh_lib.install_profile(prev)
        scan_api.plan_cache_clear()


# ---------------------------------------------------------------------------
# Straggler detection + replan
# ---------------------------------------------------------------------------


def test_straggler_detector_ewma_and_report():
    det = StragglerDetector(threshold=1.5, smoothing=1.0)
    rep = det.report()
    assert not rep.straggling and rep.inflation == 1.0
    rep = det.observe([1.0, 1.0, 1.0, 1.0])
    assert not rep.straggling and rep.slow_ranks == ()
    rep = det.observe([1.0, 1.0, 1.0, 3.0])
    assert rep.slow_ranks == (3,)
    assert rep.inflation == pytest.approx(3.0)
    assert rep.median == pytest.approx(1.0)
    det.reset()
    assert det.report().rank_seconds == ()
    # smoothing < 1: one transient spike does NOT flag a straggler
    det = StragglerDetector(threshold=2.0, smoothing=0.25)
    det.observe([1.0, 1.0, 1.0, 1.0])
    rep = det.observe([1.0, 1.0, 1.0, 4.0])  # ewma(3) = 1.75 < 2x
    assert not rep.straggling
    for _ in range(8):  # …but persistent slowness accumulates
        rep = det.observe([1.0, 1.0, 1.0, 4.0])
    assert rep.slow_ranks == (3,)
    with pytest.raises(ValueError, match="threshold"):
        StragglerDetector(threshold=1.0)
    with pytest.raises(ValueError, match="smoothing"):
        StragglerDetector(smoothing=0.0)


def test_straggler_adjusted_profile_inflates_only_dci_alpha():
    det = StragglerDetector(threshold=1.5, smoothing=1.0)
    rep = det.observe([1.0, 1.0, 2.5, 1.0])
    adj = straggler_adjusted_profile(BASE, rep)
    assert adj.model("dci").alpha == pytest.approx(
        BASE.model("dci").alpha * 2.5)
    assert adj.model("dci").beta == BASE.model("dci").beta
    assert adj.model("ici") == BASE.model("ici")
    # a healthy report is the identity (same object, no rebuild)
    calm = det.observe([1.0, 1.0, 1.0, 1.0])
    for _ in range(8):
        calm = det.observe([1.0, 1.0, 1.0, 1.0])
    assert straggler_adjusted_profile(BASE, calm) is BASE


def test_replan_hierarchical_searches_factorings():
    spec = ScanSpec(kind="exclusive", monoid="add")
    best = replan_hierarchical(spec, 12, nbytes=262_144,
                               cost_model=BASE)
    assert best.p == 12
    # the search winner is no worse than any pinned factoring
    for p_inter, p_intra in ((2, 6), (3, 4), (4, 3), (6, 2)):
        pinned = scan_api.plan_hierarchical(
            spec, p_inter=p_inter, p_intra=p_intra, nbytes=262_144,
            cost_model=BASE)
        assert best.cost <= pinned.cost, (p_inter, p_intra)
    # prime p: only the degenerate flat factorings exist
    flat = replan_hierarchical(spec, 7, nbytes=4096, cost_model=BASE)
    assert flat.p == 7 and not flat.algorithm.startswith("composite(")
    with pytest.raises(ValueError, match="p >= 1"):
        replan_hierarchical(spec, 0, nbytes=64)


def test_replan_hierarchical_straggler_pressure():
    spec = ScanSpec(kind="exclusive", monoid="add")
    det = StragglerDetector(threshold=1.5, smoothing=1.0)
    rep = det.observe([1.0] * 11 + [50.0])  # one pathological host
    calm_plan = replan_hierarchical(spec, 12, nbytes=262_144,
                                    cost_model=BASE)
    slow_plan = replan_hierarchical(spec, 12, nbytes=262_144,
                                    cost_model=BASE, report=rep)
    # both are real plans for the same problem; under inflated dci
    # pricing the winner's cost reflects the inflated α
    assert slow_plan.p == calm_plan.p == 12
    assert slow_plan.cost >= calm_plan.cost


def test_observe_dist_feeds_reservoir_and_stragglers():
    from repro.dist.launcher import DistResult

    tuner = AutoTuner(BASE, install=False, straggler_threshold=1.5)
    pl = plan(ScanSpec(kind="exclusive", monoid="add"), 4, nbytes=64,
              cost_model=BASE)
    res = DistResult(
        outputs=None, seconds=[1e-3, 1.1e-3], stats=None,
        transport={},
        rank_seconds=[[1.0, 1.0, 1.0, 3.0], [1.0, 1.0, 1.0, 3.0]])
    rep = tuner.observe_dist(res, pl.schedule(), 64)
    assert len(tuner.reservoir("dci")) == 1
    assert tuner.reservoir("dci")[0].seconds == \
        pytest.approx(np.median(res.seconds))
    assert rep.slow_ranks == (3,)
    # a result without per-rank timings still records the sample
    bare = DistResult(outputs=None, seconds=[1e-3], stats=None,
                      transport={})
    rep = tuner.observe_dist(bare, pl.schedule(), 64)
    assert len(tuner.reservoir("dci")) == 2
    assert rep.slow_ranks == (3,)  # detector state persists


# ---------------------------------------------------------------------------
# End-to-end: the serve loop swaps profiles through the subscriber
# ---------------------------------------------------------------------------


def test_service_attach_autotuner_feeds_and_rewarm_on_install():
    from repro.serve import Bucket, ScanService

    scan_api.plan_cache_clear()
    tuner = AutoTuner(BASE, capacity=12, refit_every=1000,
                      install=False,
                      gate=DriftGate(drift=0.3, min_samples=12))
    svc = ScanService(
        8, [Bucket(kind="exclusive", monoid="add", shape=(),
                   dtype=np.int32)],
        max_batch=4, cost_model=BASE)
    svc.attach_autotuner(tuner)
    assert svc._autotune_tier == BASE.tier_for_axis(None)
    svc.warmup()
    rng = np.random.default_rng(0)
    for _ in range(3):
        for _ in range(4):
            svc.submit(rng.integers(0, 9, size=(8,)).astype(np.int32))
        svc.drain()
    # every executed batch landed one measured sample
    assert tuner.executions == 3
    assert svc.post_warmup_compiles == 0
    # an install (even observe-only) notifies the service, which
    # re-prices and re-warms under the new profile — the zero-compile
    # contract survives the swap
    shifted = dataclasses.replace(BASE, tiers=tuple(
        (n, _scale(cm, alpha=4.0)) for n, cm in BASE.tiers))
    tuner.install(shifted)
    assert svc.cost_model is shifted
    for _ in range(4):
        svc.submit(rng.integers(0, 9, size=(8,)).astype(np.int32))
    svc.drain()
    assert svc.post_warmup_compiles == 0
