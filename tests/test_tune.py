"""Cost-model calibration tests (core/tune.py + the profile plumbing).

Covers the ISSUE-4 acceptance criteria: the simulated-clock
microbenchmark + NNLS fit recovers a known α/β/γ within 5% for p in
2..17; calibration produces a persisted, schema-versioned
:class:`CostProfile` whose installation flips ``ScanPlan
.cost_model_source`` to "calibrated"; an inflated-β profile flips
"auto" to the segmented ring at a smaller m than the defaults; the
plan cache keys on *resolved* pricing constants (per-call closures hit,
recalibration invalidates); ``use_cost_model`` nests re-entrantly; and
``ScanPlan.explain()`` lists every candidate algorithm's predicted
cost.
"""

import json
import subprocess
import sys

import numpy as np
import pytest

from helpers import REPO, SRC

from repro.core import scan_api, tune
from repro.core.scan_api import (
    PROFILE_SCHEMA_VERSION, CostModel, CostProfile, ScanSpec, plan,
    plan_cache_clear, use_cost_model)
from repro.launch import mesh as mesh_lib


def _profile(alpha=2e-6, beta=4e-11, gamma=5e-12, source="calibrated",
             tier="ici", **kw):
    return CostProfile(
        tiers=((tier, CostModel(alpha=alpha, beta=beta, gamma=gamma,
                                source=source)),),
        source=source, default_tier=tier, **kw)


# ---------------------------------------------------------------------------
# NNLS
# ---------------------------------------------------------------------------


def test_nnls_matches_unconstrained_when_solution_nonnegative():
    rng = np.random.default_rng(0)
    A = rng.standard_normal((40, 3))
    x_true = np.array([0.5, 2.0, 0.1])
    b = A @ x_true
    np.testing.assert_allclose(tune.nnls(A, b), x_true, rtol=1e-8)


def test_nnls_clamps_negative_coordinates():
    # b = A @ [1, -1]: the best nonnegative fit zeroes the second coord
    A = np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]])
    b = A @ np.array([1.0, -1.0])
    x = tune.nnls(A, b)
    assert (x >= 0).all()
    assert x[1] == 0.0
    # and is no worse than any other nonnegative candidate
    assert np.linalg.norm(A @ x - b) <= \
        np.linalg.norm(A @ np.array([0.5, 0.0]) - b) + 1e-12


# ---------------------------------------------------------------------------
# Fit recovery: data generated from known constants comes back (< 5%)
# ---------------------------------------------------------------------------


def test_fit_recovers_known_constants_p2_to_17():
    truth = CostModel(alpha=3.7e-6, beta=1.0 / 31e9, gamma=4.4e-12)
    samples = tune.calibration_sweep(
        "ici", truth, ps=tuple(range(2, 18)), ms=(512, 8192, 131_072),
        clock="simulated")
    fitted, resid = tune.fit_tier(samples)
    assert fitted.source == "calibrated"
    assert fitted.alpha == pytest.approx(truth.alpha, rel=0.05)
    assert fitted.beta == pytest.approx(truth.beta, rel=0.05)
    assert fitted.gamma == pytest.approx(truth.gamma, rel=0.05)
    assert resid < 0.05


def test_fit_profile_carries_provenance_and_residuals():
    truth = mesh_lib.DEFAULT_PROFILE
    prof = tune.calibrate(simulate=True, truth=truth,
                          ps=(2, 3, 4, 8), ms=(512, 8192),
                          mesh_fingerprint="test-mesh")
    assert prof.source == "calibrated"
    assert prof.mesh_fingerprint == "test-mesh"
    assert prof.axis_tiers == truth.axis_tiers
    residuals = dict(prof.residuals)
    assert set(residuals) == {name for name, _ in truth.tiers}
    assert all(r < 0.05 for r in residuals.values())
    for tier, want in truth.tiers:
        got = prof.model(tier)
        assert got.alpha == pytest.approx(want.alpha, rel=0.05)
        assert got.beta == pytest.approx(want.beta, rel=0.05)
        assert got.gamma == pytest.approx(want.gamma, rel=0.05)


def test_schedule_features_match_plan_pricing():
    # the fit's design matrix must mirror the planner's conventions,
    # or the fitted constants would price plans inconsistently
    for name, p, m, S in (("123", 9, 4096, 1), ("ring", 9, 4096, 8),
                          ("native", 9, 4096, 1)):
        pl = plan(ScanSpec(kind="exclusive", algorithm=name,
                           segments=S if name == "ring" else None),
                  p=p, nbytes=m)
        hops, wire, op_bytes = tune.schedule_features(
            pl.schedule(), m)
        cm = pl.cost_model
        assert cm.cost(hops=int(hops), serial_bytes=wire,
                       ops=pl.op_applications,
                       payload_bytes=-(-m // pl.segments)) == \
            pytest.approx(pl.cost)
        assert wire == pl.bytes_on_wire


# ---------------------------------------------------------------------------
# Decision boundaries under calibrated profiles
# ---------------------------------------------------------------------------


def _flip_m(cm, p=36, lo=64, hi=64 << 20):
    spec = ScanSpec(algorithm="auto")
    if plan(spec, p=p, nbytes=lo, cost_model=cm).algorithm != "123":
        return lo
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if plan(spec, p=p, nbytes=mid, cost_model=cm).algorithm == "123":
            lo = mid
        else:
            hi = mid
    return hi


def test_inflated_beta_flips_auto_off_123_at_smaller_m():
    default = mesh_lib.DEFAULT_PROFILE.model("ici")
    inflated = CostProfile(
        tiers=(("ici", CostModel(alpha=default.alpha,
                                 beta=default.beta * 100,
                                 gamma=default.gamma,
                                 source="calibrated")),),
        source="calibrated", default_tier="ici")
    m_default = _flip_m(default)
    m_inflated = _flip_m(inflated)
    assert m_inflated < m_default
    # past the boundary a byte-frugal algorithm owns the cell — the
    # block-distributed mid-m builders or the segmented ring, never
    # the rounds·m families
    pl = plan(ScanSpec(algorithm="auto"), p=36, nbytes=m_inflated,
              cost_model=inflated)
    assert pl.algorithm in ("halving", "quartering",
                            "reduce_scatter", "ring")
    assert pl.cost_model_source == "calibrated"


def test_calibrated_profile_keeps_small_m_on_123():
    # the --check gate's invariant, asserted directly on a fitted
    # profile: calibration from the default machine must not flip the
    # paper's small-m decision
    prof = tune.calibrate(simulate=True, ps=(2, 3, 4, 8, 9, 16, 17),
                          ms=(512, 8192, 131_072))
    for m in (8, 64):
        pl = plan(ScanSpec(algorithm="auto"), p=36, nbytes=m,
                  cost_model=prof.model("ici"))
        assert pl.algorithm == "123", (m, pl.algorithm)


# ---------------------------------------------------------------------------
# Persistence: JSON store keyed by mesh fingerprint, schema-versioned
# ---------------------------------------------------------------------------


def test_profile_json_roundtrip(tmp_path):
    prof = _profile(mesh_fingerprint="cpu-test-data4",
                    axis_tiers=(("pod", "ici"),),
                    residuals=(("ici", 1.5e-9),))
    path = tune.save_profile(prof, str(tmp_path))
    assert path.endswith("profile_cpu-test-data4.json")
    loaded = tune.load_profile("cpu-test-data4", str(tmp_path))
    assert loaded == prof
    assert loaded.fingerprint() == prof.fingerprint()
    # unknown fingerprint -> None (fallback to defaults)
    assert tune.load_profile("other-mesh", str(tmp_path)) is None
    # latest_profile finds it by mtime
    assert tune.latest_profile(str(tmp_path)) == prof


def test_profile_schema_version_gate(tmp_path):
    prof = _profile(mesh_fingerprint="m")
    path = tune.save_profile(prof, str(tmp_path))
    obj = json.load(open(path))
    obj["schema_version"] = PROFILE_SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(obj, f)
    with pytest.raises(ValueError):
        CostProfile.from_json(obj)
    # the store treats an incompatible schema as absent, not fatal
    assert tune.load_profile("m", str(tmp_path)) is None


def _store_file(tmp_path, fingerprint, text: str) -> str:
    path = tune.profile_path(fingerprint, str(tmp_path))
    import os

    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    return path


@pytest.mark.parametrize("text", [
    "{not json at all",                          # syntax error
    "",                                          # empty file
    '{"schema_version": 1}',                     # missing every field
    '{"schema_version": 1, "tiers": "oops"}',    # tiers wrong type
    '{"schema_version": 1, "tiers": [["ici", 3]]}',  # model not a dict
    '{"schema_version": "one", "tiers": {}}',    # version wrong type
], ids=["syntax", "empty", "missing", "tiers-str", "model-int",
        "version-str"])
def test_load_profile_corrupted_store_returns_none(tmp_path, text):
    """A broken store entry degrades to None (caller falls back to
    defaults) — NEVER an exception escaping into planning."""
    _store_file(tmp_path, "broken", text)
    assert tune.load_profile("broken", str(tmp_path)) is None


def test_load_profile_truncated_after_save_returns_none(tmp_path):
    prof = _profile(mesh_fingerprint="trunc")
    path = tune.save_profile(prof, str(tmp_path))
    body = open(path).read()
    with open(path, "w") as f:
        f.write(body[:len(body) // 2])  # torn write / partial copy
    assert tune.load_profile("trunc", str(tmp_path)) is None


def test_latest_profile_skips_corrupted_entries(tmp_path):
    import os
    import time

    good = _profile(mesh_fingerprint="good")
    tune.save_profile(good, str(tmp_path))
    bad = _store_file(tmp_path, "newer-but-broken", "{garbage")
    # make the broken entry strictly newest by mtime
    future = time.time() + 60
    os.utime(bad, (future, future))
    assert tune.latest_profile(str(tmp_path)) == good


def test_resolve_profile_survives_corrupted_store(tmp_path):
    _store_file(tmp_path, "cpu-x", "{garbage")
    assert mesh_lib.resolve_profile(
        fingerprint="cpu-x", directory=str(tmp_path)) is \
        mesh_lib.DEFAULT_PROFILE


def test_resolve_profile_prefers_calibrated_then_defaults(tmp_path):
    assert mesh_lib.resolve_profile(
        fingerprint="nope", directory=str(tmp_path)) is \
        mesh_lib.DEFAULT_PROFILE
    sim = _profile(mesh_fingerprint="simulated-default")
    tune.save_profile(sim, str(tmp_path))
    # device-free calibration is the fallback for any mesh fingerprint
    assert mesh_lib.resolve_profile(
        fingerprint="nope", directory=str(tmp_path)) == sim
    exact = _profile(alpha=9e-6, mesh_fingerprint="nope")
    tune.save_profile(exact, str(tmp_path))
    assert mesh_lib.resolve_profile(
        fingerprint="nope", directory=str(tmp_path)) == exact


def test_install_profile_routes_axis_cost_model():
    prof = _profile(tier="ici", axis_tiers=(("pod", "ici"),))
    prev = mesh_lib.install_profile(prof)
    try:
        assert mesh_lib.axis_cost_model("data") == prof.model("ici")
        assert mesh_lib.axis_cost_model("data").source == "calibrated"
        with scan_api.use_cost_model(mesh_lib.axis_cost_model):
            pl = plan(ScanSpec(algorithm="auto"), p=16, nbytes=64)
        assert pl.cost_model_source == "calibrated"
    finally:
        mesh_lib.install_profile(prev)
    assert mesh_lib.axis_cost_model("data") is mesh_lib.ICI_COST
    assert mesh_lib.axis_cost_model(("pod", "data")) is mesh_lib.DCI_COST


# ---------------------------------------------------------------------------
# Plan-cache keying on resolved pricing constants (satellite regression)
# ---------------------------------------------------------------------------


def test_plan_cache_keyed_by_resolved_constants_not_callable_identity():
    plan_cache_clear()
    spec = ScanSpec(algorithm="auto")
    a = plan(spec, p=16, nbytes=128,
             cost_model=lambda axis: CostModel())
    b = plan(spec, p=16, nbytes=128,
             cost_model=lambda axis: CostModel())
    assert a is b  # distinct closures, same constants: cache HIT
    info = scan_api.plan_cache_info()
    assert info["hits"] >= 1


def test_plan_cache_invalidated_by_recalibrated_profile():
    plan_cache_clear()
    spec = ScanSpec(algorithm="auto")
    prev = mesh_lib.install_profile(None)
    try:
        a = plan(spec, p=16, nbytes=128,
                 cost_model=mesh_lib.axis_cost_model)
        # recalibration installs new constants behind the SAME callable:
        # stale plans must not be served
        mesh_lib.install_profile(_profile(alpha=123e-6))
        b = plan(spec, p=16, nbytes=128,
                 cost_model=mesh_lib.axis_cost_model)
        assert b is not a
        assert b.cost_model_source == "calibrated"
        assert a.cost_model_source == "default"
    finally:
        mesh_lib.install_profile(prev)


def test_plan_accepts_profile_directly():
    prof = _profile()
    pl = plan(ScanSpec(algorithm="auto"), p=8, nbytes=64,
              cost_model=prof)
    assert pl.cost_model == prof.model("ici")
    with use_cost_model(prof):
        pl2 = plan(ScanSpec(algorithm="auto"), p=8, nbytes=64)
    assert pl2 is pl


# ---------------------------------------------------------------------------
# use_cost_model re-entrancy (satellite)
# ---------------------------------------------------------------------------


def test_use_cost_model_nests_reentrantly():
    outer = CostModel(alpha=1e-5)
    inner = CostModel(alpha=2e-5)
    assert scan_api.current_cost_model() is scan_api.DEFAULT_COST_MODEL
    with use_cost_model(outer):
        assert scan_api.current_cost_model() is outer
        with use_cost_model(inner):
            assert scan_api.current_cost_model() is inner
            with use_cost_model(outer):
                assert scan_api.current_cost_model() is outer
            assert scan_api.current_cost_model() is inner
        assert scan_api.current_cost_model() is outer
    assert scan_api.current_cost_model() is scan_api.DEFAULT_COST_MODEL


def test_use_cost_model_none_means_defaults():
    # PR-1 semantics: installing None plans under the defaults rather
    # than poisoning resolution with a NoneType
    with use_cost_model(CostModel(alpha=9e-5)):
        with use_cost_model(None):
            assert scan_api.current_cost_model() is \
                scan_api.DEFAULT_COST_MODEL
            pl = plan(ScanSpec(algorithm="auto"), p=8, nbytes=64)
            assert pl.cost_model == scan_api.DEFAULT_COST_MODEL


def test_tier_for_axis_tuple_routes_to_slowest_member():
    prof = CostProfile(
        tiers=(("dci", CostModel(alpha=1e-5)),
               ("ici", CostModel(alpha=1e-6))),
        axis_tiers=(("data", "ici"), ("pod", "dci")),
        default_tier="ici")
    # tuple order must not matter: "pod" anywhere means DCI
    assert prof.tier_for_axis(("data", "pod")) == "dci"
    assert prof.tier_for_axis(("pod", "data")) == "dci"
    assert prof.for_axis(("data", "pod")) == prof.model("dci")
    assert prof.tier_for_axis(("data",)) == "ici"
    assert prof.tier_for_axis("unlisted") == "ici"
    assert mesh_lib.DEFAULT_PROFILE.for_axis(("data", "pod")) is \
        mesh_lib.DCI_COST


def test_use_cost_model_restores_on_exception():
    cm = CostModel(alpha=1e-5)
    with pytest.raises(RuntimeError):
        with use_cost_model(cm):
            raise RuntimeError("boom")
    assert scan_api.current_cost_model() is scan_api.DEFAULT_COST_MODEL


# ---------------------------------------------------------------------------
# ScanPlan.explain(): the runner-up table
# ---------------------------------------------------------------------------


def test_explain_lists_every_candidate_with_costs():
    pl = plan(ScanSpec(algorithm="auto"), p=36, nbytes=8)
    rows = pl.explain()
    names = {r["algorithm"] for r in rows}
    assert names == set(scan_api.algorithms("exclusive"))
    chosen = [r for r in rows if r["chosen"]]
    assert len(chosen) == 1 and chosen[0]["algorithm"] == pl.algorithm
    assert rows[0]["chosen"]  # cheapest first: auto picked the min
    assert chosen[0]["cost"] == pytest.approx(pl.cost)
    for r in rows:
        assert r["cost"] == pytest.approx(
            r["cost_alpha"] + r["cost_beta"] + r["cost_gamma"])
        assert r["why"]
    # losers say why: the dominant excess component is named
    losers = [r for r in rows if not r["chosen"]]
    assert losers and all("dominated by" in r["why"] for r in losers)


def test_explain_pinned_spec_reports_auto_preference():
    pl = plan(ScanSpec(algorithm="ring"), p=36, nbytes=8)
    row = next(r for r in pl.explain() if r["chosen"])
    assert "pinned by spec" in row["why"]
    assert "auto would pick" in row["why"]


def test_explain_pinned_spec_marks_cheaper_candidates_cheaper():
    # candidates the pin kept from winning must read as cheaper, with
    # the leading (most negative) component named — not a garbled
    # "+-Nus ... dominated by" line
    pl = plan(ScanSpec(algorithm="ring"), p=64, nbytes=8)
    rows = pl.explain()
    cheaper = [r for r in rows if r["cost"] < pl.cost]
    assert cheaper
    for r in cheaper:
        assert "cheaper than pinned ring" in r["why"]
        assert "+-" not in r["why"]
    assert all("+-" not in r["why"] for r in rows)


def test_explain_composite_tags_axes():
    pl = plan(ScanSpec(algorithm="auto", axis_name=("pod", "data")),
              p=(2, 8), nbytes=64)
    rows = pl.explain()
    assert {r["axis"] for r in rows} == {"pod", "data"}
    # every sub-plan contributes a full candidate table
    assert sum(1 for r in rows if r["chosen"]) == len(pl.sub_plans)


# ---------------------------------------------------------------------------
# Walltime clock (SPMD executor on devices — fake CPU devices suffice)
# ---------------------------------------------------------------------------


_WALLTIME = """
from repro.core import scan_api, tune

sched = scan_api.get_algorithm("exclusive", "123").schedule(4)
t = tune.measure_schedule_walltime(sched, 512, repeats=2)
assert t > 0.0, t
prof = tune.calibrate(simulate=False, ps=(4,), ms=(512, 8192),
                      mesh_fingerprint="walltime-test")
assert prof.source == "calibrated"
assert prof.mesh_fingerprint == "walltime-test"
assert all(cm.source == "calibrated" for _, cm in prof.tiers)
assert all(cm.alpha >= 0 and cm.beta >= 0 and cm.gamma >= 0
           for _, cm in prof.tiers)
print("OK walltime", f"{t:.2e}")
"""


def test_walltime_clock_on_fake_devices():
    from helpers import run_with_devices

    out = run_with_devices(_WALLTIME, 4, x64=False)
    assert "OK walltime" in out


def test_walltime_refuses_without_enough_devices():
    sched = scan_api.get_algorithm("exclusive", "123").schedule(64)
    with pytest.raises(RuntimeError, match="--simulate"):
        tune.measure_schedule_walltime(sched, 512)


# ---------------------------------------------------------------------------
# CLI: the acceptance-criterion one-command flow
# ---------------------------------------------------------------------------


def test_cli_simulate_persists_profile_and_reports_residual(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.tune", "--simulate",
         "--out", str(tmp_path), "--ps", "2,3,4,8,9",
         "--ms", "512,8192"],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ,
             "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"},
        cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "residual=" in proc.stdout
    prof = tune.load_profile("simulated-default", str(tmp_path))
    assert prof is not None and prof.source == "calibrated"
    # plans priced through the persisted profile carry the provenance
    pl = plan(ScanSpec(algorithm="auto"), p=36, nbytes=8,
              cost_model=prof)
    assert pl.cost_model_source == "calibrated"
    assert {r["algorithm"] for r in pl.explain()} == \
        set(scan_api.algorithms("exclusive"))


# ---------------------------------------------------------------------------
# Process-topology fingerprints (satellite): multi-process profiles
# must never key-collide with single-host ones in the store.
# ---------------------------------------------------------------------------


def test_mesh_fingerprint_folds_in_process_topology(monkeypatch):
    import jax

    mesh = mesh_lib.make_host_mesh(1, 1)
    single = mesh_lib.mesh_fingerprint(mesh)
    # single-process fingerprints are UNCHANGED (existing stored
    # profiles stay resolvable after this extension)
    assert "procs" not in single
    assert single == mesh_lib.mesh_fingerprint(mesh, processes=1)
    multi = mesh_lib.mesh_fingerprint(mesh, processes=4,
                                      local_devices=2)
    assert multi == single + "-procs4x2"
    assert multi != mesh_lib.mesh_fingerprint(mesh, processes=2,
                                              local_devices=4)
    # defaults come from the jax runtime
    monkeypatch.setattr(jax, "process_count", lambda: 3)
    monkeypatch.setattr(jax, "local_device_count", lambda: 5)
    assert mesh_lib.mesh_fingerprint(mesh) == single + "-procs3x5"


def test_process_topology_keys_profile_store(tmp_path, monkeypatch):
    """The cache-keying regression: a profile calibrated across N
    processes resolves ONLY under the N-process fingerprint — a
    single-process planner never silently prices with cross-process
    constants (and vice versa)."""
    import jax

    mesh = mesh_lib.make_host_mesh(1, 1)
    multi_fp = mesh_lib.mesh_fingerprint(mesh, processes=2,
                                         local_devices=1)
    dist_prof = _profile(alpha=9e-5, mesh_fingerprint=multi_fp,
                         tier="dci")
    tune.save_profile(dist_prof, str(tmp_path))
    # single-process resolution falls through to defaults...
    monkeypatch.setattr(jax, "process_count", lambda: 1)
    assert mesh_lib.resolve_profile(
        mesh, directory=str(tmp_path)) is mesh_lib.DEFAULT_PROFILE
    # ...while the matching process topology finds the profile
    monkeypatch.setattr(jax, "process_count", lambda: 2)
    monkeypatch.setattr(jax, "local_device_count", lambda: 1)
    assert mesh_lib.resolve_profile(
        mesh, directory=str(tmp_path)) == dist_prof


def test_dist_fingerprint_shape():
    assert tune.dist_fingerprint(2, 4) == "dist-cpu-procs2x4"
    assert tune.dist_fingerprint(2, 4) != tune.dist_fingerprint(4, 2)
