"""Gradient compression: exactness at k=100%, EF convergence at 10%."""

from helpers import run_with_devices

_CODE = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.optim.compression import sparse_gradient_sync, \
    init_error_feedback

p = 8
mesh = Mesh(np.array(jax.devices()).reshape(p), ("data",))
rng = np.random.default_rng(0)

# --- k=1.0 must equal the dense mean ---
g = rng.standard_normal((p, 64)).astype(np.float32)
e0 = np.zeros((p, 64), np.float32)

def sync(gl, el):
    s, ne, _ = sparse_gradient_sync({"w": gl}, {"w": el}, "data",
                                    k_fraction=1.0)
    return s["w"], ne["w"]

f = jax.jit(shard_map(sync, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data"))))
s, ne = f(g, e0)
dense_mean = g.mean(axis=0)
for r in range(p):
    np.testing.assert_allclose(np.asarray(s)[r], dense_mean, rtol=1e-6)
np.testing.assert_allclose(np.asarray(ne), 0, atol=1e-7)
print("OK exact at k=1.0")

# --- k=0.1 with error feedback minimizes a quadratic ---
# distributed SGD on f(w) = mean_r ||w - t_r||^2 ; optimum = mean(t)
targets = rng.standard_normal((p, 32)).astype(np.float32)
w = np.zeros((32,), np.float32)
err = np.zeros((p, 32), np.float32)

def step(wl, el, tl):
    grad = 2 * (wl - tl)  # per-device gradient, batch-sharded targets
    s, ne, _ = sparse_gradient_sync({"w": grad[None]}, {"w": el[None]},
                                    "data", k_fraction=0.1)
    return s["w"][0], ne["w"][0]

f = jax.jit(shard_map(step, mesh=mesh,
                      in_specs=(P(None), P("data"), P("data")),
                      out_specs=(P("data"), P("data"))))
opt = targets.mean(axis=0)
init_dist = np.linalg.norm(w - opt)
lr = 0.08
for it in range(2500):
    g_synced, err = f(jnp.asarray(w), err, targets)
    w = w - lr * np.asarray(g_synced)[0]
    if it in (1000, 1800):
        lr /= 4  # EF top-k limit cycle is O(lr); decay to shrink it
final = np.linalg.norm(w - opt)
assert final < 0.15 and final < 0.1 * init_dist, (init_dist, final)
print("OK EF convergence at k=0.1")
"""


def test_gradient_compression():
    out = run_with_devices(_CODE, 8, x64=False, timeout=900)
    assert "OK exact" in out and "OK EF convergence" in out
