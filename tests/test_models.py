"""Per-architecture smoke + consistency tests (reduced configs, CPU).

* forward/loss: finite, correct shapes, for all 10 archs
* decode-with-cache == full forward (cache correctness), all decodable
* train step decreases loss (integration with optimizer)
* MoE: multi-device (2 data x 4 model) == single-device reference
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from helpers import run_with_devices
from repro import configs
from repro.models import params as PD
from repro.models.model import Model


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        batch["labels"] = batch["tokens"]
        if cfg.frontend == "vision":
            batch["prefix"] = jnp.asarray(
                rng.standard_normal((B, cfg.n_prefix, cfg.d_model)),
                jnp.float32)
    return batch


@pytest.mark.parametrize("name", configs.ARCHITECTURES)
def test_smoke_forward_loss(name):
    cfg = configs.get_smoke(name)
    mesh = _mesh1()
    m = Model(cfg, mesh)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    with jax.set_mesh(mesh):
        loss, metrics = jax.jit(m.loss)(params, batch)
        tokens = batch.get("tokens")
        embeds = batch.get("embeds") if cfg.frontend == "audio" else \
            batch.get("prefix")
        logits, _ = jax.jit(m.forward)(params, tokens, embeds)
    S_out = 32 + (cfg.n_prefix if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, S_out, PD.vocab_padded(cfg))
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", configs.ARCHITECTURES)
def test_decode_matches_forward(name):
    cfg = configs.get_smoke(name, capacity_factor=16.0)
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode step")
    mesh = _mesh1()
    m = Model(cfg, mesh)
    params = m.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    with jax.set_mesh(mesh):
        logits_full, _ = jax.jit(m.forward)(params, tokens)
        cache = m.init_cache(B, S)
        step = jax.jit(m.decode_step)
        outs = []
        for t in range(S):
            lg, cache = step(params, cache, tokens[:, t : t + 1], t)
            outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), atol=2e-3, rtol=1e-2)


@pytest.mark.parametrize("name", ["llama3_8b", "qwen2_moe_a2_7b",
                                  "rwkv6_1_6b", "jamba_1_5_large_398b"])
def test_train_step_decreases_loss(name):
    from repro.optim.adamw import adamw_init, adamw_update

    cfg = configs.get_smoke(name)
    mesh = _mesh1()
    m = Model(cfg, mesh)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            m.loss, has_aux=True)(params, batch)
        params, opt = adamw_update(params, grads, opt, lr=3e-3)
        return params, opt, loss

    with jax.set_mesh(mesh):
        losses = []
        for _ in range(8):
            params, opt, loss = step(params, opt, batch)
            losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0], losses


def test_param_counts_match_published():
    expect = {
        "jamba_1_5_large_398b": (398e9, 410e9),
        "qwen2_moe_a2_7b": (14e9, 16e9),
        "llama3_8b": (7.9e9, 8.2e9),
        "gemma2_9b": (9.0e9, 9.5e9),
        "rwkv6_1_6b": (1.5e9, 1.8e9),
        "pixtral_12b": (11.8e9, 12.6e9),
    }
    for name, (lo, hi) in expect.items():
        n = PD.count_params(configs.get(name))
        assert lo <= n <= hi, (name, n)
    # active params: jamba publishes 94B
    na = PD.count_params(configs.get("jamba_1_5_large_398b"),
                         active_only=True)
    assert 90e9 <= na <= 98e9, na


_MOE_MULTIDEV = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import configs
from repro.models.model import Model

cfg = configs.get_smoke("qwen2_moe_a2_7b", capacity_factor=16.0,
                        exscan_algorithm="{alg}")
B, S = 4, 16
rng = np.random.default_rng(3)
tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

# single-device reference
mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
m1 = Model(cfg, mesh1)
params = m1.init_params(jax.random.PRNGKey(0))
with jax.set_mesh(mesh1):
    ref, _ = jax.jit(m1.forward)(params, tokens)

# 2 data x 4 model
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
m = Model(cfg, mesh)
with jax.set_mesh(mesh):
    got, _ = jax.jit(m.forward)(params, tokens)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           atol=3e-4, rtol=3e-3)
print("OK moe multidev")
"""


@pytest.mark.parametrize("alg", ["123", "1doubling", "two_op"])
def test_moe_multidevice_matches_reference(alg):
    out = run_with_devices(_MOE_MULTIDEV.format(alg=alg), 8, x64=False)
    assert "OK" in out
