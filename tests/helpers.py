"""Shared test helpers.

Multi-device collective tests must run in a subprocess: jax fixes the
device count at first initialization, and the main pytest process is
required to see exactly ONE CPU device (smoke tests and benches depend
on that).  ``run_with_devices`` executes a python snippet with
``--xla_force_host_platform_device_count=N``.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, x64: bool = True,
                     timeout: int = 600) -> str:
    """Run ``code`` in a fresh interpreter with N fake CPU devices.

    Raises on non-zero exit; returns captured stdout.
    """
    # the ambient-flag scrub lives with the mesh helpers so benchmarks
    # spawn fake-device subprocesses through the same recipe
    from repro.launch.mesh import fake_device_env

    env = fake_device_env(n_devices)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


def fake_hypothesis():
    """Stand-ins for ``hypothesis`` when it is not installed.

    ``@given(...)`` becomes a skip marker so property tests are reported
    as skipped (not errors) in minimal containers; everything else in
    the module still runs.
    """
    import pytest

    def given(*args, **kwargs):
        del args, kwargs
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        del args, kwargs
        return lambda f: f

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    return given, settings, _Strategies()
