"""Schedule-IR tests (ISSUE-2): every registered algorithm builds an
executable Schedule; the numpy simulator executes it at any p against
the sequential oracle; the SPMD and simulator executors agree on
results AND on measured stats; segmentation is a schedule transform
with the p−2+S pipelined round structure; the Pallas executor lowers
the RoundStep combine hook through the block-combine kernel."""

import numpy as np
import pytest

from helpers import run_with_devices

from repro.core import monoid as monoid_lib
from repro.core import schedule as schedule_lib
from repro.core.scan_api import ScanSpec, algorithms, plan
from repro.core.schedule import (
    SimulatorExecutor, build_123, build_ring, collect_stats, segment)


# ---------------------------------------------------------------------------
# Simulator-executor property: every registered schedule at p in 2..17
# reproduces the numpy oracle, and the executed stats equal the plan's
# predictions (no devices, no tracing).
# ---------------------------------------------------------------------------


def _exclusive_ref(x):
    ref = np.zeros_like(x)
    ref[1:] = np.cumsum(x[:-1], axis=0)
    return ref


def test_simulator_matches_oracle_every_algorithm():
    sim = SimulatorExecutor()
    checked = 0
    for p in range(2, 18):
        x = np.arange(p * 4, dtype=np.int64).reshape(p, 4) ** 2
        refs = {
            "exclusive": _exclusive_ref(x),
            "inclusive": np.cumsum(x, axis=0),
            "allreduce": np.broadcast_to(x.sum(0), x.shape),
        }
        for kind, ref in refs.items():
            for alg in algorithms(kind):
                pl = plan(ScanSpec(kind=kind, algorithm=alg), p,
                          nbytes=32)
                with collect_stats() as st:
                    got = sim.execute(pl.schedule(), x, monoid_lib.ADD)
                assert np.array_equal(got, ref), (kind, alg, p)
                assert st.rounds == pl.rounds, (kind, alg, p, st, pl)
                assert st.op_applications == pl.op_applications, \
                    (kind, alg, p, st, pl)
                assert st.allgathers == pl.allgathers, (kind, alg, p)
                checked += 1
    assert checked == 16 * 10  # 16 p-values x (8 excl + 1 incl + 1 allred)


@pytest.mark.parametrize("S", [1, 2, 4, 8])
def test_simulator_segmented_ring_noncommutative(S):
    """The pipelined ring at every segment count, under the AFFINE
    (non-commutative) monoid, at p in 2..17."""
    sim = SimulatorExecutor()
    for p in range(2, 18):
        rng = np.random.default_rng(p * 10 + S)
        a = rng.standard_normal((p, 16))
        b = rng.standard_normal((p, 16))
        oa = np.ones_like(a)
        ob = np.zeros_like(b)
        ca, cb = np.ones(16), np.zeros(16)
        for r in range(p):
            oa[r], ob[r] = ca, cb
            ca, cb = a[r] * ca, a[r] * cb + b[r]
        sched = build_ring(p, S)
        assert sched.rounds == p - 2 + S
        assert sched.op_applications == max(0, p - 3 + S)
        with collect_stats() as st:
            ga, gb = sim.execute(sched, (a, b), monoid_lib.AFFINE)
        np.testing.assert_allclose(ga, oa, rtol=1e-12)
        np.testing.assert_allclose(gb, ob, rtol=1e-12)
        assert st.rounds == sched.rounds
        assert st.op_applications == sched.op_applications


def test_simulator_segmented_ring_unpadded_sizes():
    """Segment counts that do NOT divide the payload still compute the
    right answer (zero-padded final block)."""
    sim = SimulatorExecutor()
    for p, S, m in [(5, 4, 7), (9, 8, 3), (6, 2, 1)]:
        x = np.arange(p * m, dtype=np.int64).reshape(p, m) + 1
        got = sim.execute(build_ring(p, S), x, monoid_lib.ADD)
        assert np.array_equal(got, _exclusive_ref(x)), (p, S, m)


def test_segmented_ring_edge_cases():
    """The pipelined ring's corner cells: p=2 (every S), S=1 at any p,
    S > p (more segments than ranks — the pipeline is all fill), and
    non-divisible leading dims / multi-dim leaves (padded final
    block), simulator-executed with plan-vs-measured stats."""
    sim = SimulatorExecutor()
    cases = (
        [(2, S, 5) for S in (1, 2, 4, 8)]  # p=2: n rounds == S
        + [(p, 1, 3) for p in (2, 3, 9)]  # S=1: the plain ring
        + [(2, 16, 3), (3, 8, 5), (5, 16, 7)]  # S > p
        + [(4, 8, 13), (7, 4, 1)]  # S doesn't divide m
    )
    for p, S, m in cases:
        x = np.arange(p * m, dtype=np.int64).reshape(p, m) + 1
        sched = build_ring(p, S)
        assert sched.rounds == p - 2 + S, (p, S)
        with collect_stats() as st:
            got = sim.execute(sched, x, monoid_lib.ADD)
        assert np.array_equal(got, _exclusive_ref(x)), (p, S, m)
        assert st.rounds == sched.rounds, (p, S)
        assert st.op_applications == sched.op_applications == \
            max(0, p - 3 + S), (p, S)
    # multi-dim leading dims: the leaves flatten, segment, and restore
    x = (np.arange(3 * 2 * 5, dtype=np.int64).reshape(3, 2, 5) ** 2
         % 1009)
    got = sim.execute(build_ring(3, 4), x, monoid_lib.ADD)
    ref = np.zeros_like(x)
    ref[1:] = np.cumsum(x[:-1], axis=0)
    assert np.array_equal(got, ref)


def test_commutative_elision_counts_and_results():
    """Commutative monoids elide the redundant combine order:
    butterfly exchange 2→1 ⊕, fused scan_reduce 3→2 ⊕ — on the IR
    (``op_count``), in the plan, and in the executed stats — with
    results unchanged."""
    sim = SimulatorExecutor()
    for p in (4, 8, 16):
        k = int(np.log2(p))
        bf = schedule_lib.build_butterfly(p)
        assert bf.op_applications == 2 * k  # non-commutative worst case
        assert bf.op_count(commutative=True) == k
        x = np.arange(p * 4, dtype=np.int64).reshape(p, 4) + 1
        with collect_stats() as st:
            got = sim.execute(bf, x, monoid_lib.ADD)
        assert np.array_equal(got, np.broadcast_to(x.sum(0), x.shape))
        assert st.op_applications == k  # measured == elided prediction
        st_sched = schedule_lib.build_scan_total(p)
        assert st_sched.op_applications == 3 * k
        assert st_sched.op_count(commutative=True) == 2 * k
        with collect_stats() as st:
            prefix, total = sim.execute(st_sched, x, monoid_lib.ADD)
        assert np.array_equal(prefix, _exclusive_ref(x))
        assert np.array_equal(total, np.broadcast_to(x.sum(0), x.shape))
        assert st.op_applications == 2 * k
        # non-commutative monoids keep both combine orders (and the
        # correct one): matmul allreduce folds in rank order
        mats = (np.random.default_rng(p).standard_normal((p, 3, 3))
                * 0.5)
        with collect_stats() as st:
            got = sim.execute(bf, mats, monoid_lib.MATMUL)
        acc = np.eye(3)
        for r in range(p):
            acc = mats[r] @ acc
        np.testing.assert_allclose(got, np.broadcast_to(acc, got.shape),
                                   rtol=1e-10, atol=1e-12)
        assert st.op_applications == 2 * k
    # plan predictions are monoid-aware and match the simulator
    for mono in ("add", "affine"):
        res = schedule_lib.verify_plan(
            plan(ScanSpec(kind="allreduce", algorithm="butterfly",
                          monoid=mono), p=8, nbytes=128))
        assert res["ok"], (mono, res)
        res = schedule_lib.verify_plan(
            plan(ScanSpec(kind="scan_total", algorithm="auto",
                          monoid=mono), p=8, nbytes=128))
        assert res["ok"], (mono, res)


# ---------------------------------------------------------------------------
# The IR itself
# ---------------------------------------------------------------------------


def test_segment_transform():
    s1 = build_ring(10)
    assert s1.rounds == 9 and s1.n_segments == 1
    s4 = segment(s1, 4)
    assert s4.rounds == 10 - 2 + 4 and s4.n_segments == 4
    assert [st.prep for st in s4.steps] == [True] * 11 + [False]
    with pytest.raises(ValueError, match="segmentable"):
        segment(build_123(10), 4)


def test_schedule_counts_match_theory():
    from repro.core import oracle

    for p in range(1, 64):
        assert build_123(p).rounds == oracle.q_123(p)
        assert build_123(p).op_applications == \
            (0 if p <= 2 else oracle.q_123(p))
        assert build_ring(p).rounds == max(0, p - 1)
        assert build_ring(p).op_applications == max(0, p - 2)


def test_plan_schedule_is_inspectable_without_tracing():
    pl = plan(ScanSpec(kind="exclusive", algorithm="123"), p=8)
    text = pl.schedule().describe()
    # round-by-round peers and ops, straight from the IR
    assert "r0" in text and "shift +1" in text and "W←recv⊕W" in text
    assert pl.schedule() is plan(
        ScanSpec(kind="exclusive", algorithm="123"), p=8).schedule()
    ringpl = plan(ScanSpec(algorithm="ring", segments=4), p=8,
                  nbytes=1024)
    assert "S=4" in ringpl.schedule().describe()
    # multi-axis plans compose into ONE axis-annotated schedule (the
    # sub_plans remain inspectable provenance)
    mpl = plan(ScanSpec(algorithm="123", axis_name=("pod", "data")),
               p=(2, 4), nbytes=64)
    msched = mpl.schedule()
    assert msched.rounds == mpl.rounds
    # the plan prices the commutative (add) elision; op_applications
    # on the IR stays the non-commutative worst case
    assert msched.op_count(commutative=True) == mpl.op_applications
    assert msched.op_applications >= mpl.op_applications
    assert msched.axes == (("pod", 2), ("data", 4))
    assert "@data" in msched.describe() and "@pod" in msched.describe()
    assert mpl.algorithm.startswith("composite(")
    assert mpl.sub_plans[0].schedule().rounds == mpl.sub_plans[0].rounds


def test_verify_plan_reports_drift_free():
    for kind in ("exclusive", "inclusive", "allreduce"):
        for alg in algorithms(kind):
            res = schedule_lib.verify_plan(
                plan(ScanSpec(kind=kind, algorithm=alg), p=9,
                     nbytes=1024))
            assert res["ok"], res
    # segmented + non-commutative + multi-axis
    res = schedule_lib.verify_plan(
        plan(ScanSpec(algorithm="ring", monoid="affine"), p=12,
             nbytes=1 << 20))
    assert res["ok"] and res["segments"] > 1, res
    # ... while "auto" at that size hands the affine payload to a
    # mid-m block builder, equally drift-free
    res = schedule_lib.verify_plan(
        plan(ScanSpec(algorithm="auto", monoid="affine"), p=12,
             nbytes=1 << 20))
    assert res["ok"] and res["algorithm"] == "quartering", res
    # multi-axis plans verify as ONE composed schedule now
    res = schedule_lib.verify_plan(
        plan(ScanSpec(algorithm="auto", axis_name=("pod", "data")),
             p=(2, 8), nbytes=256))
    assert res["ok"] and res["algorithm"].startswith("composite("), res
    assert res["rounds_measured"] == res["rounds_predicted"]
    # ... and so do fused exscan+allreduce ("scan_total") plans
    res = schedule_lib.verify_plan(
        plan(ScanSpec(kind="scan_total", algorithm="auto"), p=16,
             nbytes=64))
    assert res["ok"] and res["algorithm"] == "fused_doubling", res


def test_matmul_monoid_never_segments():
    pl = plan(ScanSpec(algorithm="auto", monoid="matmul"), p=36,
              nbytes=64 << 20)
    assert pl.segments == 1
    with pytest.raises(ValueError, match="does not support"):
        plan(ScanSpec(algorithm="123", segments=4), p=8, nbytes=1024)


# ---------------------------------------------------------------------------
# SPMD executor vs simulator executor: identical results and identical
# measured stats for every registered algorithm (plus segmented rings).
# ---------------------------------------------------------------------------

_SPMD_VS_SIM = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core import monoid as monoid_lib
from repro.core.scan_api import ScanSpec, scan, algorithms
from repro.core.schedule import SimulatorExecutor, collect_stats

p = 8
mesh = Mesh(np.array(jax.devices()).reshape(p), ("x",))
rng = np.random.default_rng(0)
x = rng.integers(0, 1 << 30, size=(p, 16)).astype(np.int64)
sim = SimulatorExecutor()
checked = 0
specs = [ScanSpec(kind=k, algorithm=a, axis_name="x")
         for k in ("exclusive", "inclusive", "allreduce")
         for a in algorithms(k)]
specs += [ScanSpec(algorithm="ring", segments=S, axis_name="x")
          for S in (2, 4, 8)]
for spec in specs:
    from repro.core.scan_api import plan
    with collect_stats() as st_spmd:
        f = jax.jit(shard_map(lambda v: scan(v, spec), mesh=mesh,
                              in_specs=P("x"), out_specs=P("x")))
        got = np.asarray(f(x))
    pl = plan(spec, p=p, nbytes=x[0].nbytes)
    with collect_stats() as st_sim:
        ref = sim.execute(pl.schedule(), x, monoid_lib.ADD)
    assert np.array_equal(got, np.asarray(ref)), spec
    assert (st_spmd.rounds, st_spmd.op_applications,
            st_spmd.allgathers) == (
        st_sim.rounds, st_sim.op_applications, st_sim.allgathers), spec
    assert st_spmd.bytes_per_round == st_sim.bytes_per_round, spec
    checked += 1
print("OK spmd==sim", checked)
"""


def test_spmd_and_simulator_executors_agree():
    out = run_with_devices(_SPMD_VS_SIM, 8)
    assert "OK spmd==sim 13" in out  # 10 registered + 3 segmented rings


_PALLAS = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core.scan_api import ScanSpec, scan
from repro.core.schedule import PallasExecutor

p = 4
mesh = Mesh(np.array(jax.devices()).reshape(p), ("x",))
x = np.arange(p * 40, dtype=np.int32).reshape(p, 40)
ref = np.zeros_like(x)
ref[1:] = np.cumsum(x[:-1], axis=0)
for alg in ("123", "1doubling", "two_op", "native", "ring",
            "halving", "quartering", "reduce_scatter"):
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm=alg,
                    axis_name="x")
    ex = PallasExecutor("x", interpret=True)
    f = jax.jit(shard_map(lambda v: scan(v, spec, executor=ex),
                          mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                          check_vma=False))
    assert np.array_equal(np.asarray(f(x)), ref), alg
# structured monoid falls back to the plain op through the same hook
spec = ScanSpec(kind="exclusive", monoid="affine", algorithm="123",
                axis_name="x")
a = np.linspace(0.5, 1.5, p * 8).reshape(p, 8)
b = np.linspace(-1.0, 1.0, p * 8).reshape(p, 8)
ex = PallasExecutor("x", interpret=True)
f = jax.jit(shard_map(lambda A, B: scan((A, B), spec, executor=ex),
                      mesh=mesh, in_specs=(P("x"), P("x")),
                      out_specs=(P("x"), P("x")), check_vma=False))
ga, gb = f(a, b)
oa = np.ones_like(a); ob = np.zeros_like(b)
ca, cb = np.ones(8), np.zeros(8)
for r in range(p):
    oa[r], ob[r] = ca, cb
    ca, cb = a[r] * ca, a[r] * cb + b[r]
np.testing.assert_allclose(np.asarray(ga), oa, rtol=1e-6)
np.testing.assert_allclose(np.asarray(gb), ob, rtol=1e-6)
# block-exchange kernel accounting: measured launches / HBM passes on
# the fused Pallas round path must equal the schedule's own law
from repro.core.scan_api import plan
from repro.core.schedule import collect_stats
for alg in ("halving", "reduce_scatter"):
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm=alg,
                    axis_name="x")
    ex = PallasExecutor("x", interpret=True)
    f = jax.jit(shard_map(lambda v: scan(v, spec, executor=ex),
                          mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                          check_vma=False))
    with collect_stats() as st:
        assert np.array_equal(np.asarray(f(x)), ref), alg
    sched = plan(spec, p=p, nbytes=x[0].nbytes).schedule()
    assert st.kernel_launches == sched.kernel_launches(True), (
        alg, st.kernel_launches, sched.kernel_launches(True))
    assert st.hbm_passes == sched.kernel_passes(True), (
        alg, st.hbm_passes, sched.kernel_passes(True))
print("OK pallas executor")
"""


def test_pallas_executor_matches_reference():
    out = run_with_devices(_PALLAS, 4, x64=False)
    assert "OK pallas executor" in out


def test_block_combine_kernel_interpret():
    import jax.numpy as jnp

    from repro.kernels.blelloch_exscan import block_combine

    rng = np.random.default_rng(0)
    for shape in [(7,), (3, 130), (2, 5, 9), (256, 128)]:
        a = rng.integers(0, 1 << 20, size=shape).astype(np.int32)
        b = rng.integers(0, 1 << 20, size=shape).astype(np.int32)
        got = block_combine(jnp.asarray(a), jnp.asarray(b), jnp.add,
                            interpret=True)
        np.testing.assert_array_equal(np.asarray(got), a + b)
        got = block_combine(jnp.asarray(a), jnp.asarray(b), jnp.maximum,
                            interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.maximum(a, b))


def test_block_combine_fused_masked_path():
    """The masked path fuses select(keep, a ⊕ b, b) into the kernel's
    single VMEM pass (the PallasExecutor shift-round hook)."""
    import jax.numpy as jnp

    from repro.kernels.blelloch_exscan import block_combine

    rng = np.random.default_rng(1)
    for shape in [(7,), (3, 130), (256, 128)]:
        a = rng.integers(0, 1 << 20, size=shape).astype(np.int32)
        b = rng.integers(0, 1 << 20, size=shape).astype(np.int32)
        for keep, want in ((True, a + b), (False, b)):
            got = block_combine(jnp.asarray(a), jnp.asarray(b),
                                jnp.add, keep=jnp.asarray(keep),
                                interpret=True)
            np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# Legacy shims warn (satellite): string-based wrappers point at ScanSpec
# ---------------------------------------------------------------------------


def test_legacy_wrappers_emit_deprecation_warning():
    import jax
    from jax import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    import repro.core.collectives as ex

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("x",))
    x = np.arange(4, dtype=np.int32).reshape(1, 4)
    for fn in (lambda v: ex.exscan(v, "x", "add", "123"),
               lambda v: ex.inclusive_scan(v, "x", "add"),
               lambda v: ex.allreduce(v, "x", "add")):
        with pytest.warns(DeprecationWarning, match="ScanSpec"):
            shard_map(fn, mesh=mesh, in_specs=P("x"),
                      out_specs=P("x"))(x)


# ---------------------------------------------------------------------------
# Non-power-of-two fallbacks (satellite): build_scan_total and the
# butterfly must degrade gracefully — correct results, explicit round
# structure, and plan/measurement agreement — at every awkward p.
# ---------------------------------------------------------------------------

NON_POW2_PS = (3, 5, 6, 7, 12)


@pytest.mark.parametrize("p", NON_POW2_PS)
def test_scan_total_non_pow2_fallback(p):
    """At non-pow-2 p the fused butterfly pairing doesn't close;
    build_scan_total reroutes to exscan+with_total and must still
    produce (exclusive prefix, total) — for a NON-commutative monoid
    too — with the (rounds, ⊕)-minimal doubling underneath."""
    sched = schedule_lib.build_scan_total(p)
    assert sched.kind == "scan_total"
    assert sched.algorithm == "fused_doubling"
    assert sched.outputs == ("prefix", "$w")
    # the reroute picked the cheaper doubling: never worse than either
    candidate = min(
        (schedule_lib.with_total(build_123(p)),
         schedule_lib.with_total(schedule_lib.build_two_op(p))),
        key=lambda s: (s.rounds, s.op_applications))
    assert (sched.rounds, sched.op_applications) == \
        (candidate.rounds, candidate.op_applications)
    x = np.arange(p * 4, dtype=np.int64).reshape(p, 4) ** 2
    prefix, total = SimulatorExecutor().execute(sched, x,
                                                monoid_lib.ADD)
    assert np.array_equal(prefix, _exclusive_ref(x))
    assert np.array_equal(total, np.broadcast_to(x.sum(0), x.shape))
    # non-commutative: affine composition order must survive the
    # fallback's shift/bcast structure
    m = monoid_lib.get("affine")
    rng = np.random.default_rng(p)
    ax = (rng.standard_normal((p, 4)), rng.standard_normal((p, 4)))
    prefix, total = SimulatorExecutor().execute(sched, ax, m)
    want_a = np.ones_like(ax[0])
    want_b = np.zeros_like(ax[1])
    for r in range(p):
        assert np.allclose(prefix[0][r], want_a)
        assert np.allclose(prefix[1][r], want_b)
        want_b = ax[1][r] + ax[0][r] * want_b
        want_a = want_a * ax[0][r]
    assert np.allclose(total[0], np.broadcast_to(want_a, ax[0].shape))
    assert np.allclose(total[1], np.broadcast_to(want_b, ax[1].shape))


@pytest.mark.parametrize("p", NON_POW2_PS)
def test_butterfly_non_pow2_fallback(p):
    """Non-pow-2 butterfly = inclusive scan + bcast of the last rank:
    order-preserving (non-commutative safe), correct, and its round
    count is the inclusive scan's plus the broadcast."""
    sched = schedule_lib.build_butterfly(p)
    incl_rounds = schedule_lib.build_hillis_steele(p).rounds
    assert sched.rounds == incl_rounds  # bcast is not a priced round
    x = np.arange(p * 4, dtype=np.int64).reshape(p, 4) + 1
    got = SimulatorExecutor().execute(sched, x, monoid_lib.ADD)
    assert np.array_equal(got, np.broadcast_to(x.sum(0), x.shape))
    m = monoid_lib.get("matmul")
    rng = np.random.default_rng(p)
    mats = rng.standard_normal((p, 3, 3))
    got = SimulatorExecutor().execute(sched, mats, m)
    # repo convention: op(lo, hi) = hi @ lo, so the rank-ordered
    # reduction is mats[p-1] @ ... @ mats[0]
    want = mats[0]
    for r in range(1, p):
        want = mats[r] @ want
    for r in range(p):
        assert np.allclose(got[r], want)


@pytest.mark.parametrize("p", NON_POW2_PS)
def test_non_pow2_plans_verify_drift_free(p):
    """The planner path over the fallbacks: predicted rounds/⊕/bytes
    must match the simulator-executed schedule exactly."""
    for kind, alg in (("scan_total", "fused_doubling"),
                      ("allreduce", "butterfly")):
        pl = plan(ScanSpec(kind=kind, algorithm=alg, monoid="add"),
                  p, nbytes=64)
        res = schedule_lib.verify_plan(pl)
        assert res["ok"], (kind, p, res)


# scan_total at awkward p across the OTHER executors (the simulator
# legs are above; the dist/LocalTransport leg lives in test_dist.py):
# the SPMD and Pallas executors must run the rerouted fused_doubling
# schedule with simulator-identical results and plan-exact stats.
_SCAN_TOTAL_NON_POW2_EXECUTORS = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core import monoid as monoid_lib
from repro.core.scan_api import ScanSpec, plan, scan_with_total
from repro.core.schedule import (
    SimulatorExecutor, PallasExecutor, collect_stats)

sim = SimulatorExecutor()
rng = np.random.default_rng(2)
checked = 0
for p in (3, 5, 6, 7, 12):
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("x",))
    spec = ScanSpec(kind="exclusive", monoid="add",
                    algorithm="fused_doubling", axis_name="x")
    pl = plan(spec.over("x", kind="scan_total"), p=p, nbytes=96)
    sched = pl.schedule()
    assert sched.algorithm == "fused_doubling", (p, sched.algorithm)
    x = rng.integers(0, 1 << 30, size=(p, 12)).astype(np.int64)
    with collect_stats() as st_sim:
        want_prefix, want_total = sim.execute(sched, x, monoid_lib.ADD)
    with collect_stats() as st_spmd:
        f = jax.jit(shard_map(lambda v: scan_with_total(v, spec),
                              mesh=mesh, in_specs=P("x"),
                              out_specs=(P("x"), P("x"))))
        prefix, total = f(x)
    assert np.array_equal(np.asarray(prefix), want_prefix), p
    assert np.array_equal(np.asarray(total), want_total), p
    assert (st_spmd.rounds, st_spmd.op_applications) == (
        st_sim.rounds, st_sim.op_applications) == (
        pl.rounds, pl.op_applications), (p, st_spmd, pl)
    ex = PallasExecutor("x", interpret=True)
    g = jax.jit(shard_map(
        lambda v: scan_with_total(v, spec, executor=ex), mesh=mesh,
        in_specs=P("x"), out_specs=(P("x"), P("x")),
        check_vma=False))
    pprefix, ptotal = g(x)
    assert np.array_equal(np.asarray(pprefix), want_prefix), p
    assert np.array_equal(np.asarray(ptotal), want_total), p
    checked += 1
print("OK scan_total non-pow2 executors", checked)
"""


def test_scan_total_non_pow2_spmd_and_pallas():
    """Satellite: the non-pow-2 scan_total reroute on the SPMD and
    Pallas executors at p in {3,5,6,7,12} — (prefix, total) bit-equal
    to the simulator, measured stats equal to the plan."""
    out = run_with_devices(_SCAN_TOTAL_NON_POW2_EXECUTORS, 12)
    assert "OK scan_total non-pow2 executors 5" in out


# ---------------------------------------------------------------------------
# Block-distributed mid-m builders (Träff 2026 halving/quartering +
# the reduce-scatter exscan): bit-identity battery across p=2..17 —
# every non-power-of-two included — under commutative and
# non-commutative monoids, with the closed-form round laws pinned.
# ---------------------------------------------------------------------------

BLOCK_ALGS = ("halving", "quartering", "reduce_scatter")


def _affine_ref(a, b):
    oa, ob = np.empty_like(a), np.empty_like(b)
    ca, cb = np.ones_like(a[0]), np.zeros_like(b[0])
    for r in range(a.shape[0]):
        oa[r], ob[r] = ca, cb
        ca, cb = a[r] * ca, a[r] * cb + b[r]
    return oa, ob


@pytest.mark.parametrize("alg", BLOCK_ALGS)
def test_block_builders_simulator_battery(alg):
    """Every p in 2..17: results match the sequential reference for
    add (bit-exact), max (bit-exact, non-zero identity) and the
    non-commutative affine monoid (allclose — the block tree reorders
    float ⊕), executed stats match the plan, and the round count
    matches the closed-form law including non-power-of-two ρ folds."""
    from repro.core import oracle

    sim = SimulatorExecutor()
    closed = {"halving": oracle.rounds_halving,
              "quartering": oracle.rounds_quartering,
              "reduce_scatter": oracle.rounds_reduce_scatter}[alg]
    rng = np.random.default_rng(3)
    for p in range(2, 18):
        pl = plan(ScanSpec(kind="exclusive", algorithm=alg), p,
                  nbytes=64)
        assert pl.rounds == closed(p), (alg, p)
        x = rng.integers(0, 1 << 30, size=(p, 8)).astype(np.int64)
        with collect_stats() as st:
            got = sim.execute(pl.schedule(), x, monoid_lib.ADD)
        assert np.array_equal(got, _exclusive_ref(x)), (alg, p)
        assert (st.rounds, st.op_applications, st.allgathers) == \
            (pl.rounds, pl.op_applications, pl.allgathers), (alg, p)
        # max: the identity is NOT the zero the row-split pads with,
        # so this catches any pad lane leaking into a real lane
        got = sim.execute(pl.schedule(), x, monoid_lib.MAX)
        want = np.empty_like(x)
        want[0] = np.iinfo(x.dtype).min  # numpy-path max identity
        want[1:] = np.maximum.accumulate(x[:-1], axis=0)
        assert np.array_equal(got, want), (alg, p)
        # affine: composition order must survive fold/up/mid/down/unfold
        m = monoid_lib.get("affine")
        a = rng.standard_normal((p, 8))
        b = rng.standard_normal((p, 8))
        ga, gb = sim.execute(
            plan(ScanSpec(kind="exclusive", algorithm=alg,
                          monoid="affine"), p, nbytes=64).schedule(),
            (a, b), m)
        oa, ob = _affine_ref(a, b)
        assert np.allclose(ga, oa, rtol=1e-10), (alg, p)
        assert np.allclose(gb, ob, rtol=1e-10), (alg, p)


_BLOCK_NON_POW2 = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core import monoid as monoid_lib
from repro.core.scan_api import ScanSpec, scan, plan
from repro.core.schedule import SimulatorExecutor, collect_stats

sim = SimulatorExecutor()
rng = np.random.default_rng(1)
checked = 0
for p in (3, 5, 6, 7, 12):
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("x",))
    for alg in ("halving", "quartering", "reduce_scatter"):
        spec = ScanSpec(kind="exclusive", algorithm=alg, axis_name="x")
        x = rng.integers(0, 1 << 30, size=(p, 24)).astype(np.int64)
        with collect_stats() as st_spmd:
            f = jax.jit(shard_map(lambda v: scan(v, spec), mesh=mesh,
                                  in_specs=P("x"), out_specs=P("x")))
            got = np.asarray(f(x))
        pl = plan(spec, p=p, nbytes=x[0].nbytes)
        with collect_stats() as st_sim:
            ref = sim.execute(pl.schedule(), x, monoid_lib.ADD)
        assert np.array_equal(got, np.asarray(ref)), (alg, p)
        assert (st_spmd.rounds, st_spmd.op_applications) == (
            st_sim.rounds, st_sim.op_applications), (alg, p)
        assert st_spmd.bytes_per_round == st_sim.bytes_per_round, \\
            (alg, p)
        checked += 1
        if p in (6, 12):  # non-commutative at the rho-fold sizes
            aspec = ScanSpec(kind="exclusive", monoid="affine",
                             algorithm=alg, axis_name="x")
            a = rng.standard_normal((p, 8))
            b = rng.standard_normal((p, 8))
            f = jax.jit(shard_map(lambda A, B: scan((A, B), aspec),
                                  mesh=mesh, in_specs=(P("x"), P("x")),
                                  out_specs=(P("x"), P("x"))))
            ga, gb = f(a, b)
            m = monoid_lib.get("affine")
            ra, rb = sim.execute(
                plan(aspec, p=p, nbytes=a[0].nbytes).schedule(),
                (a, b), m)
            assert np.allclose(np.asarray(ga), ra, rtol=1e-12), (alg, p)
            assert np.allclose(np.asarray(gb), rb, rtol=1e-12), (alg, p)
            checked += 1
print("OK block non-pow2", checked)
"""


def test_block_builders_spmd_non_pow2_sweep():
    """SPMD == simulator at p in {3,5,6,7,12}: results, stats and
    per-round byte profile, for add (bit-exact) and affine."""
    out = run_with_devices(_BLOCK_NON_POW2, 12)
    assert "OK block non-pow2 21" in out  # 15 add cells + 6 affine
