"""The single-pass chunked scan engine (kernels/scan_engine, DESIGN §7).

Four layers of coverage, all in interpret mode (device-free):

  * engine unit tests — ``monoid_exscan`` across every elementwise
    monoid, the affine chunk scan/summary, ``block_combine`` edge
    shapes (widths ∤ 128, single row, bf16/int32) and the identity-
    valued padding;
  * the ONE-affine-definition regression: ``core.monoid.affine_combine``
    is the object every consumer imports, and the engine's affine
    instance is bit-identical to the XLA formulation built from it;
  * IR kernel accounting — ``Schedule.kernel_passes``/``kernel_launches``
    at the ISSUE acceptance point (ring p=64/S=8: fused halves the
    baseline's HBM passes at equal launches; fused-doubling scan_total:
    fused halves the launches);
  * the executor parity sweep (subprocess, 17 fake devices): the fused
    ``PallasExecutor`` is bit-identical to the SPMD executor AND the
    numpy simulator for p ∈ 2..17 across monoids, including the fused
    masked prep rounds of the segmented ring, the fused scan_reduce
    butterfly, and k-leaf mixed-dtype payloads batched per dtype group
    — with measured kernel stats equal to the IR prediction in both
    fused and baseline modes.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal container: property tests skip
    from helpers import fake_hypothesis

    given, settings, st = fake_hypothesis()

from helpers import run_with_devices

from repro.core import monoid as monoid_lib
from repro.core import schedule as schedule_lib
from repro.kernels import scan_engine


# ------------------- monoid_exscan: every elementwise monoid -------------


def _np_exscan(x, op, ident):
    out = np.empty_like(x)
    out[0] = ident
    for t in range(1, len(x)):
        out[t] = op(out[t - 1], x[t - 1])
    return out


@pytest.mark.parametrize("name,ident", [
    ("add", 0), ("max", np.iinfo(np.int32).min),
    ("min", np.iinfo(np.int32).max), ("xor", 0)])
def test_monoid_exscan_int_exact(name, ident):
    ops = {"add": np.add, "max": np.maximum, "min": np.minimum,
           "xor": np.bitwise_xor}
    rng = np.random.default_rng(hash(name) % 2**31)
    x = rng.integers(-1000, 1000, (512, 7)).astype(np.int32)
    got = scan_engine.monoid_exscan(jnp.asarray(x), name,
                                    block_rows=128, interpret=True)
    want = _np_exscan(x, ops[name], ident)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_monoid_exscan_mul_float():
    rng = np.random.default_rng(3)
    x = rng.uniform(0.9, 1.1, (256, 5)).astype(np.float32)
    got = scan_engine.monoid_exscan(jnp.asarray(x), "mul",
                                    block_rows=64, interpret=True)
    want = np.ones_like(x)
    want[1:] = np.cumprod(x[:-1], axis=0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_monoid_exscan_rejects_structured_monoid():
    with pytest.raises(ValueError, match="not elementwise"):
        scan_engine.monoid_exscan(jnp.zeros((4, 4)), "affine",
                                  block_rows=4, interpret=True)


def test_chunked_scan_chunking_invariance():
    """Multi-chunk carry propagation == one big chunk, bitwise."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(-50, 50, (256, 3)).astype(np.int64))
    one = scan_engine.monoid_exscan(x, "add", block_rows=256,
                                    interpret=True)
    many = scan_engine.monoid_exscan(x, "add", block_rows=32,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(many))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=300),
       d=st.integers(min_value=1, max_value=150),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_monoid_exscan_max_property(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-1000, 1000, (n, d)).astype(np.int32)
    got = scan_engine.monoid_exscan(jnp.asarray(x), "max",
                                    block_rows=n, interpret=True)
    want = _np_exscan(x, np.maximum, np.iinfo(np.int32).min)
    np.testing.assert_array_equal(np.asarray(got), want)


# --------------- ONE affine definition + bit-identity (satellite) --------


def test_affine_combine_single_definition():
    """Every consumer binds the ONE core affine combine — the dedup
    this PR enforces (kernels, mamba, rwkv, AFFINE monoid)."""
    from repro.kernels import ssm_chunk_scan  # noqa: F401  (delegate)
    from repro.models import mamba, rwkv

    f = monoid_lib.affine_combine
    assert scan_engine._affine_combine is f
    assert mamba._affine is f
    assert rwkv._affine is f
    assert monoid_lib._affine_op is f  # back-compat alias


def test_affine_engine_bit_identical_to_xla_formulation():
    """The engine's affine instance computes the SAME recurrence as
    the XLA chunked formulation built from the same ``affine_combine``.

    Bit-identity is asserted on integer affine elements (a ∈ {0, 1}),
    where every ⊕ is exact — float32 can differ by a few ulps between
    in-kernel and host XLA fusion, so the float check is a tight
    allclose, not the dedup regression itself."""
    from jax import lax

    rng = np.random.default_rng(5)
    T, D = 64, 128
    a = jnp.asarray(rng.integers(0, 2, (T, D)).astype(np.int32))
    b = jnp.asarray(rng.integers(-99, 99, (T, D)).astype(np.int32))
    h0 = jnp.asarray(rng.integers(-99, 99, (1, D)).astype(np.int32))
    h, hf = scan_engine.affine_chunk_scan(a, b, h0, chunk=16,
                                          interpret=True)
    want = []
    cur = np.asarray(h0)
    for t in range(T):
        cur = np.asarray(a[t]) * cur + np.asarray(b[t])
        want.append(cur[0])
    np.testing.assert_array_equal(np.asarray(h), np.stack(want))
    np.testing.assert_array_equal(np.asarray(hf), want[-1][None])

    af = jnp.asarray(rng.uniform(0.8, 1.0, (T, D)).astype(np.float32))
    bf = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    hf0 = jnp.asarray(rng.standard_normal((1, D)).astype(np.float32))
    got, _ = scan_engine.affine_chunk_scan(af, bf, hf0, chunk=T,
                                           interpret=True)
    incl = lax.associative_scan(monoid_lib.affine_combine, (af, bf),
                                axis=0)
    _, ref = monoid_lib.affine_combine((jnp.ones_like(hf0), hf0), incl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_affine_chunk_summary_single_pass_matches_two_pass():
    """(A_total, B_total) from the carry's a-leaf == the old prod+scan
    two-traversal result."""
    rng = np.random.default_rng(6)
    T, D = 128, 64
    a = rng.uniform(0.7, 1.0, (T, D)).astype(np.float32)
    b = rng.standard_normal((T, D)).astype(np.float32)
    at, bt = scan_engine.affine_chunk_summary(
        jnp.asarray(a), jnp.asarray(b), chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(at), np.prod(a, axis=0,
                                                       keepdims=True),
                               rtol=3e-4, atol=3e-4)
    h = np.zeros((1, D), np.float32)
    for t in range(T):
        h = a[t] * h + b[t]
    np.testing.assert_allclose(np.asarray(bt), h, rtol=3e-4, atol=3e-4)


# ----------- block_combine edge cases + identity padding (satellites) ----


@pytest.mark.parametrize("shape", [(1, 5), (3, 130), (7,), (2, 5, 9),
                                   (1, 1), (129,)])
@pytest.mark.parametrize("dtype", [np.int32, jnp.bfloat16])
def test_block_combine_edge_shapes(shape, dtype):
    """Widths ∤ 128, single-row and bf16/int32 payloads: the engine's
    tiling/padding never leaks into the truncated output."""
    rng = np.random.default_rng(int(np.prod(shape)))
    if dtype is np.int32:
        a = jnp.asarray(rng.integers(-99, 99, shape).astype(dtype))
        b = jnp.asarray(rng.integers(-99, 99, shape).astype(dtype))
    else:
        a = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
        b = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
    for op in (jnp.add, jnp.maximum, jnp.minimum):
        got = scan_engine.block_combine(a, b, op, interpret=True)
        assert got.dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(op(a, b)))


def test_block_combine_masked_edge_shapes():
    rng = np.random.default_rng(9)
    for shape in [(1, 5), (3, 130), (129,)]:
        a = jnp.asarray(rng.integers(-99, 99, shape).astype(np.int32))
        b = jnp.asarray(rng.integers(-99, 99, shape).astype(np.int32))
        for keep in (False, True):
            got = scan_engine.block_combine(
                a, b, jnp.maximum, keep=jnp.asarray(keep),
                interpret=True)
            want = np.maximum(a, b) if keep else np.asarray(b)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))


def test_leaf_identity_values():
    assert scan_engine.leaf_identity("add", np.int32) == 0
    assert scan_engine.leaf_identity("xor", np.int64) == 0
    assert scan_engine.leaf_identity("mul", np.float32) == 1
    assert scan_engine.leaf_identity("max", np.int32) == \
        np.iinfo(np.int32).min
    assert scan_engine.leaf_identity("min", np.int32) == \
        np.iinfo(np.int32).max
    assert scan_engine.leaf_identity("max", np.float32) == -np.inf
    assert scan_engine.leaf_identity("min", np.float32) == np.inf
    with pytest.raises(KeyError):
        scan_engine.leaf_identity("matmul", np.float32)


def test_pad_tile_uses_monoid_identity():
    """The pad lanes hold the monoid identity, not zeros — max/min/mul
    can never read garbage even if a caller stops truncating."""
    flat = jnp.asarray(np.arange(5, dtype=np.int32) - 100)
    for name, op in (("max", jnp.maximum), ("min", jnp.minimum)):
        pv = scan_engine._op_identity(op, np.int32)
        tiled, br = scan_engine._pad_tile(flat, pv, 256)
        assert tiled.shape == (1, scan_engine.LANE) and br == 1
        np.testing.assert_array_equal(np.asarray(tiled)[0, 5:],
                                      np.full(123, pv, np.int32))
    # unknown ops keep the legacy zero pad (hardening default)
    assert scan_engine._op_identity(lambda a, b: a, np.int32) == 0


def test_identity_padding_keeps_pad_lanes_inert():
    """identity ⊕ identity == identity through the whole kernel: the
    padded region of the OUTPUT tile is still the identity."""
    a = jnp.asarray(np.full(5, -7, np.int32))
    pv = scan_engine.leaf_identity("max", np.int32)
    out, = scan_engine._round_call(
        __import__("functools").partial(scan_engine._combine_kernel,
                                        jnp.maximum),
        [a, a], (pv, pv), 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.full(5, -7, np.int32))


# ------------------- tree-level batched round kernels --------------------


def _int_tree(rng, dtypes=(np.int64, np.int64, np.int32)):
    return {k: jnp.asarray(rng.integers(-999, 999, (n,)).astype(dt))
            for (k, n), dt in zip((("a", 16), ("b", 5), ("c", 7)),
                                  dtypes)}


def test_tree_combine_batches_dtype_groups():
    """Three leaves, two dtypes → per-leaf results identical to the
    plain op while the int64 pair shares one pallas_call."""
    rng = np.random.default_rng(21)
    lo, hi = _int_tree(rng), _int_tree(rng)
    m = monoid_lib.MAX
    got = scan_engine.tree_combine(m, lo, hi, interpret=True)
    for k in lo:
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.maximum(np.asarray(lo[k]),
                                           np.asarray(hi[k])))
    for keep in (0, 1):
        got = scan_engine.tree_combine(m, lo, hi,
                                       keep=jnp.asarray(keep),
                                       interpret=True)
        for k in lo:
            want = np.maximum(np.asarray(lo[k]), np.asarray(hi[k])) \
                if keep else np.asarray(hi[k])
            np.testing.assert_array_equal(np.asarray(got[k]), want)


def test_tree_exchange_and_scan_reduce_both_sides():
    rng = np.random.default_rng(22)
    m = monoid_lib.ADD
    recv, w, prefix = (_int_tree(rng) for _ in range(3))
    for low in (0, 1):
        got = scan_engine.tree_exchange(m, recv, w, jnp.asarray(low),
                                        interpret=True)
        for k in recv:
            np.testing.assert_array_equal(
                np.asarray(got[k]),
                np.asarray(recv[k]) + np.asarray(w[k]))
        w2, p2 = scan_engine.tree_scan_reduce(
            m, recv, w, prefix, jnp.asarray(low), interpret=True)
        for k in recv:
            np.testing.assert_array_equal(
                np.asarray(w2[k]),
                np.asarray(recv[k]) + np.asarray(w[k]))
            want_p = np.asarray(prefix[k]) + np.asarray(recv[k]) \
                if low else np.asarray(prefix[k])
            np.testing.assert_array_equal(np.asarray(p2[k]), want_p)


def test_tree_hooks_decline_unserved_payloads():
    """MATMUL and non-pair affine payloads return None — the executor
    falls back to the plain XLA op."""
    m = monoid_lib.MATMUL
    x = jnp.zeros((4, 4))
    assert scan_engine.tree_combine(m, x, x, interpret=True) is None
    aff = monoid_lib.AFFINE
    bad = (jnp.zeros((3,)), jnp.zeros((4,)))  # shape-mismatched pair
    assert scan_engine.tree_combine(aff, bad, bad,
                                    interpret=True) is None
    assert scan_engine.tree_exchange(aff, bad, bad, jnp.asarray(1),
                                     interpret=True) is None
    assert scan_engine.tree_scan_reduce(aff, bad, bad, bad,
                                        jnp.asarray(1),
                                        interpret=True) is None


def test_affine_tree_hooks_match_core_op():
    """Integer affine elements (a ∈ {0, 1}): every ⊕ exact, so the
    fused kernels must reproduce the core op bitwise."""
    rng = np.random.default_rng(23)

    def pair():
        return (jnp.asarray(rng.integers(0, 2, (37,))
                            .astype(np.int32)),
                jnp.asarray(rng.integers(-99, 99, (37,))
                            .astype(np.int32)))

    m = monoid_lib.AFFINE
    recv, w, prefix = pair(), pair(), pair()
    for low in (0, 1):
        got = scan_engine.tree_exchange(m, recv, w, jnp.asarray(low),
                                        interpret=True)
        want = m.op(recv, w) if low else m.op(w, recv)
        for g, wnt in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g),
                                          np.asarray(wnt))
        w2, p2 = scan_engine.tree_scan_reduce(
            m, recv, w, prefix, jnp.asarray(low), interpret=True)
        want_w = m.op(recv, w) if low else m.op(w, recv)
        want_p = m.op(recv, prefix) if low else prefix
        for g, wnt in zip((*w2, *p2), (*want_w, *want_p)):
            np.testing.assert_array_equal(np.asarray(g),
                                          np.asarray(wnt))


# ------------------- IR kernel accounting (acceptance point) -------------


def test_ring_p64_s8_fused_halves_hbm_passes():
    """The ISSUE acceptance gate, off the IR alone: p=64/S=8 ring —
    69 launches either way (the rolled round table launches once per
    round), but fused does each prep in ONE sweep where baseline pays
    a combine launch plus a select sweep: 138 → 69 passes, exactly
    2×."""
    sched = schedule_lib.build_ring(64, 8)
    assert sched.kernel_launches(True, fused=True) == 69
    assert sched.kernel_launches(True, fused=False) == 69
    fused = sched.kernel_passes(True, fused=True)
    base = sched.kernel_passes(True, fused=False)
    assert (fused, base) == (69, 138)
    assert base >= 2 * fused


def test_scan_total_p64_fused_halves_launches():
    """fused-doubling at p=64: 6 scan_reduce rounds; fused batches the
    (P, T) register pair into ONE pallas_call per round (6L/6P) where
    the commutative baseline pays two launches (12L/12P) and the
    non-commutative one 3 launches + 2 select sweeps (18L/30P)."""
    sched = schedule_lib.build_scan_total(64)
    assert (sched.kernel_launches(True, fused=True),
            sched.kernel_passes(True, fused=True)) == (6, 6)
    assert (sched.kernel_launches(True, fused=False),
            sched.kernel_passes(True, fused=False)) == (12, 12)
    assert (sched.kernel_launches(False, fused=True),
            sched.kernel_passes(False, fused=True)) == (6, 6)
    assert (sched.kernel_launches(False, fused=False),
            sched.kernel_passes(False, fused=False)) == (18, 30)


def test_plan_carries_kernel_passes():
    from repro.core.scan_api import ScanSpec, plan

    pl = plan(ScanSpec(kind="exclusive", algorithm="ring", segments=8),
              p=64, nbytes=2048)
    assert pl.kernel_passes == \
        pl.schedule().kernel_passes(monoid_lib.ADD.commutative)
    rows = pl.explain()
    assert all("kernel_passes" in r for r in rows)
    chosen = [r for r in rows if r["chosen"]]
    assert chosen and chosen[0]["kernel_passes"] == pl.kernel_passes


def test_gamma_pass_pricing_opt_in():
    """gamma_pass=0 (the default) prices passes at zero — bit-identical
    costs to the historical model; nonzero gamma_pass separates fused
    from baseline pass budgets that op counts cannot distinguish."""
    from repro.core.scan_api import CostModel

    kw = dict(hops=10, serial_bytes=1e4, ops=20, payload_bytes=256)
    base = CostModel()
    assert base.cost(**kw) == base.cost(**kw, passes=69)
    priced = CostModel(gamma_pass=1e-9)
    assert priced.cost(**kw, passes=138) - priced.cost(**kw, passes=69) \
        == pytest.approx(1e-9 * 69 * 256)


def test_schedule_features_optional_pass_regressor():
    from repro.core import tune

    sched = schedule_lib.build_ring(64, 8)
    three = tune.schedule_features(sched, 2048, commutative=True)
    assert len(three) == 3
    four = tune.schedule_features(sched, 2048, commutative=True,
                                  passes=True)
    assert four[:3] == three
    assert four[3] == sched.kernel_passes(True) * (2048 // 8)


# ------------- executor parity sweep: p ∈ 2..17, all executors -----------


_SWEEP = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core import monoid as monoid_lib
from repro.core.scan_api import ScanSpec, plan
from repro.core.schedule import (PallasExecutor, SPMDExecutor,
                                 SimulatorExecutor, collect_stats)

devices = jax.devices()
sim = SimulatorExecutor()
checked = 0


def run(p, spec, payload, m, in_specs, out_specs, exact, atol=0.0):
    global checked
    mesh = Mesh(np.array(devices[:p]).reshape(p), ("x",))
    pl = plan(spec, p=p, nbytes=sum(
        np.asarray(v).nbytes for v in jax.tree.leaves(payload)) // p)
    sched = pl.schedule()
    ref_spmd = jax.jit(shard_map(
        lambda v: SPMDExecutor("x").execute(sched, v, m), mesh=mesh,
        in_specs=in_specs, out_specs=out_specs))(payload)
    ref_sim = sim.execute(sched, payload, m)
    outs = {}
    for fused in (True, False):
        ex = PallasExecutor("x", interpret=True, fused=fused)
        fn = jax.jit(shard_map(
            lambda v: ex.execute(sched, v, m), mesh=mesh,
            in_specs=in_specs, out_specs=out_specs, check_vma=False))
        with collect_stats() as st:
            jax.make_jaxpr(fn)(payload)
        assert st.kernel_launches == sched.kernel_launches(
            m.commutative, fused=fused), (spec, fused, "launches")
        assert st.hbm_passes == sched.kernel_passes(
            m.commutative, fused=fused), (spec, fused, "passes")
        if fused:
            assert st.hbm_passes == pl.kernel_passes, (spec, "plan")
        outs[fused] = fn(payload)
    for ref in (ref_spmd, ref_sim):
        for fused in (True, False):
            for g, w in zip(jax.tree.leaves(outs[fused]),
                            jax.tree.leaves(ref)):
                g, w = np.asarray(g), np.asarray(w)
                if exact:
                    assert np.array_equal(g, w), (spec, fused)
                else:
                    np.testing.assert_allclose(g, w, rtol=1e-12,
                                               atol=atol)
    checked += 1


rng = np.random.default_rng(0)
ADD, MAX, AFF = monoid_lib.ADD, monoid_lib.MAX, monoid_lib.AFFINE
for p in range(2, 18):
    x = rng.integers(-(1 << 40), 1 << 40, (p, 16)).astype(np.int64)
    for alg in ("123", "ring"):
        spec = ScanSpec(kind="exclusive", algorithm=alg, axis_name="x")
        run(p, spec, x, ADD, P("x"), P("x"), exact=True)
    run(p, ScanSpec(kind="exclusive", algorithm="123", monoid="max",
                    axis_name="x"), x, MAX, P("x"), P("x"), exact=True)
    a = rng.uniform(0.5, 1.5, (p, 8))
    b = rng.standard_normal((p, 8))
    run(p, ScanSpec(kind="exclusive", algorithm="native",
                    monoid="affine", axis_name="x"), (a, b), AFF,
        P("x"), P("x"), exact=False, atol=1e-12)

# fused scan_reduce butterfly (exscan+allreduce registers) at 2-powers,
# including the non-commutative affine side-select path
for p in (4, 8, 16):
    x = rng.integers(-(1 << 40), 1 << 40, (p, 16)).astype(np.int64)
    run(p, ScanSpec(kind="scan_total", algorithm="fused_doubling",
                    axis_name="x"), x, ADD, P("x"), P("x"),
        exact=True)
    a = rng.uniform(0.5, 1.5, (p, 8))
    b = rng.standard_normal((p, 8))
    run(p, ScanSpec(kind="scan_total", algorithm="fused_doubling",
                    monoid="affine", axis_name="x"), (a, b), AFF,
        P("x"), P("x"), exact=False, atol=1e-12)

# k-slot batching: mixed-dtype payload tree, masked ring preps included
tree = {"a": rng.integers(-99, 99, (8, 16)).astype(np.int64),
        "b": rng.integers(-99, 99, (8, 5)).astype(np.int64),
        "c": rng.integers(-99, 99, (8, 7)).astype(np.int32)}
for alg, S in (("123", None), ("ring", 4)):
    spec = ScanSpec(kind="exclusive", algorithm=alg, segments=S,
                    axis_name="x")
    run(8, spec, tree, ADD, P("x"), P("x"), exact=True)

print("OK engine sweep", checked)
"""


def test_engine_parity_sweep_p2_to_17():
    """Fused PallasExecutor == SPMD == simulator for p ∈ 2..17 across
    monoids (bitwise for int64; affine ≤1e-12), with measured kernel
    launch/pass counts equal to the IR prediction in BOTH modes."""
    out = run_with_devices(_SWEEP, 17)
    # 16 p-values x 4 specs + 3 scan_total p's x 2 + 2 tree cases
    assert "OK engine sweep 72" in out
