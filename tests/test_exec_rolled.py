"""Compiled round-table executor laws (ISSUE-5).

The acceptance properties of the rolled execution engine:

  * rolled vs unrolled bit-identity — the segmented ring executed
    through the single-``lax.scan`` round table is bit-identical to
    the legacy one-trace-site-per-round execution at p ∈ 2..17 ×
    S ∈ {1, 2, 4, 8} (SPMD, subprocess on 17 fake devices) for int64
    add, and ulp-tight for the non-commutative float affine monoid
    (XLA fuses its multiply-add differently inside a ``lax.scan``
    body); both match the numpy simulator;
  * every other registered algorithm traces the IDENTICAL jaxpr in
    both modes (their rounds have varying peer offsets, so they never
    roll — jaxpr equality implies bit-identical outputs without
    compiling 100s of programs);
  * the rolled ring's trace size is O(1) in p and S, and the
    commutative-monoid ⊕ elision shrinks butterfly/scan_reduce traces;
  * ``collectives.expected_rounds``/``expected_ops`` are derived from
    the schedule builders and can never drift from the closed-form
    oracle counts.
"""

import numpy as np

from helpers import run_with_devices

from repro.core import collectives as collectives_lib
from repro.core import oracle


_ROLLED_VS_UNROLLED = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core import monoid as monoid_lib
from repro.core.scan_api import ScanSpec, plan, algorithms
from repro.core.schedule import (
    SPMDExecutor, SimulatorExecutor, build_ring, collect_stats)

devs = np.array(jax.devices())
sim = SimulatorExecutor()

def run(mesh, sched, x, m, unrolled, n_in=1):
    ex = SPMDExecutor("x", unrolled=unrolled)
    specs = jax.tree.map(lambda _: P("x"), x)
    f = jax.jit(shard_map(lambda v: ex.execute(sched, v, m),
                          mesh=mesh, in_specs=(specs,),
                          out_specs=specs))
    with collect_stats() as st:
        out = jax.tree.map(np.asarray, f(x))
    return out, st

# 1) the ring: rolled (lax.scan round table, double-buffered) vs
#    unrolled (legacy per-round trace) bit-identity, int64 add,
#    p in 2..17 x S in {1,2,4,8}; 6 elements/rank so S=4 and S=8 pad
checked = 0
for p in range(2, 18):
    mesh = Mesh(devs[:p].reshape(p), ("x",))
    rng = np.random.default_rng(p)
    x = rng.integers(0, 1 << 30, size=(p, 6)).astype(np.int64)
    for S in (1, 2, 4, 8):
        sched = build_ring(p, S)
        rolled, st_r = run(mesh, sched, x, monoid_lib.ADD, False)
        unrolled, st_u = run(mesh, sched, x, monoid_lib.ADD, True)
        assert np.array_equal(rolled, unrolled), (p, S)
        assert (st_r.rounds, st_r.op_applications) == \
            (st_u.rounds, st_u.op_applications) == \
            (sched.rounds, sched.op_applications), (p, S, st_r, st_u)
        assert st_r.bytes_per_round == st_u.bytes_per_round, (p, S)
        ref = sim.execute(sched, x, monoid_lib.ADD)
        assert np.array_equal(rolled, np.asarray(ref)), (p, S)
        checked += 1
print("OK ring rolled==unrolled", checked)

# 2) non-commutative float payloads through the rolled ring: affine
#    (a, b) tuple trees.  The int sweep above is bitwise; floats get
#    a tight allclose — XLA may fuse the affine a_hi*b_lo + b_hi into
#    an FMA differently inside the lax.scan body than in straightline
#    code (same ⊕ order, ulp-level rounding difference only).
for p, S in ((2, 4), (7, 2), (12, 8), (17, 4)):
    mesh = Mesh(devs[:p].reshape(p), ("x",))
    rng = np.random.default_rng(100 + p)
    a = rng.standard_normal((p, 10))
    b = rng.standard_normal((p, 10))
    sched = build_ring(p, S)
    rolled, _ = run(mesh, sched, (a, b), monoid_lib.AFFINE, False)
    unrolled, _ = run(mesh, sched, (a, b), monoid_lib.AFFINE, True)
    for lr, lu in zip(jax.tree.leaves(rolled), jax.tree.leaves(unrolled)):
        np.testing.assert_allclose(lr, lu, rtol=1e-13,
                                   err_msg=str((p, S)))
    ga, gb = sim.execute(sched, (a, b), monoid_lib.AFFINE)
    np.testing.assert_allclose(rolled[0], ga, rtol=1e-12)
    np.testing.assert_allclose(rolled[1], gb, rtol=1e-12)
print("OK ring rolled==unrolled affine")

# 3) every other registered algorithm: rounds have varying peer
#    offsets, so both modes must trace the IDENTICAL jaxpr (which
#    implies bit-identical outputs) — p in 2..17, no compilation
same = 0
for p in range(2, 18):
    mesh = Mesh(devs[:p].reshape(p), ("x",))
    x = np.arange(p * 4, dtype=np.int64).reshape(p, 4)
    for kind in ("exclusive", "inclusive", "allreduce", "scan_total"):
        for alg in algorithms(kind):
            sched = plan(ScanSpec(kind=kind, algorithm=alg), p=p,
                         nbytes=32).schedule()
            if any(st.kind == "seg_shift" for st in sched.steps):
                continue  # the ring: modes differ; covered above
            outs = (P("x"),) * len(sched.outputs) \
                if len(sched.outputs) > 1 else P("x")
            jaxprs = []
            for unrolled in (False, True):
                ex = SPMDExecutor("x", unrolled=unrolled)
                f = shard_map(
                    lambda v: ex.execute(sched, v, monoid_lib.ADD),
                    mesh=mesh, in_specs=P("x"), out_specs=outs)
                jaxprs.append(str(jax.make_jaxpr(f)(x)))
            assert jaxprs[0] == jaxprs[1], (kind, alg, p)
            same += 1
print("OK identical traces", same)

# 4) scan_total ring (with_total over seg_shift steps): execute both
#    modes at a couple of p to close the registered-algorithm sweep
for p in (5, 8):
    mesh = Mesh(devs[:p].reshape(p), ("x",))
    x = np.arange(p * 8, dtype=np.int64).reshape(p, 8)
    sched = plan(ScanSpec(kind="scan_total", algorithm="ring",
                          segments=4), p=p, nbytes=64).schedule()
    outs = {}
    for unrolled in (False, True):
        ex = SPMDExecutor("x", unrolled=unrolled)
        f = jax.jit(shard_map(
            lambda v: ex.execute(sched, v, monoid_lib.ADD),
            mesh=mesh, in_specs=P("x"),
            out_specs=(P("x"), P("x"))))
        outs[unrolled] = jax.tree.map(np.asarray, f(x))
    for lr, lu in zip(jax.tree.leaves(outs[False]),
                      jax.tree.leaves(outs[True])):
        assert np.array_equal(lr, lu), p
print("OK scan_total ring rolled==unrolled")
"""


def test_rolled_executors_bit_identical_to_unrolled():
    out = run_with_devices(_ROLLED_VS_UNROLLED, 17, timeout=1200)
    assert "OK ring rolled==unrolled 64" in out  # 16 p x 4 S
    assert "OK ring rolled==unrolled affine" in out
    assert "OK identical traces" in out
    assert "OK scan_total ring rolled==unrolled" in out


_TRACE_SIZE = """
import jax, numpy as np
from repro.core import monoid as monoid_lib
from repro.core.schedule import (
    build_butterfly, build_ring, build_scan_total, trace_eqn_count)

# the rolled ring's trace is O(1) in p and S: identical equation
# counts across every (p, S); the unrolled trace grows with p+S
eqs = {}
for p, S in ((5, 2), (9, 4), (17, 8)):
    x = np.arange(p * 16, dtype=np.int64).reshape(p, 16)
    sched = build_ring(p, S)
    eqs[(p, S)] = trace_eqn_count(sched, monoid_lib.ADD, x)
    un = trace_eqn_count(sched, monoid_lib.ADD, x, unrolled=True)
    assert un > (p - 2 + S) * 4, (p, S, un)  # per-round trace sites
vals = set(eqs.values())
assert len(vals) == 1, eqs  # O(1): independent of p and S
# rolled beats unrolled by the acceptance floor already at p=17
p, S = 17, 8
x = np.arange(p * 16, dtype=np.int64).reshape(p, 16)
sched = build_ring(p, S)
rolled = trace_eqn_count(sched, monoid_lib.ADD, x)
unrolled = trace_eqn_count(sched, monoid_lib.ADD, x, unrolled=True)
assert unrolled >= 5 * rolled, (rolled, unrolled)

# commutative ⊕ elision shrinks butterfly and scan_reduce traces
p = 16
x = np.arange(p * 4, dtype=np.int64).reshape(p, 4)
bf = build_butterfly(p)
assert trace_eqn_count(bf, monoid_lib.ADD, x) < \\
    trace_eqn_count(bf, monoid_lib.AFFINE, (x, x))
print("OK trace sizes", rolled, unrolled)
"""


def test_rolled_ring_trace_is_o1_in_p_and_s():
    out = run_with_devices(_TRACE_SIZE, 17)
    assert "OK trace sizes" in out


# ---------------------------------------------------------------------------
# expected_rounds / expected_ops: derived from the schedule builders,
# drift-tested against the closed-form oracle counts (no devices).
# ---------------------------------------------------------------------------


def test_expected_rounds_cannot_drift_from_oracle():
    ex = collectives_lib
    for p in range(1, 65):
        assert ex.expected_rounds("123", p) == oracle.q_123(p)
        assert ex.expected_rounds("1doubling", p) == \
            oracle.rounds_1doubling(p)
        assert ex.expected_rounds("two_op", p) == oracle.rounds_two_op(p)
        assert ex.expected_rounds("ring", p) == max(0, p - 1)
        assert ex.expected_rounds("native", p) == 1  # legacy convention
        for S in (4, 16):
            assert ex.expected_rounds("ring", p, segments=S) == \
                (0 if p <= 1 else p - 2 + S)
        assert ex.expected_rounds("hillis_steele", p,
                                  kind="inclusive") == \
            oracle.rounds_two_op(p)
        # butterfly: ⌈log₂p⌉ exchanges at power-of-two p, else the
        # inclusive scan (+ a broadcast, which is not a ppermute round)
        assert ex.expected_rounds("butterfly", p, kind="allreduce") == \
            oracle.rounds_two_op(p)
        # block-distributed mid-m builders (Träff 2026 + reduce-scatter):
        # schedule-derived rounds vs the closed forms, any p (the range
        # above includes every non-power-of-two up to 64)
        assert ex.expected_rounds("halving", p) == \
            oracle.rounds_halving(p)
        assert ex.expected_rounds("quartering", p) == \
            oracle.rounds_quartering(p)
        assert ex.expected_rounds("reduce_scatter", p) == \
            oracle.rounds_reduce_scatter(p)
        # the textbook depth law: vector halving/doubling exscan takes
        # 2·⌈log₂p⌉ rounds at powers of two
        if p > 1 and p & (p - 1) == 0:
            assert oracle.rounds_reduce_scatter(p) == \
                2 * (p.bit_length() - 1)


def test_expected_ops_reflects_commutative_elision():
    ex = collectives_lib
    for k in range(1, 7):
        p = 1 << k
        # butterfly: 2 ⊕ per exchange round, 1 when commutative
        assert ex.expected_ops("butterfly", p, kind="allreduce") == 2 * k
        assert ex.expected_ops("butterfly", p, kind="allreduce",
                               commutative=True) == k
        # fused scan_total butterfly: 3 ⊕ per round, 2 when commutative
        assert ex.expected_ops("fused_doubling", p,
                               kind="scan_total") == 3 * k
        assert ex.expected_ops("fused_doubling", p, kind="scan_total",
                               commutative=True) == 2 * k
    # shift-based algorithms have no redundant combine order to elide
    for p in (5, 9, 36):
        for alg in ("123", "1doubling", "two_op", "ring"):
            assert ex.expected_ops(alg, p) == \
                ex.expected_ops(alg, p, commutative=True)


def test_roofline_parse_is_loop_and_branch_aware():
    """The HLO collective parse multiplies while-body collectives by
    the loop's known trip count (the rolled ring's single permute
    trace site = p−2+S dynamic rounds) and still counts collectives
    inside non-while sub-computations (conditional branches)."""
    from repro.launch.roofline import parse_collectives

    hlo = """\
HloModule m, entry_computation_layout={()->f32[8]}

%branch_true (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(f32[8]{0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add_comp
}

%branch_false (p1: f32[8]) -> f32[8] {
  ROOT %p1 = f32[8]{0} parameter(0)
}

%loop_body (t: (s32[], f32[8])) -> (s32[], f32[8]) {
  %t = (s32[], f32[8]) parameter(0)
  %gte = f32[8]{0} get-tuple-element((s32[], f32[8]) %t), index=1
  %cp = f32[8]{0} collective-permute(f32[8]{0} %gte), source_target_pairs={{0,1},{1,2}}
  ROOT %tup = (s32[], f32[8]) tuple(s32[] %c, f32[8]{0} %cp)
}

%loop_cond (t2: (s32[], f32[8])) -> pred[] {
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

ENTRY %main () -> f32[8] {
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%loop_cond, body=%loop_body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %cond = f32[8]{0} conditional(pred[] %p, f32[8]{0} %a, f32[8]{0} %b), true_computation=%branch_true, false_computation=%branch_false
}
"""
    stats = parse_collectives(hlo)
    # one permute trace site x 7 trips, one branch all-reduce
    assert stats.op_counts["collective-permute"] == 7
    assert stats.op_counts["all-reduce"] == 1
    assert stats.op_bytes["collective-permute"] == 7 * 32.0


def test_expected_ops_matches_plan_predictions():
    from repro.core.scan_api import ScanSpec, plan

    for p in (4, 8, 16):
        for mono, comm in (("add", True), ("affine", False)):
            pl = plan(ScanSpec(kind="allreduce", algorithm="butterfly",
                               monoid=mono), p=p, nbytes=64)
            assert pl.op_applications == collectives_lib.expected_ops(
                "butterfly", p, kind="allreduce", commutative=comm)
