"""Data pipeline, optimizer, checkpoint/restart, fault-tolerance tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_lr


# ------------------------------ data ------------------------------


def test_data_deterministic_and_resumable():
    dc = DataConfig(vocab=1000, seq_len=64, global_batch=4, seed=7)
    a = SyntheticLM(dc).batch(5)
    b = SyntheticLM(dc).batch(5)  # fresh instance, same step -> identical
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(dc).batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_packing_offsets():
    dc = DataConfig(vocab=1000, seq_len=64, global_batch=2, seed=0)
    pipe = SyntheticLM(dc)
    docs = pipe.docs_for_step(0)
    packed = pipe.pack(docs)
    flat = packed["tokens"].reshape(-1)
    pos = packed["positions"].reshape(-1)
    seg = packed["segments"].reshape(-1)
    # exscan property: each doc starts at the exclusive prefix of lengths
    lengths = [len(d) for d in docs]
    offset = 0
    for i, d in enumerate(docs):
        if offset >= flat.size:
            break
        n = min(len(d), flat.size - offset)
        np.testing.assert_array_equal(flat[offset : offset + n], d[:n])
        np.testing.assert_array_equal(pos[offset : offset + n],
                                      np.arange(n))
        assert (seg[offset : offset + n] == i + 1).all()
        offset += lengths[i]


def test_data_hosts_split_batch():
    dc = DataConfig(vocab=100, seq_len=32, global_batch=8)
    h0 = SyntheticLM(dc, host_id=0, n_hosts=2)
    h1 = SyntheticLM(dc, host_id=1, n_hosts=2)
    b0, b1 = h0.batch(0), h1.batch(0)
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ------------------------------ optim ------------------------------


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=5e-2,
                                   weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_cosine_lr_schedule():
    lr0 = float(cosine_lr(jnp.int32(0), peak=1.0, warmup=10, total=100))
    lr_w = float(cosine_lr(jnp.int32(10), peak=1.0, warmup=10, total=100))
    lr_end = float(cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100))
    assert lr0 < 0.11
    assert abs(lr_w - 1.0) < 1e-5
    assert abs(lr_end - 0.1) < 1e-5  # floor = 10% of peak


# ------------------------------ checkpoint ------------------------------


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.arange(7), "c": jnp.asarray(2.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree(0)
    store.save(10, t)
    assert store.latest_step() == 10
    got = store.restore(10, jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_checkpoint_latest_ignores_uncommitted(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(10, _tree(0))
    # fake a crashed save at step 20: directory without COMMITTED
    os.makedirs(tmp_path / "step_00000020")
    assert store.latest_step() == 10


def test_checkpoint_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree(1)
    store.save(5, t, blocking=False)
    store.wait()
    assert store.latest_step() == 5


def test_checkpoint_restart_bitexact_training(tmp_path):
    """Train 4 steps; checkpoint at 2; restart from 2 and verify the
    final params match the uninterrupted run exactly."""
    from repro import configs
    from repro.launch.steps import make_train_step
    from repro.models.model import Model
    from jax.sharding import Mesh

    cfg = configs.get_smoke("granite_3_2b")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    model = Model(cfg, mesh)
    step_fn = jax.jit(make_train_step(cfg, mesh))
    rng = np.random.default_rng(0)
    batches = [{
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                              jnp.int32),
    } for _ in range(4)]

    with jax.set_mesh(mesh):
        params = model.init_params(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        store = CheckpointStore(str(tmp_path))
        for i, b in enumerate(batches):
            if i == 2:
                store.save(2, {"params": params, "opt": opt})
            params, opt, _ = step_fn(params, opt, b, jnp.int32(i))
        final_a = jax.tree.leaves(params)

        state = store.restore(2, {"params": model.init_params(
            jax.random.PRNGKey(1)), "opt": opt})
        p2, o2 = state["params"], state["opt"]
        for i in (2, 3):
            p2, o2, _ = step_fn(p2, o2, batches[i], jnp.int32(i))
        final_b = jax.tree.leaves(p2)
    for a, b in zip(final_a, final_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog():
    from repro.launch.train import StragglerWatchdog

    w = StragglerWatchdog(alpha=0.5, k=2.0)
    assert not w.observe(0, 1.0)
    assert not w.observe(1, 1.1)
    assert w.observe(2, 10.0)  # 10x slower than EWMA -> flagged
    assert w.flagged == [2]


def test_checkpoint_elastic_hosts(tmp_path):
    """Save with 2 hosts, restore with 1 (and vice versa): the manifest
    records leaf->shard placement, so any host count can restore."""
    t = _tree(3)
    # two "hosts" write their leaf subsets
    s0 = CheckpointStore(str(tmp_path), host_id=0, n_hosts=2)
    s1 = CheckpointStore(str(tmp_path), host_id=1, n_hosts=2)
    s1.save(7, t)   # host 1 writes its shard
    s0.save(7, t)   # host 0 writes manifest + its shard
    s0.commit(7)    # after the cross-host barrier
    single = CheckpointStore(str(tmp_path))  # 1-host restart
    got = single.restore(7, jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)
