"""SPMD (ppermute/shard_map) exscan validated on 8 fake CPU devices.

Runs in subprocesses so the main pytest process keeps a single device.
Checks: numerical equality with a sequential fold for commutative and
non-commutative monoids, round counts equal to the theory/oracle, and
multi-axis (pod,data) composition.
"""

import pytest

from helpers import run_with_devices

_VALIDATE = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex

p = {p}
mesh = Mesh(np.array(jax.devices())[:p].reshape(p), ("x",))
rng = np.random.default_rng({seed})
x = rng.integers(0, 1 << 30, size=(p, {m})).astype(np.int64)

def ref_exscan(x):
    out = np.zeros_like(x)
    out[1:] = np.cumsum(x[:-1], axis=0)
    return out

alg = "{alg}"
with ex.collect_stats() as st:
    f = shard_map(lambda v: ex.exscan(v, "x", "add", alg), mesh=mesh,
                  in_specs=P("x"), out_specs=P("x"))
    got = jax.jit(f)(x)
np.testing.assert_array_equal(np.asarray(got), ref_exscan(x))
if alg not in ("native",):
    assert st.rounds == ex.expected_rounds(alg, p), (st.rounds,)
print("OK", alg, p, st.rounds, st.op_applications)
"""


@pytest.mark.parametrize("alg", ["123", "1doubling", "two_op", "native", "ring"])
@pytest.mark.parametrize("p", [2, 3, 5, 8])
def test_spmd_exscan_add(alg, p):
    out = run_with_devices(_VALIDATE.format(p=p, m=16, seed=0, alg=alg), 8)
    assert "OK" in out


_NONCOMM = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex

p = 8
mesh = Mesh(np.array(jax.devices()).reshape(p), ("x",))
rng = np.random.default_rng(1)

# affine (diagonal SSM state composition): non-commutative
a = rng.standard_normal((p, 8)); b = rng.standard_normal((p, 8))
def ref_affine(a, b):
    oa = np.ones_like(a); ob = np.zeros_like(b)
    ca, cb = np.ones(8), np.zeros(8)
    for r in range(p):
        oa[r], ob[r] = ca, cb
        ca, cb = a[r] * ca, a[r] * cb + b[r]
    return oa, ob
for alg in ("123", "1doubling", "two_op", "native"):
    f = shard_map(lambda A, B: ex.exscan((A, B), "x", "affine", alg),
                  mesh=mesh, in_specs=(P("x"), P("x")),
                  out_specs=(P("x"), P("x")))
    ga, gb = jax.jit(f)(a, b)
    ea, eb = ref_affine(a, b)
    np.testing.assert_allclose(np.asarray(ga), ea, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(gb), eb, rtol=1e-12)

# full matrix-product monoid
mats = rng.standard_normal((p, 4, 4)) * 0.5
f = shard_map(lambda v: ex.exscan(v, "x", "matmul", "123"), mesh=mesh,
              in_specs=P("x"), out_specs=P("x"))
got = np.asarray(jax.jit(f)(mats))
acc = np.eye(4)
for r in range(p):
    np.testing.assert_allclose(got[r], acc, rtol=1e-10, atol=1e-12)
    acc = mats[r] @ acc

# xor — the paper's experimental operator (MPI_BXOR over MPI_LONG)
xi = rng.integers(0, 1 << 62, size=(p, 32)).astype(np.uint64)
out = np.zeros_like(xi); accx = np.zeros(32, np.uint64)
for r in range(p):
    out[r] = accx; accx = accx ^ xi[r]
f = shard_map(lambda v: ex.exscan(v, "x", "xor", "123"), mesh=mesh,
              in_specs=P("x"), out_specs=P("x"))
np.testing.assert_array_equal(np.asarray(jax.jit(f)(xi)), out)
print("OK noncommutative")
"""


def test_spmd_noncommutative_monoids():
    out = run_with_devices(_NONCOMM, 8)
    assert "OK" in out


_MULTIAXIS = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex

rng = np.random.default_rng(2)
x = rng.integers(0, 1 << 30, size=(8, 16)).astype(np.int64)
ref = np.zeros_like(x); ref[1:] = np.cumsum(x[:-1], axis=0)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
for alg in ("123", "1doubling", "two_op"):
    f = shard_map(lambda v: ex.exscan(v, ("pod", "data"), "add", alg),
                  mesh=mesh, in_specs=P(("pod", "data")),
                  out_specs=P(("pod", "data")))
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)), ref)
print("OK multiaxis")
"""


def test_spmd_multiaxis():
    out = run_with_devices(_MULTIAXIS, 8)
    assert "OK" in out


_INCL_ALLRED = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
import repro.core.collectives as ex

rng = np.random.default_rng(3)
for p in (2, 3, 5, 7, 8):
    mesh = Mesh(np.array(jax.devices())[:p].reshape(p), ("x",))
    x = rng.integers(0, 1 << 30, size=(p, 8)).astype(np.int64)
    f = shard_map(lambda v: ex.inclusive_scan(v, "x", "add"), mesh=mesh,
                  in_specs=P("x"), out_specs=P("x"))
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)), np.cumsum(x, axis=0))
    f = shard_map(lambda v: ex.allreduce(v, "x", "add"), mesh=mesh,
                  in_specs=P("x"), out_specs=P("x"))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(f)(x)),
        np.broadcast_to(x.sum(0, keepdims=True), x.shape))
    # non-commutative allreduce (matmul) must fold in rank order
    mats = rng.standard_normal((p, 3, 3)) * 0.5
    f = shard_map(lambda v: ex.allreduce(v, "x", "matmul"), mesh=mesh,
                  in_specs=P("x"), out_specs=P("x"))
    got = np.asarray(jax.jit(f)(mats))
    acc = np.eye(3)
    for r in range(p):
        acc = mats[r] @ acc
    for r in range(p):
        np.testing.assert_allclose(got[r], acc, rtol=1e-10, atol=1e-12)
print("OK inclusive/allreduce")
"""


def test_spmd_inclusive_and_allreduce():
    out = run_with_devices(_INCL_ALLRED, 8)
    assert "OK" in out
