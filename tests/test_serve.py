"""Scan-service tests (serving subsystem, DESIGN §8).

Covers the serving acceptance criteria: admission control (bad
payloads, undeclared buckets, queue-depth backpressure), bucketing by
(kind, monoid, shape, dtype), continuous batching into fused schedules
with correct per-request results (including multi-output scan_total
requests), the warmup contract (zero plan-cache misses in steady
state), admission-to-start deadline semantics, the metrics surface,
the workload generators wired to the real consumers, and a serve-bench
burst smoke through the same ``check()`` gate CI runs.
"""

import numpy as np
import pytest

from repro.core.scan_api import plan_cache_clear, plan_cache_info
from repro.serve import (
    AdmissionError, Bucket, ScanService, bucket_key, bucket_of,
    percentile, workloads)


def _exclusive_ref(x):
    ref = np.zeros_like(x)
    ref[1:] = np.cumsum(x[:-1], axis=0)
    return ref


def _scalar_buckets():
    return [Bucket(kind="exclusive", monoid="add", shape=(),
                   dtype=np.int32, name="scalars")]


# ---------------------------------------------------------------------------
# Buckets
# ---------------------------------------------------------------------------


def test_bucket_key_and_normalization():
    b = Bucket(kind="exclusive", monoid="add", shape=[4],
               dtype="int32", name="n")
    assert b.shape == (4,) and b.key == bucket_key(
        "exclusive", "add", (4,), np.int32)
    assert b.nbytes == 16
    spec = b.spec("x")
    assert spec.kind == "exclusive" and spec.axis_name == "x"
    x = np.zeros((8, 4), np.int32)
    assert bucket_of(x, kind="exclusive", monoid="add").key == b.key
    b.validate(x, 8)
    with pytest.raises(ValueError):
        b.validate(np.zeros((8, 5), np.int32), 8)  # wrong shape
    with pytest.raises(ValueError):
        b.validate(np.zeros((7, 4), np.int32), 8)  # wrong p
    with pytest.raises(ValueError):
        b.validate(x.astype(np.int64), 8)  # wrong dtype


def test_duplicate_buckets_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        ScanService(4, _scalar_buckets() + _scalar_buckets())


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_rejects_bad_payload_and_unknown_bucket():
    svc = ScanService(8, _scalar_buckets())
    with pytest.raises(AdmissionError) as e:
        svc.submit(np.zeros((4,), np.int32))  # wrong rank axis
    assert e.value.reason == "bad_payload"
    with pytest.raises(AdmissionError) as e:
        svc.submit(np.zeros((8, 3), np.int32))  # undeclared shape
    assert e.value.reason == "unknown_bucket"
    with pytest.raises(AdmissionError) as e:
        svc.submit(np.zeros((8,), np.float32))  # undeclared dtype
    assert e.value.reason == "unknown_bucket"
    assert svc.metrics.rejected_unknown == 3
    assert svc.metrics.admitted == 0 and svc.depth == 0


def test_admission_overload_backpressure():
    svc = ScanService(4, _scalar_buckets(), max_queue=3)
    for _ in range(3):
        svc.submit(np.ones((4,), np.int32))
    with pytest.raises(AdmissionError) as e:
        svc.submit(np.ones((4,), np.int32))
    assert e.value.reason == "overload"
    assert svc.metrics.rejected_overload == 1
    svc.drain()  # queue empties -> admission reopens
    svc.submit(np.ones((4,), np.int32))
    assert svc.depth == 1


def test_admit_unknown_auto_declares():
    svc = ScanService(4, [], admit_unknown=True)
    req = svc.submit(np.arange(4, dtype=np.int64))
    assert req.bucket.key in svc.buckets
    (done,) = svc.drain()
    np.testing.assert_array_equal(
        done.result, _exclusive_ref(np.arange(4, dtype=np.int64)))


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


def test_batch_results_match_host_reference_mixed_buckets():
    buckets = [
        Bucket(kind="exclusive", monoid="add", shape=(), dtype=np.int32),
        Bucket(kind="scan_total", monoid="add", shape=(5),
               dtype=np.int64),
    ]
    svc = ScanService(8, buckets, max_batch=4)
    rng = np.random.default_rng(0)
    scalars = [rng.integers(0, 100, size=(8,)).astype(np.int32)
               for _ in range(6)]
    vectors = [rng.integers(0, 100, size=(8, 5)).astype(np.int64)
               for _ in range(3)]
    reqs = [svc.submit(x) for x in scalars]
    reqs += [svc.submit(x, kind="scan_total") for x in vectors]
    done = svc.drain()
    assert len(done) == 9 and all(r.status == "done" for r in reqs)
    for r, x in zip(reqs[:6], scalars):
        np.testing.assert_array_equal(r.result, _exclusive_ref(x))
    for r, x in zip(reqs[6:], vectors):
        prefix, total = r.result  # scan_total: per-request tuple
        np.testing.assert_array_equal(prefix, _exclusive_ref(x))
        np.testing.assert_array_equal(
            total, np.broadcast_to(x.sum(0), x.shape))
    m = svc.metrics
    # 6 scalars at max_batch=4 -> batches of 4+2; vectors -> one of 3
    assert m.batches == 3 and m.occupancy_sum == 9
    assert m.fused_round_win > 1.0
    assert m.completed == 9 and m.rounds_executed > 0


def test_single_request_batches_run_solo_not_fused():
    svc = ScanService(4, _scalar_buckets())
    svc.submit(np.arange(4, dtype=np.int32))
    svc.drain()
    assert svc.metrics.batches == 1
    assert svc.metrics.fused_batches == 0  # k=1 has nothing to fuse
    assert svc.metrics.fused_round_win == 1.0


def test_tick_round_robin_serves_all_buckets():
    buckets = [
        Bucket(kind="exclusive", monoid="add", shape=(), dtype=np.int32,
               name="a"),
        Bucket(kind="exclusive", monoid="add", shape=(2,),
               dtype=np.int32, name="b"),
    ]
    svc = ScanService(4, buckets, max_batch=2)
    for _ in range(2):
        svc.submit(np.ones((4,), np.int32))
        svc.submit(np.ones((4, 2), np.int32))
    finalized = svc.tick()
    # one tick drains up to max_batch from EVERY bucket queue
    assert len(finalized) == 4 and svc.depth == 0


# ---------------------------------------------------------------------------
# Warmup contract
# ---------------------------------------------------------------------------


def test_warmup_then_steady_state_never_compiles():
    plan_cache_clear()
    svc = ScanService(8, _scalar_buckets(), max_batch=4)
    assert svc.post_warmup_compiles is None  # not warmed yet
    info = svc.warmup()
    assert info["fused_plans_primed"] == 4
    # every batch size 1..max_batch hits only primed plans
    rng = np.random.default_rng(1)
    for k in range(1, 5):
        for _ in range(k):
            svc.submit(rng.integers(0, 9, size=(8,)).astype(np.int32))
        svc.drain()
    assert svc.post_warmup_compiles == 0
    # an UNDECLARED shape admitted via admit_unknown does compile —
    # the contract covers exactly the declared buckets
    svc.admit_unknown = True
    svc.submit(np.ones((8, 7), np.int32))
    svc.drain()
    assert svc.post_warmup_compiles > 0


def test_warmup_primes_cache_not_just_counts():
    plan_cache_clear()
    svc = ScanService(8, _scalar_buckets(), max_batch=3)
    svc.warmup()
    before = plan_cache_info()
    svc2 = ScanService(8, _scalar_buckets(), max_batch=3)
    svc2.warmup()  # same bucket set: pure cache hits
    after = plan_cache_info()
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]


def test_profile_swap_mid_service_rewarm_and_new_winner():
    """Satellite: swap a drifted cost profile into a WARMED service
    with requests already queued.  install_cost_model must re-warm
    (the zero-post-warmup-compile contract survives the swap) and the
    next drained batch must plan the post-drift winner — the pinned
    (p=8, packed 256 KiB) dci cell flips halving → two_op under 4×
    dci α."""
    import dataclasses

    from repro.launch.mesh import DEFAULT_PROFILE

    plan_cache_clear()
    # four 64 KiB requests pack to the pinned 256 KiB dci-tier cell
    bucket = Bucket(kind="exclusive", monoid="add", shape=(8192,),
                    dtype=np.int64)
    svc = ScanService(8, [bucket], axis_name="pod", max_batch=4,
                      cost_model=DEFAULT_PROFILE)
    svc.warmup()
    rng = np.random.default_rng(0)

    def submit4():
        return [svc.submit(rng.integers(0, 1 << 20, size=(8, 8192))
                           .astype(np.int64)) for _ in range(4)]

    reqs = submit4()
    svc.drain()
    assert all(r.status == "done" for r in reqs)
    assert svc.post_warmup_compiles == 0
    assert svc.last_decision.packed.algorithm == "halving"
    # drift lands while requests sit in the queue
    queued = submit4()
    drifted = dataclasses.replace(DEFAULT_PROFILE, tiers=tuple(
        (n, dataclasses.replace(cm, alpha=cm.alpha * 4.0)
         if n == "dci" else cm)
        for n, cm in DEFAULT_PROFILE.tiers))
    report = svc.install_cost_model(drifted)
    assert report is not None and report["fused_plans_primed"] == 4
    assert svc.post_warmup_compiles == 0  # re-warmed before draining
    done = svc.drain()
    assert [r.status for r in done] == ["done"] * 4
    for r, q in zip(done, queued):
        assert r is q
        ref = np.zeros_like(r.payload)
        ref[1:] = np.cumsum(r.payload[:-1], axis=0)
        np.testing.assert_array_equal(r.result, ref)
    # the queued batch planned under the NEW pricing: winner flipped
    assert svc.last_decision.packed.algorithm == "two_op"
    assert svc.post_warmup_compiles == 0


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------


def test_deadline_admission_to_start_semantics():
    svc = ScanService(4, _scalar_buckets(), default_timeout=1.0)
    late = svc.submit(np.ones((4,), np.int32), now=0.0)
    assert late.deadline == 1.0
    # its deadline passes while it is still queued -> dropped, never run
    finalized = svc.tick(now=2.0)
    assert [r.status for r in finalized] == ["timeout"]
    assert late.result is None and svc.metrics.timed_out == 1
    assert late.latency == 2.0
    # per-request timeout overrides the default; a request whose batch
    # starts before the deadline completes even if execution crosses it
    ok = svc.submit(np.ones((4,), np.int32), now=2.0, timeout=1e-9)
    finalized = svc.tick(now=2.0)  # deadline not yet passed at drain
    assert ok.status == "done" and finalized == [ok]
    # explicit absolute deadline wins over default_timeout
    req = svc.submit(np.ones((4,), np.int32), now=3.0, deadline=100.0)
    assert req.deadline == 100.0


def test_clock_is_monotone_and_measures_service_time():
    svc = ScanService(4, _scalar_buckets())
    assert svc.now == 0.0
    svc.submit(np.ones((4,), np.int32), now=5.0)
    svc.tick(now=4.0)  # stale caller clock cannot move time backwards
    assert svc.now > 5.0  # advanced by the measured execution seconds
    assert svc.metrics.service_seconds > 0.0


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_percentile_and_snapshot():
    assert np.isnan(percentile([], 50))
    assert percentile([1.0, None, 3.0], 50) == 2.0
    svc = ScanService(4, _scalar_buckets())
    for _ in range(3):
        svc.submit(np.ones((4,), np.int32), now=0.0)
    svc.drain()
    snap = svc.metrics.snapshot()
    assert snap["completed"] == 3 and snap["queue_depth"] == 0
    assert snap["latency_p50_s"] > 0.0
    assert snap["latency_p99_s"] >= snap["latency_p50_s"]
    assert snap["rounds_per_request"] > 0
    svc.reset_metrics()
    assert svc.metrics.snapshot()["completed"] == 0


# ---------------------------------------------------------------------------
# Workload generators (the real consumers' request shapes)
# ---------------------------------------------------------------------------


def test_moe_workload_matches_bucket_and_serves():
    from repro import configs

    cfg = configs.get_smoke("qwen2_moe_a2_7b")
    bucket = workloads.moe_bucket(cfg)
    assert bucket.kind == "scan_total"
    rng = np.random.default_rng(0)
    pay = workloads.moe_dispatch_payload(cfg, 4, rng, n_tokens=16)
    bucket.validate(pay, 4)
    assert pay.sum() == 4 * 16 * max(cfg.top_k, 1)  # every token routed
    svc = ScanService(4, [bucket])
    req = svc.submit(pay, kind="scan_total")
    svc.drain()
    prefix, total = req.result
    np.testing.assert_array_equal(prefix, _exclusive_ref(pay))
    np.testing.assert_array_equal(
        total, np.broadcast_to(pay.sum(0), pay.shape))


def test_compression_workload_matches_module_counts():
    from repro.optim.compression import leaf_slot_counts

    sizes = [100, 2_000, 7]
    pays = workloads.compression_offset_payloads(4, sizes, 0.01)
    counts = leaf_slot_counts(sizes, 0.01)
    assert len(pays) == 3
    bucket = workloads.compression_bucket()
    for pay, c in zip(pays, counts):
        bucket.validate(pay, 4)
        assert (pay == c).all()  # untresholded: uniform counts
    jittered = workloads.compression_offset_payloads(
        4, sizes, 0.01, rng=np.random.default_rng(0), thresholded=True)
    for pay, c in zip(jittered, counts):
        assert (1 <= pay).all() and (pay <= c).all()
    with pytest.raises(ValueError, match="rng"):
        workloads.compression_offset_payloads(4, sizes, thresholded=True)


def test_poisson_arrivals():
    arr = workloads.poisson_arrivals(np.random.default_rng(0), 100.0,
                                     500)
    assert len(arr) == 500 and (np.diff(arr) > 0).all()
    assert 2.0 < arr[-1] < 10.0  # ~5 s of traffic at 100 req/s
    with pytest.raises(ValueError, match="rate"):
        workloads.poisson_arrivals(np.random.default_rng(0), 0.0, 1)


# ---------------------------------------------------------------------------
# Serve bench: burst phase through the CI gate
# ---------------------------------------------------------------------------


def test_serve_bench_burst_gate():
    import importlib.util
    import os

    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "serve_bench.py")
    spec = importlib.util.spec_from_file_location("serve_bench", path)
    sb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(sb)

    plan_cache_clear()
    svc, traffic, _ = sb._make_service_and_traffic(seed=0)
    svc.warmup()
    rows = [sb.run_burst(svc, traffic)]
    assert sb.check(rows) == []
    assert rows[0]["fused_round_win"] >= sb.MIN_FUSED_ROUND_WIN
    assert rows[0]["post_warmup_compiles"] == 0
    # a broken burst row trips the gate
    bad = dict(rows[0], completed=0)
    assert sb.check([bad])
