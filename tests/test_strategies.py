"""Sharding-strategy equivalence: fsdp_sp == tp == single-device, for a
dense and a MoE arch on a 2x4 mesh (8 fake devices, subprocess)."""

import pytest

from helpers import run_with_devices

_CODE = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro import configs
from repro.models.model import Model

rng = np.random.default_rng(3)
tokens = jnp.asarray(rng.integers(0, 256, (4, 32)), jnp.int32)

mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
cfg0 = configs.get_smoke("{arch}", capacity_factor=16.0)
m1 = Model(cfg0, mesh1)
params = m1.init_params(jax.random.PRNGKey(0))
with jax.set_mesh(mesh1):
    ref = np.asarray(jax.jit(m1.forward)(params, tokens)[0])

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = configs.get_smoke("{arch}", capacity_factor=16.0,
                        sharding_strategy="{strategy}")
m = Model(cfg, mesh)
with jax.set_mesh(mesh):
    got = np.asarray(jax.jit(m.forward)(params, tokens)[0])
err = float(np.max(np.abs(got - ref)))
assert err < 3e-4, err
print("OK", err)
"""


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_moe_a2_7b",
                                  "rwkv6_1_6b"])
@pytest.mark.parametrize("strategy", ["tp", "fsdp_sp"])
def test_strategy_equivalence(arch, strategy):
    out = run_with_devices(_CODE.format(arch=arch, strategy=strategy),
                           8, x64=False, timeout=900)
    assert "OK" in out
