"""Composition/fusion tests (ISSUE-3): every plan — single-axis,
multi-axis (composed into ONE axis-annotated schedule) and fused —
lowers to one executable Schedule.

Covers the acceptance criteria: the composed multi-axis schedule is
bit-identical to the legacy three-sub-plan execution at p in 2..17
(simulator), executable by all three executors with simulator-measured
stats matching the plan's predictions; ``fused_scan`` of k small
same-axis exscans equals k independent scans while using the
single-scan round count; the fused exscan+allreduce ("scan_total")
returns (prefix, total) in the allreduce's round count at power-of-two
p; and the plan cache reports hits for repeated ``plan()`` calls.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
except ImportError:  # minimal container: property tests skip
    from helpers import fake_hypothesis

    given, settings, hst = fake_hypothesis()

from helpers import run_with_devices

from repro.core import monoid as monoid_lib
from repro.core import schedule as schedule_lib
from repro.core.scan_api import (
    ScanSpec, algorithms, plan, plan_cache_clear, plan_cache_info,
    plan_fused)
from repro.core.schedule import (
    SimulatorExecutor, collect_stats, compose, fuse, make_layout,
    pack_payloads, unpack_payloads)


def _exclusive_ref(x):
    ref = np.zeros_like(x)
    ref[1:] = np.cumsum(x[:-1], axis=0)
    return ref


# ---------------------------------------------------------------------------
# Composed multi-axis schedules == the legacy three-sub-plan execution
# ---------------------------------------------------------------------------


def _legacy_subplan_execute(pl, x, m):
    """The pre-refactor multi-axis execution: run the three sub-plans'
    schedules separately (inner exscan / minor allreduce per major
    group, outer exscan of totals across groups) plus the combining ⊕
    — the reference the composed single schedule must reproduce
    bit-for-bit."""
    sim = SimulatorExecutor()
    inner_pl, reduce_pl, outer_pl = pl.sub_plans
    p_out, p_in = outer_pl.p, inner_pl.p
    grp = x.reshape(p_out, p_in, *x.shape[1:])
    op = monoid_lib.NUMPY_OPS[m.name]
    inner = np.stack([sim.execute(inner_pl.schedule(), grp[g], m)
                      for g in range(p_out)])
    total = np.stack([sim.execute(reduce_pl.schedule(), grp[g], m)
                      for g in range(p_out)])
    # outer exscan runs on the (replicated) minor-axis totals: one
    # value per major group (take minor rank 0's copy)
    outer = sim.execute(outer_pl.schedule(), total[:, 0], m)
    combined = op(outer[:, None], inner)
    return combined.reshape(x.shape)


def test_composed_bit_identical_to_legacy_subplans():
    sim = SimulatorExecutor()
    for p_in in range(2, 18):
        for p_out in (2, 3):
            p = p_out * p_in
            x = (np.arange(p * 4, dtype=np.int64).reshape(p, 4) ** 2
                 % 100003)
            pl = plan(ScanSpec(kind="exclusive", algorithm="auto",
                               axis_name=("A", "B")),
                      p=(p_out, p_in), nbytes=32)
            want = _legacy_subplan_execute(pl, x, monoid_lib.ADD)
            with collect_stats() as st:
                got = sim.execute(pl.schedule(), x, monoid_lib.ADD)
            assert np.array_equal(got, want), (p_out, p_in)
            assert np.array_equal(got, _exclusive_ref(x))
            assert st.rounds == pl.rounds, (p_out, p_in, st, pl)
            assert st.op_applications == pl.op_applications
            assert st.allgathers == pl.allgathers
            assert pl.algorithm.startswith("composite(")


def test_composed_three_axes_and_noncommutative():
    sim = SimulatorExecutor()
    # three axes, non-commutative affine monoid
    ps = (2, 3, 4)
    p = int(np.prod(ps))
    rng = np.random.default_rng(0)
    a = rng.standard_normal((p, 8))
    b = rng.standard_normal((p, 8))
    pl = plan(ScanSpec(kind="exclusive", algorithm="auto",
                       monoid="affine", axis_name=("A", "B", "C")),
              p=ps, nbytes=128)
    sched = pl.schedule()
    assert sched.axes == (("A", 2), ("B", 3), ("C", 4))
    with collect_stats() as st:
        ga, gb = sim.execute(sched, (a, b), monoid_lib.AFFINE)
    oa = np.ones_like(a)
    ob = np.zeros_like(b)
    ca, cb = np.ones(8), np.zeros(8)
    for r in range(p):
        oa[r], ob[r] = ca, cb
        ca, cb = a[r] * ca, a[r] * cb + b[r]
    np.testing.assert_allclose(ga, oa, rtol=1e-12)
    np.testing.assert_allclose(gb, ob, rtol=1e-12)
    assert st.rounds == pl.rounds
    assert st.op_applications == pl.op_applications


def test_composed_with_segmented_ring_stage():
    # a large payload on the minor axis makes the inner stage a
    # segmented ring inside the composed schedule
    pl = plan(ScanSpec(kind="exclusive", algorithm="auto",
                       axis_name=("A", "B")), p=(2, 12),
              nbytes=2 << 20)
    assert pl.sub_plans[0].algorithm == "ring"
    assert pl.sub_plans[0].segments > 1
    res = schedule_lib.verify_plan(pl)
    assert res["ok"], res
    # one notch down the payload axis the mid-m block builders own
    # the inner stage instead, inside the same composed structure
    pl = plan(ScanSpec(kind="exclusive", algorithm="auto",
                       axis_name=("A", "B")), p=(2, 12),
              nbytes=1 << 20)
    assert pl.sub_plans[0].algorithm == "quartering"
    res = schedule_lib.verify_plan(pl)
    assert res["ok"], res


def test_compose_transform_validation():
    from repro.core.schedule import (
        build_123, build_butterfly, build_hillis_steele)

    with pytest.raises(ValueError, match="allreduce"):
        compose(build_123(4), build_hillis_steele(4), build_123(2),
                minor_axis="B", outer_axis="A")
    with pytest.raises(ValueError, match="share p"):
        compose(build_123(4), build_butterfly(8), build_123(2),
                minor_axis="B", outer_axis="A")
    with pytest.raises(ValueError, match="outer_axis"):
        compose(build_123(4), build_butterfly(4), build_123(2),
                minor_axis="B")


# ---------------------------------------------------------------------------
# Fused k-scans: packed payload, single-scan round count
# ---------------------------------------------------------------------------


def test_fused_equals_independent_scans_with_single_scan_rounds():
    sim = SimulatorExecutor()
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto",
                    axis_name="x")
    for p in range(2, 18):
        rng = np.random.default_rng(p)
        sizes = (2, 5, 3, 8)
        xs = [rng.integers(0, 1 << 30, size=(p, n)).astype(np.int64)
              for n in sizes]
        fp = plan_fused([spec] * len(xs), p, [n * 8 for n in sizes])
        assert fp.fused, p
        single = plan(spec, p=p, nbytes=8 * sum(sizes))
        assert fp.rounds == single.rounds  # NOT k x single
        with collect_stats() as st:
            outs = fp.execute(xs, executor=sim)
        for o, x in zip(outs, xs):
            assert np.array_equal(o, _exclusive_ref(x)), p
        assert st.rounds == fp.rounds == fp.packed.rounds, (p, st)
        assert st.op_applications == fp.packed.op_applications


def test_fused_decision_respects_cost_model():
    from repro.core.scan_api import CostModel

    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    # latency-dominated: fusing always wins (α·q once, not k·α·q)
    fp = plan_fused([spec] * 4, 36, [8] * 4,
                    cost_model=CostModel(alpha=1.0, beta=0.0,
                                         gamma=0.0))
    assert fp.fused and fp.rounds == plan(spec, 36, nbytes=32).rounds
    # a single scan never "fuses"
    fp1 = plan_fused([spec], 36, [8])
    assert not fp1.fused and fp1.rounds == fp1.plans[0].rounds
    # conflicting algorithm pins fall back to serial execution
    fp2 = plan_fused([spec.over(None, algorithm="123"),
                      spec.over(None, algorithm="ring")], 36, [8, 8])
    assert not fp2.fused
    # non-segmentable monoids cannot pack
    fp3 = plan_fused([spec.over(None, monoid="matmul")] * 2, 8,
                     [128, 128])
    assert not fp3.fused


def test_fused_verify_and_affine_payloads():
    spec = ScanSpec(kind="exclusive", monoid="affine",
                    algorithm="auto", axis_name="x")
    fp = plan_fused([spec] * 3, 9, [64] * 3)
    res = fp.verify()
    assert res["ok"], res
    assert res["rounds_measured"] == res["rounds_predicted"]


def test_payload_layout_pack_roundtrip():
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((3, 4)), rng.standard_normal((7,)),
          rng.standard_normal((2, 2, 2))]
    layout = make_layout(xs)
    assert layout.n == 3 and layout.totals == (12 + 7 + 8,)
    packed = pack_payloads(layout, xs, xp=np)
    outs = unpack_payloads(layout, packed)
    for o, x in zip(outs, xs):
        np.testing.assert_array_equal(o, x)
    # mismatched dtypes refuse to pack
    with pytest.raises(ValueError, match="dtype"):
        make_layout([xs[0], xs[1].astype(np.float32)])
    # tuple payloads (affine-style) share one treedef
    ys = [(rng.standard_normal(4), rng.standard_normal(4)),
          (rng.standard_normal(6), rng.standard_normal(6))]
    layout = make_layout(ys)
    packed = pack_payloads(layout, ys, xp=np)
    outs = unpack_payloads(layout, packed)
    for o, y in zip(outs, ys):
        np.testing.assert_array_equal(o[0], y[0])
        np.testing.assert_array_equal(o[1], y[1])
    with pytest.raises(ValueError, match="tree structure"):
        make_layout([ys[0], xs[0]])


def test_fuse_transform_validation():
    from repro.core.schedule import build_123, build_butterfly

    layout = make_layout([np.zeros(3), np.zeros(5)])
    fused = fuse([build_123(8)], layout)
    assert fused.layout is layout and fused.rounds == build_123(8).rounds
    assert fused.algorithm == "fused[2](123)"
    with pytest.raises(ValueError, match="share kind"):
        fuse([build_123(8), build_butterfly(8)], layout)
    with pytest.raises(ValueError, match="already fused"):
        fuse([fused], layout)
    # same kind, mismatched output lists refuse to fuse
    import dataclasses as dc

    from repro.core.schedule import build_scan_total

    st = build_scan_total(8)
    with pytest.raises(ValueError, match="share outputs"):
        fuse([st, dc.replace(st, outputs=("$w",))], layout)


def _check_fused_bucket(p, xs, dtype, rng):
    """One bucket's property: k mixed-size payloads of one dtype fuse
    into the single-scan round count and every unpacked result matches
    the host exscan."""
    sim = SimulatorExecutor()
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto",
                    axis_name="x")
    fp = plan_fused([spec] * len(xs), p,
                    [x[0].nbytes for x in xs])
    assert fp.fused == (len(xs) > 1), (p, dtype)
    with collect_stats() as st:
        outs = fp.execute(xs, executor=sim)
    for o, x in zip(outs, xs):
        assert o.dtype == x.dtype
        if np.issubdtype(x.dtype, np.integer):
            np.testing.assert_array_equal(o, _exclusive_ref(x))
        else:  # ⊕ order differs from cumsum's: bit-exact only for ints
            np.testing.assert_allclose(o, _exclusive_ref(x),
                                       rtol=1e-12, atol=1e-12)
    assert st.rounds == fp.rounds, (p, dtype, st.rounds, fp.rounds)
    res = fp.verify()  # simulator drift check on the same plan
    assert res["ok"], (p, dtype, res)


def test_fused_property_mixed_sizes_and_dtypes_every_p():
    # deterministic property sweep: p in 2..17, random payload-size
    # mixes, int64 and float64 buckets (dtype is part of the bucket —
    # mixed dtypes refuse to pack, asserted at the end)
    for p in range(2, 18):
        rng = np.random.default_rng(1000 + p)
        k = int(rng.integers(1, 6))
        sizes = [int(rng.integers(1, 32)) for _ in range(k)]
        ints = [rng.integers(0, 1 << 30, size=(p, n)).astype(np.int64)
                for n in sizes]
        _check_fused_bucket(p, ints, np.int64, rng)
        floats = [rng.standard_normal((p, n)) for n in sizes]
        _check_fused_bucket(p, floats, np.float64, rng)
    # a mixed-dtype batch is NOT one bucket: the pack refuses
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto",
                    axis_name="x")
    fp = plan_fused([spec] * 2, 8, [32, 32])
    bad = [np.zeros((8, 4), np.int64), np.zeros((8, 4), np.float64)]
    with pytest.raises(ValueError, match="dtype"):
        fp.execute(bad, executor=SimulatorExecutor())


def test_fused_scan_total_multi_output():
    # k fused scan_totals: ONE packed butterfly, every request gets its
    # own (prefix, total) back via unpack_fused_outputs
    from repro.core.schedule import unpack_fused_outputs

    sim = SimulatorExecutor()
    spec = ScanSpec(kind="scan_total", monoid="add", algorithm="auto",
                    axis_name="x")
    for p in (4, 8, 9, 13, 16):
        rng = np.random.default_rng(p)
        xs = [rng.integers(0, 1 << 20, size=(p, n)).astype(np.int64)
              for n in (3, 1, 6)]
        fp = plan_fused([spec] * len(xs), p,
                        [x[0].nbytes for x in xs])
        assert fp.fused, p
        single = plan(spec, p=p, nbytes=sum(x[0].nbytes for x in xs))
        assert fp.rounds == single.rounds
        with collect_stats() as st:
            outs = fp.execute(xs, executor=sim)
        for (prefix, total), x in zip(outs, xs):
            np.testing.assert_array_equal(prefix, _exclusive_ref(x))
            np.testing.assert_array_equal(
                total, np.broadcast_to(x.sum(0), x.shape))
        assert st.rounds == fp.rounds, (p, st.rounds, fp.rounds)
        assert fp.verify()["ok"], p
    # unpack_fused_outputs on a plain (single-output) result is just
    # unpack_payloads
    xs = [np.arange(6).reshape(2, 3), np.arange(2)]
    layout = make_layout(xs)
    packed = pack_payloads(layout, xs, xp=np)
    outs = unpack_fused_outputs(layout, packed)
    for o, x in zip(outs, xs):
        np.testing.assert_array_equal(o, x)
    # two outputs: payload i gets (out0_i, out1_i)
    outs = unpack_fused_outputs(layout, (packed, packed), 2)
    for (a, b), x in zip(outs, xs):
        np.testing.assert_array_equal(a, x)
        np.testing.assert_array_equal(b, x)


@settings(max_examples=25, deadline=None)
@given(p=hst.integers(min_value=2, max_value=17),
       sizes=hst.lists(hst.integers(min_value=1, max_value=16),
                       min_size=2, max_size=5),
       seed=hst.integers(min_value=0, max_value=2**31 - 1))
def test_fused_property_hypothesis(p, sizes, seed):
    rng = np.random.default_rng(seed)
    xs = [rng.integers(0, 1 << 30, size=(p, n)).astype(np.int64)
          for n in sizes]
    _check_fused_bucket(p, xs, np.int64, rng)


# ---------------------------------------------------------------------------
# scan_total: fused exscan+allreduce
# ---------------------------------------------------------------------------


def test_scan_total_simulator_every_p():
    sim = SimulatorExecutor()
    for p in range(1, 18):
        x = np.arange(max(p, 1) * 4, dtype=np.int64).reshape(-1, 4)[:p]
        pl = plan(ScanSpec(kind="scan_total", algorithm="auto"), p=p,
                  nbytes=32)
        with collect_stats() as st:
            prefix, total = sim.execute(pl.schedule(), x,
                                        monoid_lib.ADD)
        assert np.array_equal(prefix, _exclusive_ref(x)), p
        assert np.array_equal(
            total, np.broadcast_to(x.sum(0), x.shape)), p
        assert st.rounds == pl.rounds, (p, st, pl)
        assert st.op_applications == pl.op_applications, (p, st, pl)
        # power-of-two p: BOTH results in the allreduce's round count
        if p >= 2 and not (p & (p - 1)):
            assert pl.algorithm == "fused_doubling"
            assert pl.rounds == int(np.ceil(np.log2(p)))


def test_scan_total_pinned_variants_cover_exclusive_algorithms():
    assert algorithms("scan_total") == (
        "123", "1doubling", "fused_doubling", "halving", "native",
        "quartering", "reduce_scatter", "ring", "two_op")
    for alg in algorithms("scan_total"):
        res = schedule_lib.verify_plan(
            plan(ScanSpec(kind="scan_total", algorithm=alg), p=9,
                 nbytes=1024))
        assert res["ok"], (alg, res)
    # the fused butterfly strictly beats exscan+allreduce serially: at
    # p=16 it needs 4 rounds where 123 + butterfly would pay 5 + 4
    fused = plan(ScanSpec(kind="scan_total", algorithm="auto"), p=16,
                 nbytes=8)
    serial = (plan(ScanSpec(kind="exclusive", algorithm="123"), p=16,
                   nbytes=8).rounds
              + plan(ScanSpec(kind="allreduce", algorithm="butterfly"),
                     p=16, nbytes=8).rounds)
    assert fused.rounds == 4 and serial == 9


def test_scan_total_multi_axis_composes():
    pl = plan(ScanSpec(kind="scan_total", algorithm="auto",
                       axis_name=("pod", "data")), p=(2, 8), nbytes=16)
    assert len(pl.sub_plans) == 2  # no separate allreduce stage
    res = schedule_lib.verify_plan(pl)
    assert res["ok"], res
    # rounds: inner fused butterfly (3) + outer (1) — the allreduce the
    # §5 rewrite needs rides the inner scan_total for free
    assert pl.rounds == 4


# ---------------------------------------------------------------------------
# Plan cache observability
# ---------------------------------------------------------------------------


def test_plan_cache_reports_hits():
    plan_cache_clear()
    spec = ScanSpec(kind="exclusive", algorithm="auto")
    before = plan_cache_info()
    assert before["hits"] == 0 and before["size"] == 0
    a = plan(spec, p=16, nbytes=128)
    mid = plan_cache_info()
    b = plan(spec, p=16, nbytes=128)
    after = plan_cache_info()
    assert a is b
    assert after["hits"] == mid["hits"] + 1
    assert after["size"] == mid["size"]


# ---------------------------------------------------------------------------
# SPMD + Pallas executors on composed/fused schedules (subprocess with
# fake devices; acceptance criterion: one IR, three executors)
# ---------------------------------------------------------------------------

_SPMD_COMPOSED = """
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core import monoid as monoid_lib
from repro.core.scan_api import ScanSpec, scan, plan, scan_with_total, \\
    fused_scan
from repro.core.schedule import (
    SimulatorExecutor, PallasExecutor, collect_stats)

x = np.arange(8 * 4, dtype=np.int64).reshape(8, 4)
ref = np.zeros_like(x)
ref[1:] = np.cumsum(x[:-1], axis=0)
mesh2 = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
mesh1 = Mesh(np.array(jax.devices()).reshape(8), ("x",))

# multi-axis spec -> ONE composed schedule, SPMD == simulator == plan
spec = ScanSpec(kind="exclusive", algorithm="auto",
                axis_name=("pod", "data"))
pl = plan(spec, p=(2, 4), nbytes=32)
assert pl.algorithm.startswith("composite(")
with collect_stats() as st:
    f = jax.jit(shard_map(lambda v: scan(v, spec), mesh=mesh2,
                          in_specs=P(("pod", "data")),
                          out_specs=P(("pod", "data"))))
    got = np.asarray(f(x))
assert np.array_equal(got, ref)
assert (st.rounds, st.op_applications, st.allgathers) == (
    pl.rounds, pl.op_applications, pl.allgathers), (st, pl)
with collect_stats() as st_sim:
    sim = SimulatorExecutor().execute(pl.schedule(), x, monoid_lib.ADD)
assert np.array_equal(np.asarray(sim), got)
assert st_sim.bytes_per_round == st.bytes_per_round
print("OK composed spmd", pl.rounds)

# plan.lower() retargets the same composed schedule at the Pallas
# executor (the third backend)
ex = PallasExecutor(interpret=True)
fp = jax.jit(shard_map(pl.lower(ex), mesh=mesh2,
                       in_specs=P(("pod", "data")),
                       out_specs=P(("pod", "data")), check_vma=False))
assert np.array_equal(np.asarray(fp(x)), ref)
print("OK composed pallas")

# fused exscan+allreduce: (prefix, total) in the allreduce's rounds
tspec = ScanSpec(kind="exclusive", algorithm="auto", axis_name="x")
with collect_stats() as st:
    g = jax.jit(shard_map(lambda v: scan_with_total(v, tspec),
                          mesh=mesh1, in_specs=P("x"),
                          out_specs=(P("x"), P("x"))))
    pref, tot = g(x)
assert np.array_equal(np.asarray(pref), ref)
assert np.array_equal(np.asarray(tot),
                      np.broadcast_to(x.sum(0), x.shape))
assert st.rounds == 3  # ceil(log2(8)): allreduce round count for BOTH
print("OK scan_with_total", st.rounds)

# fused_scan: 3 concurrent exscans ride the single-scan round count
xs = [np.arange(8 * n, dtype=np.int64).reshape(8, n)
      for n in (2, 3, 5)]
espec = ScanSpec(kind="exclusive", algorithm="auto", axis_name="x")
with collect_stats() as st:
    h = jax.jit(shard_map(
        lambda a, b, c: tuple(fused_scan(
            [(a, espec), (b, espec), (c, espec)])),
        mesh=mesh1, in_specs=(P("x"),) * 3, out_specs=(P("x"),) * 3))
    outs = h(*xs)
for o, xi in zip(outs, xs):
    r = np.zeros_like(xi)
    r[1:] = np.cumsum(xi[:-1], axis=0)
    assert np.array_equal(np.asarray(o), r)
single = plan(espec, p=8, nbytes=sum(xi[0].nbytes for xi in xs))
assert st.rounds == single.rounds, (st.rounds, single.rounds)
print("OK fused_scan", st.rounds)
"""


def test_spmd_composed_fused_and_scan_total():
    out = run_with_devices(_SPMD_COMPOSED, 8)
    assert out.count("OK") == 4
