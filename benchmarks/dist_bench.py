"""Multi-process hierarchical exscan bench: the correctness bridge
between the distributed runtime (``repro.dist``) and the
single-process simulator.

Each config plans one two-level exscan over (proc, local), where the
planner's per-tier cost models pick a DIFFERENT algorithm on the
intra-process ("ici") tier than on the cross-process ("dci") tier —
the paper's motivating regime.  The composed schedule is executed
across a real :class:`~repro.dist.launcher.WorkerPool` (N OS
processes, socket transport) and checked against
:class:`~repro.core.schedule.SimulatorExecutor`:

- bit-identity of every output leaf (the runtime's core contract),
- measured rounds == simulator rounds == plan prediction,
- measured per-round bytes == ``expected_round_bytes`` (IR byte law),
- the two tiers chose different algorithms (otherwise the config no
  longer exercises per-tier choice and must be repinned),
- cross-process traffic actually flowed (``cross_bytes > 0``).

``--check`` turns any drift into a build failure; results land in
``BENCH_dist.json`` next to the other ``BENCH_*.json`` artifacts.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

DEFAULT_JSON = "BENCH_dist.json"

# (nprocs, p_intra, nbytes): pinned where DEFAULT_PROFILE's dci/ici
# pricing splits the tiers.  Config 1: large-ish m at p=12 -> latency
# -optimal 123 inside each process, bandwidth-leaning ring (S=2)
# across processes (p_inter=3 is non-pow-2, exercising the fallback).
# Config 2: 1 MiB at p=8 -> segmented ring (S=8) inside, 123 across
# (dci's 10x alpha makes extra cross rounds too expensive).
CONFIGS = (
    {"nprocs": 3, "p_intra": 4, "nbytes": 262_144},
    {"nprocs": 2, "p_intra": 4, "nbytes": 1_048_576},
)


def _payload(p: int, nbytes: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 30,
                        size=(p, max(1, nbytes // 8))).astype(np.int64)


def run_config(cfg: dict, seed: int = 0) -> dict:
    import jax

    from repro.core import monoid as monoid_lib
    from repro.core import scan_api
    from repro.core import schedule as schedule_lib
    from repro.dist.launcher import WorkerPool, run_plan

    spec = scan_api.ScanSpec(kind="exclusive", monoid="add")
    pl = scan_api.plan_hierarchical(spec, p_inter=cfg["nprocs"],
                                    p_intra=cfg["p_intra"],
                                    nbytes=cfg["nbytes"])
    inner, outer = pl.sub_plans[0], pl.sub_plans[-1]
    sched = pl.schedule()
    x = _payload(pl.p, cfg["nbytes"], seed)
    m = monoid_lib.get("add")

    from repro.core import tune

    with WorkerPool(cfg["nprocs"], cfg["p_intra"]) as pool:
        res = run_plan(pool, pl, x)
        # the raw "dci" latency evidence: one-way ping-pong hop times
        # at a small and the config's payload size (previously only
        # measured transiently during calibrate_dist, then discarded)
        hops = tune.measure_hops(pool, sizes=(8, cfg["nbytes"]),
                                 repeats=5)

    with schedule_lib.collect_stats() as sim_st:
        want = schedule_lib.SimulatorExecutor().execute(sched, x, m)
    identical = all(
        np.array_equal(g, w) for g, w in
        zip(jax.tree.leaves(res.outputs), jax.tree.leaves(want)))
    bytes_expected = schedule_lib.expected_round_bytes(
        sched, jax.tree.map(lambda a: a[0], x))

    row = {
        "nprocs": cfg["nprocs"], "p_intra": cfg["p_intra"],
        "p": pl.p, "nbytes": cfg["nbytes"],
        "intra_algorithm": inner.algorithm,
        "intra_segments": inner.segments,
        "inter_algorithm": outer.algorithm,
        "inter_segments": outer.segments,
        "rounds_plan": pl.rounds,
        "rounds_dist": res.stats["rounds"],
        "rounds_sim": sim_st.rounds,
        "ops_dist": res.stats["op_applications"],
        "ops_sim": sim_st.op_applications,
        "bytes_dist": sum(res.stats["bytes_per_round"]),
        "bytes_expected": bytes_expected,
        "cross_bytes": res.transport["cross_bytes"],
        "cross_msgs": res.transport["cross_msgs"],
        "seconds": res.seconds[0],
        "rank_seconds": res.rank_seconds[0] if res.rank_seconds
        else [],
        "hop_timings": hops,
        "bit_identical": bool(identical),
    }
    row["tiers_diverge"] = inner.algorithm != outer.algorithm
    row["ok"] = bool(
        identical
        and row["rounds_dist"] == row["rounds_sim"] == pl.rounds
        and row["ops_dist"] == row["ops_sim"]
        and row["bytes_dist"] == bytes_expected
        and row["tiers_diverge"]
        and row["cross_bytes"] > 0)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on any drift (CI gate)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=DEFAULT_JSON, metavar="PATH")
    args = ap.parse_args(argv)

    rows = [run_config(cfg) for cfg in CONFIGS]
    for r in rows:
        print(f"p={r['p']} ({r['nprocs']}x{r['p_intra']}) "
              f"m={r['nbytes']}: intra={r['intra_algorithm']} "
              f"S={r['intra_segments']} / inter={r['inter_algorithm']} "
              f"S={r['inter_segments']} rounds={r['rounds_dist']} "
              f"(plan {r['rounds_plan']}) "
              f"cross_bytes={r['cross_bytes']} "
              f"identical={r['bit_identical']} ok={r['ok']}")
    if args.json:
        from repro.core.benchmeta import bench_metadata

        with open(args.json, "w") as f:
            json.dump({"meta": bench_metadata(),
                       "schema_version": 2, "benchmark": "dist",
                       "rows": rows}, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    bad = [r for r in rows if not r["ok"]]
    if args.check and bad:
        print(f"DIST DRIFT in {len(bad)} config(s): {bad}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
