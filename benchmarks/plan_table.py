"""Planner decision table: which algorithm ``"auto"`` picks per (p, m).

Pure planning math — no devices, no tracing: for each rank count p and
payload size m the rows give the chosen algorithm plus its predicted
rounds and cost-model latency, under both interconnect tiers
(ICI intra-pod, DCI cross-pod; launch/mesh.py parameters).  This is the
paper's "regimes" story made executable: 123-doubling owns the small-m
rows, the pipelined ring takes over as m grows.
"""

from __future__ import annotations

from repro.core.scan_api import ScanSpec, plan
from repro.launch.mesh import DCI_COST, ICI_COST

PS = (8, 36, 256, 512)
MS = (8, 1024, 65_536, 1_048_576, 16_777_216)  # payload bytes

TIERS = (("ici", ICI_COST), ("dci", DCI_COST))


def run(csv_rows: list):
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    for tier, cm in TIERS:
        for p in PS:
            for m in MS:
                pl = plan(spec, p=p, nbytes=m, cost_model=cm)
                key = f"plan/{tier}/p{p}/m{m}"
                csv_rows.append((key + "/algorithm", pl.algorithm,
                                 "auto_choice"))
                csv_rows.append((key + "/rounds", pl.rounds, "rounds"))
                csv_rows.append((key + "/cost_us", pl.cost * 1e6,
                                 "us_abg_model"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
