"""Planner decision table: which algorithm ``"auto"`` picks per (p, m).

No devices, no tracing: for each rank count p and payload size m the
rows give the chosen algorithm, its planner-chosen segment count S
(the pipelined ring splits the payload into S blocks and streams them
through p−2+S neighbour rounds), predicted rounds and cost-model
latency under both interconnect tiers (ICI intra-pod, DCI cross-pod),
plus the rounds *measured* by executing the chosen plan's schedule in
the numpy simulator executor — plan vs measurement drift is visible in
the table and fails the build in ``--check`` mode (CI smoke).  This is
the paper's "regimes" story made executable: 123-doubling owns the
small-m rows, the pipelined segmented ring takes over as m grows.

Pricing provenance (the calibration refactor): ``--profile PATH``
loads a **calibrated** :class:`~repro.core.scan_api.CostProfile`
(a ``profile_*.json`` file, or a store directory — the latest profile
wins; see ``python -m repro.core.tune --simulate``).  Decisions are
then made under the *measured* constants while ``cost_modeled_us``
keeps the hand-guessed default pricing next to ``cost_us`` —
measured-vs-modeled, the paper's empirical discipline in one table.

Two decision-boundary sections ride along:

  * ``crossover/…`` — the paper-style crossover table: per tier and p,
    the smallest m (bytes, binary-searched) where the segmented ring's
    best plan beats 123-doubling, under both the active and the
    default pricing (``m_star`` vs ``m_star_modeled``);
  * ``pin/…`` — small-m cells where the default profile picks ``123``;
    ``--check`` fails if the active (fitted) profile flips any of them
    away from ``123`` (calibration must never lose the paper's
    headline small-message decision).

Further sections cover the composition/fusion refactor: ``plan2d/…``
(composed multi-axis plans, simulator-verified), ``fused/…`` (k
concurrent scans fused vs serial), and ``--verbose`` prints
:func:`scan_api.plan_cache_info`.  ``--json [PATH]`` additionally
writes the whole table as ``BENCH_plan_table.json`` so the perf
trajectory is machine-readable across PRs.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core import scan_api
from repro.core import schedule as schedule_lib
from repro.core import tune
from repro.core.scan_api import ScanSpec, plan, plan_fused
from repro.launch import mesh as mesh_lib

PS = (8, 36, 256, 512)
MS = (8, 1024, 65_536, 1_048_576, 16_777_216)  # payload bytes

# small-m cells eligible for the 123 decision pin (--check gate)
SMALL_MS = (8, 64)

# crossover search range: the smallest m where the ring beats 123
CROSSOVER_LO, CROSSOVER_HI = 8, 1 << 26

# winner-map m ladder (powers of two): the per-band winner table
# sweeps "auto" over these and collapses equal neighbours into bands
WINNER_MS = tuple(1 << e for e in range(3, 27))  # 8 B .. 64 MiB

# the mid-m band builders this PR adds (gated in --check: each tier
# must show at least one p where one of them wins a band)
NEW_ALGS = ("halving", "quartering", "reduce_scatter")

# composed multi-axis cells: (major, minor) rank grids
PS_2D = ((2, 8), (2, 36), (4, 64))
MS_2D = (8, 65_536)

# fused cells: k concurrent same-axis scans of m bytes each
FUSED_K = 4
MS_FUSED = (8, 1024, 1_048_576)

DEFAULT_JSON = "BENCH_plan_table.json"


def _load_profile(path: str | None):
    """--profile resolution: None -> defaults; file -> that profile;
    directory -> the most recently written profile in it."""
    if path is None:
        return mesh_lib.DEFAULT_PROFILE
    if os.path.isdir(path):
        prof = tune.latest_profile(path)
        if prof is None:
            raise SystemExit(f"no readable profile_*.json under {path!r}")
        return prof
    return tune.load_profile_file(path)


def _tiers(active):
    """(tier, active_cm, default_cm) triples; tiers the default profile
    does not know fall back to the active kernel for both columns."""
    default = dict(mesh_lib.DEFAULT_PROFILE.tiers)
    return [(name, cm, default.get(name, cm)) for name, cm in
            active.tiers]


def crossover_m(p: int, cm, algo_a: str = "123", algo_b: str = "ring",
                lo: int = CROSSOVER_LO, hi: int = CROSSOVER_HI):
    """Smallest payload m (bytes) in [lo, hi] where ``algo_b``'s best
    plan costs less than ``algo_a``'s under ``cm`` (binary search on
    the monotone α/β trade-off), for ANY registered algorithm pair.

    Returns ``(m_star, qualifier)``: qualifier ``""`` marks an
    interior crossover (m_star is real); ``"<="`` means algo_b
    already wins at ``lo`` (the true crossover is at or below the
    range floor); ``">"`` means algo_a still wins at ``hi`` (no
    crossover in range — which is a legitimate answer when the pair's
    asymptotic byte slopes never cross, e.g. ring vs reduce_scatter
    at large p under the planner's segment cap).  Callers must
    surface the qualifier instead of reporting a saturated boundary
    as if it were a measured crossover."""
    sa = ScanSpec(kind="exclusive", monoid="add", algorithm=algo_a)
    sb = ScanSpec(kind="exclusive", monoid="add", algorithm=algo_b)

    def b_wins(m: int) -> bool:
        return plan(sb, p=p, nbytes=m, cost_model=cm).cost < \
            plan(sa, p=p, nbytes=m, cost_model=cm).cost

    if b_wins(lo):
        return lo, "<="
    if not b_wins(hi):
        return hi, ">"
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if b_wins(mid):
            hi = mid
        else:
            lo = mid
    return hi, ""


def _fmt_crossover(m_star: int, qualifier: str):
    """Row value: the bare integer for a real crossover, '<=LO' /
    '>HI' for a saturated search (never a silently clamped number)."""
    return f"{qualifier}{m_star}" if qualifier else m_star


def winner_map(p: int, cm):
    """Contiguous (m_lo, m_hi, algorithm) bands of the "auto" winner
    over the ``WINNER_MS`` ladder — the per-band winner table the
    mid-m story is measured by.  m_hi is the last ladder point the
    band holds (the final band extends beyond the ladder)."""
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    bands: list = []
    for m in WINNER_MS:
        alg = plan(spec, p=p, nbytes=m, cost_model=cm).algorithm
        if bands and bands[-1][2] == alg:
            bands[-1] = (bands[-1][0], m, alg)
        else:
            bands.append((m, m, alg))
    return bands


def run(csv_rows: list, check: bool = False, profile=None):
    active = profile or mesh_lib.DEFAULT_PROFILE
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    drift = []
    tiers = _tiers(active)
    csv_rows.append(("profile/source", active.source, "pricing"))
    csv_rows.append(("profile/fingerprint", active.fingerprint(),
                     "pricing"))
    for tier, cm, cm_default in tiers:
        for p in PS:
            for m in MS:
                pl = plan(spec, p=p, nbytes=m, cost_model=cm)
                pl_model = plan(spec, p=p, nbytes=m,
                                cost_model=cm_default)
                res = schedule_lib.verify_plan(pl)
                key = f"plan/{tier}/p{p}/m{m}"
                csv_rows.append((key + "/algorithm", pl.algorithm,
                                 "auto_choice"))
                csv_rows.append((key + "/segments", pl.segments,
                                 "pipeline_S"))
                csv_rows.append((key + "/rounds", pl.rounds, "rounds"))
                csv_rows.append((key + "/rounds_measured",
                                 res["rounds_measured"],
                                 "simulator_executor"))
                # monoid-aware ⊕ prediction (the add monoid elides the
                # redundant combine order in exchange/scan_reduce
                # rounds); verify_plan above drift-checks it against
                # the simulator-executed count
                csv_rows.append((key + "/ops", pl.op_applications,
                                 "oplus_commutative_elided"))
                csv_rows.append((key + "/cost_us", pl.cost * 1e6,
                                 f"us_{pl.cost_model_source}_abg"))
                csv_rows.append((key + "/cost_modeled_us",
                                 pl_model.cost * 1e6,
                                 "us_default_abg"))
                if pl_model.algorithm != pl.algorithm:
                    csv_rows.append((key + "/algorithm_modeled",
                                     pl_model.algorithm,
                                     "default_profile_choice"))
                if not res["ok"]:
                    drift.append((key, res))
    # paper-style crossover table: smallest m where the segmented ring
    # beats 123-doubling, measured (active profile) vs modeled — now
    # with explicit saturation qualifiers instead of silent clamping
    for tier, cm, cm_default in tiers:
        for p in PS:
            key = f"crossover/{tier}/p{p}"
            m_star, q_act = crossover_m(p, cm)
            m_model, q_mod = crossover_m(p, cm_default)
            csv_rows.append((key + "/m_star",
                             _fmt_crossover(m_star, q_act),
                             "min_m_ring_beats_123"))
            csv_rows.append((key + "/m_star_modeled",
                             _fmt_crossover(m_model, q_mod),
                             "min_m_ring_beats_123_default"))
    # per-band winner map (the mid-m payoff, measured not asserted):
    # the "auto" winner over the WINNER_MS ladder, collapsed into
    # bands, under the active ("") and default ("_modeled") pricing;
    # each adjacent band pair gets a binary-searched crossover whose
    # range is the two band edges — saturation there means the sweep
    # and the search disagree, a drift failure, never a clamped cell
    new_band_cells: dict = {}
    for tier, cm, cm_default in tiers:
        for which, kernel in (("", cm), ("_modeled", cm_default)):
            for p in PS:
                bands = winner_map(p, kernel)
                key = f"winner_map{which}/{tier}/p{p}"
                csv_rows.append((
                    key + "/bands",
                    " ".join(f"{alg}:{mlo}..{mhi}"
                             for mlo, mhi, alg in bands),
                    "auto_winner_per_m_band"))
                for (_, ahi, a), (blo, _, b) in zip(bands, bands[1:]):
                    m_star, qual = crossover_m(p, kernel, a, b,
                                               lo=ahi, hi=blo)
                    ckey = f"{key}/crossover/{a}-to-{b}"
                    csv_rows.append((ckey,
                                     _fmt_crossover(m_star, qual),
                                     "min_m_next_band_wins"))
                    if qual:
                        drift.append((ckey, {
                            "saturated": f"{qual}{m_star}",
                            "range": (ahi, blo)}))
                if {alg for _, _, alg in bands} & set(NEW_ALGS):
                    new_band_cells[(which, tier)] = \
                        new_band_cells.get((which, tier), 0) + 1
    # --check gate: every tier must have at least one p where a new
    # mid-m builder wins a band, under BOTH active and default pricing
    for tier, _, _ in tiers:
        for which in ("", "_modeled"):
            n = new_band_cells.get((which, tier), 0)
            csv_rows.append((f"winner_map{which}/{tier}/new_alg_cells",
                             n, "cells_where_mid_m_builder_wins"))
            if n == 0:
                drift.append((f"winner_map{which}/{tier}",
                              {"new_alg_cells": 0, "want": ">=1",
                               "new_algs": NEW_ALGS}))
    # pinned small-m decisions: wherever the default profile picks the
    # paper's 123-doubling, a fitted profile must not flip it
    for tier, cm, cm_default in tiers:
        for p in PS:
            for m in SMALL_MS:
                if plan(spec, p=p, nbytes=m,
                        cost_model=cm_default).algorithm != "123":
                    continue
                got = plan(spec, p=p, nbytes=m, cost_model=cm)
                key = f"pin/{tier}/p{p}/m{m}"
                csv_rows.append((key + "/algorithm", got.algorithm,
                                 "small_m_123_pin"))
                if got.algorithm != "123":
                    drift.append(
                        (key, {"pinned": "123",
                               "got": got.algorithm,
                               "profile": active.fingerprint()}))
    # composed multi-axis plans: one schedule, drift-checked like the
    # single-axis rows (kind "exclusive" and the fused "scan_total")
    spec2 = spec.over(("pod", "data"))
    for tier, cm, _ in tiers:
        for p1, p2 in PS_2D:
            for m in MS_2D:
                for kind in ("exclusive", "scan_total"):
                    pl = plan(spec2.over(spec2.axis_name, kind=kind),
                              p=(p1, p2), nbytes=m, cost_model=cm)
                    res = schedule_lib.verify_plan(pl)
                    key = f"plan2d/{tier}/{kind}/p{p1}x{p2}/m{m}"
                    csv_rows.append((key + "/algorithm", pl.algorithm,
                                     "composite"))
                    csv_rows.append((key + "/rounds", pl.rounds,
                                     "rounds"))
                    csv_rows.append((key + "/rounds_measured",
                                     res["rounds_measured"],
                                     "simulator_executor"))
                    if not res["ok"]:
                        drift.append((key, res))
    # fused vs serial: k concurrent small scans ride ONE schedule's
    # rounds when the α saving beats the packed payload's β cost
    for tier, cm, _ in tiers:
        for p in PS:
            for m in MS_FUSED:
                fp = plan_fused([spec] * FUSED_K, p, [m] * FUSED_K,
                                cost_model=cm)
                single = plan(spec, p=p, nbytes=m * FUSED_K,
                              cost_model=cm)
                key = f"fused/{tier}/p{p}/m{m}/k{FUSED_K}"
                csv_rows.append((key + "/fused", int(fp.fused),
                                 "fuse_decision"))
                csv_rows.append((key + "/rounds_fused", fp.rounds,
                                 "rounds_chosen"))
                csv_rows.append((key + "/rounds_serial",
                                 sum(pl.rounds for pl in fp.plans),
                                 "k_separate_scans"))
                csv_rows.append((key + "/round_counts",
                                 f"{fp.rounds}=={single.rounds}"
                                 if fp.fused else "serial",
                                 "fused_equals_single_scan"))
                if fp.fused and fp.rounds != single.rounds:
                    drift.append((key, {"fused_rounds": fp.rounds,
                                        "single_rounds": single.rounds}))
                if check:
                    res = fp.verify()
                    if not res["ok"]:
                        drift.append((key, res))
    if check and drift:
        raise SystemExit(
            f"plan/measurement drift in {len(drift)} cells: {drift}")
    return csv_rows


def write_json(rows: list, path: str, profile) -> None:
    """Machine-readable benchmark output (BENCH_plan_table.json): the
    CSV rows plus the pricing provenance that produced them."""
    from repro.core.benchmeta import bench_metadata

    with open(path, "w") as f:
        json.dump({
            "meta": bench_metadata(),
            "schema_version": 1,
            "benchmark": "plan_table",
            "profile": profile.provenance(),
            "rows": [[k, v, note] for k, v, note in rows],
        }, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail if any plan disagrees with the "
                         "simulator-executed schedule, or a fitted "
                         "profile flips a pinned small-m 123 decision "
                         "(CI smoke)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print plan-cache hit/miss counters")
    ap.add_argument("--profile", default=None,
                    help="calibrated CostProfile: a profile_*.json "
                         "file or a store directory (latest wins)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=None, metavar="PATH",
                    help=f"also write rows as JSON "
                         f"(default {DEFAULT_JSON})")
    args = ap.parse_args()
    prof = _load_profile(args.profile)
    rows = run([], check=args.check, profile=prof)
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.json:
        write_json(rows, args.json, prof)
        print(f"wrote {args.json}")
    if args.verbose:
        info = scan_api.plan_cache_info()
        print(f"plan_cache,hits={info['hits']},misses={info['misses']},"
              f"size={info['size']}")
