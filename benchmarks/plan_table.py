"""Planner decision table: which algorithm ``"auto"`` picks per (p, m).

No devices, no tracing: for each rank count p and payload size m the
rows give the chosen algorithm, its planner-chosen segment count S
(the pipelined ring splits the payload into S blocks and streams them
through p−2+S neighbour rounds), predicted rounds and cost-model
latency under both interconnect tiers (ICI intra-pod, DCI cross-pod;
launch/mesh.py parameters), plus the rounds *measured* by executing the
chosen plan's schedule in the numpy simulator executor — plan vs
measurement drift is visible in the table and fails the build in
``--check`` mode (CI smoke).  This is the paper's "regimes" story made
executable: 123-doubling owns the small-m rows, the pipelined
segmented ring takes over as m grows.
"""

from __future__ import annotations

import argparse

from repro.core import schedule as schedule_lib
from repro.core.scan_api import ScanSpec, plan
from repro.launch.mesh import DCI_COST, ICI_COST

PS = (8, 36, 256, 512)
MS = (8, 1024, 65_536, 1_048_576, 16_777_216)  # payload bytes

TIERS = (("ici", ICI_COST), ("dci", DCI_COST))


def run(csv_rows: list, check: bool = False):
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    drift = []
    for tier, cm in TIERS:
        for p in PS:
            for m in MS:
                pl = plan(spec, p=p, nbytes=m, cost_model=cm)
                res = schedule_lib.verify_plan(pl)
                key = f"plan/{tier}/p{p}/m{m}"
                csv_rows.append((key + "/algorithm", pl.algorithm,
                                 "auto_choice"))
                csv_rows.append((key + "/segments", pl.segments,
                                 "pipeline_S"))
                csv_rows.append((key + "/rounds", pl.rounds, "rounds"))
                csv_rows.append((key + "/rounds_measured",
                                 res["rounds_measured"],
                                 "simulator_executor"))
                csv_rows.append((key + "/cost_us", pl.cost * 1e6,
                                 "us_abg_model"))
                if not res["ok"]:
                    drift.append((key, res))
    if check and drift:
        raise SystemExit(
            f"plan/measurement drift in {len(drift)} cells: {drift}")
    return csv_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail if any plan disagrees with the "
                         "simulator-executed schedule (CI smoke)")
    args = ap.parse_args()
    for r in run([], check=args.check):
        print(",".join(str(x) for x in r))
