"""Planner decision table: which algorithm ``"auto"`` picks per (p, m).

No devices, no tracing: for each rank count p and payload size m the
rows give the chosen algorithm, its planner-chosen segment count S
(the pipelined ring splits the payload into S blocks and streams them
through p−2+S neighbour rounds), predicted rounds and cost-model
latency under both interconnect tiers (ICI intra-pod, DCI cross-pod;
launch/mesh.py parameters), plus the rounds *measured* by executing the
chosen plan's schedule in the numpy simulator executor — plan vs
measurement drift is visible in the table and fails the build in
``--check`` mode (CI smoke).  This is the paper's "regimes" story made
executable: 123-doubling owns the small-m rows, the pipelined
segmented ring takes over as m grows.

Three further sections cover the composition/fusion refactor:

  * ``plan2d/…`` — composed multi-axis plans (ONE axis-annotated
    schedule), simulator-verified like the single-axis rows;
  * ``fused/…`` — k concurrent small scans fused vs serial: the
    ``rounds_fused`` column shows the single-scan round count the
    packed payload rides (not k×), ``rounds_serial`` what k separate
    scans would pay, and ``--check`` executes the fused schedule;
  * ``--verbose`` prints :func:`scan_api.plan_cache_info` — the table
    itself exercises the plan cache heavily.
"""

from __future__ import annotations

import argparse

from repro.core import scan_api
from repro.core import schedule as schedule_lib
from repro.core.scan_api import ScanSpec, plan, plan_fused
from repro.launch.mesh import DCI_COST, ICI_COST

PS = (8, 36, 256, 512)
MS = (8, 1024, 65_536, 1_048_576, 16_777_216)  # payload bytes

# composed multi-axis cells: (major, minor) rank grids
PS_2D = ((2, 8), (2, 36), (4, 64))
MS_2D = (8, 65_536)

# fused cells: k concurrent same-axis scans of m bytes each
FUSED_K = 4
MS_FUSED = (8, 1024, 1_048_576)

TIERS = (("ici", ICI_COST), ("dci", DCI_COST))


def run(csv_rows: list, check: bool = False):
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    drift = []
    for tier, cm in TIERS:
        for p in PS:
            for m in MS:
                pl = plan(spec, p=p, nbytes=m, cost_model=cm)
                res = schedule_lib.verify_plan(pl)
                key = f"plan/{tier}/p{p}/m{m}"
                csv_rows.append((key + "/algorithm", pl.algorithm,
                                 "auto_choice"))
                csv_rows.append((key + "/segments", pl.segments,
                                 "pipeline_S"))
                csv_rows.append((key + "/rounds", pl.rounds, "rounds"))
                csv_rows.append((key + "/rounds_measured",
                                 res["rounds_measured"],
                                 "simulator_executor"))
                csv_rows.append((key + "/cost_us", pl.cost * 1e6,
                                 "us_abg_model"))
                if not res["ok"]:
                    drift.append((key, res))
    # composed multi-axis plans: one schedule, drift-checked like the
    # single-axis rows (kind "exclusive" and the fused "scan_total")
    spec2 = spec.over(("pod", "data"))
    for tier, cm in TIERS:
        for p1, p2 in PS_2D:
            for m in MS_2D:
                for kind in ("exclusive", "scan_total"):
                    pl = plan(spec2.over(spec2.axis_name, kind=kind),
                              p=(p1, p2), nbytes=m, cost_model=cm)
                    res = schedule_lib.verify_plan(pl)
                    key = f"plan2d/{tier}/{kind}/p{p1}x{p2}/m{m}"
                    csv_rows.append((key + "/algorithm", pl.algorithm,
                                     "composite"))
                    csv_rows.append((key + "/rounds", pl.rounds,
                                     "rounds"))
                    csv_rows.append((key + "/rounds_measured",
                                     res["rounds_measured"],
                                     "simulator_executor"))
                    if not res["ok"]:
                        drift.append((key, res))
    # fused vs serial: k concurrent small scans ride ONE schedule's
    # rounds when the α saving beats the packed payload's β cost
    for tier, cm in TIERS:
        for p in PS:
            for m in MS_FUSED:
                fp = plan_fused([spec] * FUSED_K, p, [m] * FUSED_K,
                                cost_model=cm)
                single = plan(spec, p=p, nbytes=m * FUSED_K,
                              cost_model=cm)
                key = f"fused/{tier}/p{p}/m{m}/k{FUSED_K}"
                csv_rows.append((key + "/fused", int(fp.fused),
                                 "fuse_decision"))
                csv_rows.append((key + "/rounds_fused", fp.rounds,
                                 "rounds_chosen"))
                csv_rows.append((key + "/rounds_serial",
                                 sum(pl.rounds for pl in fp.plans),
                                 "k_separate_scans"))
                csv_rows.append((key + "/round_counts",
                                 f"{fp.rounds}=={single.rounds}"
                                 if fp.fused else "serial",
                                 "fused_equals_single_scan"))
                if fp.fused and fp.rounds != single.rounds:
                    drift.append((key, {"fused_rounds": fp.rounds,
                                        "single_rounds": single.rounds}))
                if check:
                    res = fp.verify()
                    if not res["ok"]:
                        drift.append((key, res))
    if check and drift:
        raise SystemExit(
            f"plan/measurement drift in {len(drift)} cells: {drift}")
    return csv_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail if any plan disagrees with the "
                         "simulator-executed schedule (CI smoke)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print plan-cache hit/miss counters")
    args = ap.parse_args()
    for r in run([], check=args.check):
        print(",".join(str(x) for x in r))
    if args.verbose:
        info = scan_api.plan_cache_info()
        print(f"plan_cache,hits={info['hits']},misses={info['misses']},"
              f"size={info['size']}")
