"""Execution-engine benchmark: trace size, compile time, walltime.

The compiled-round-table claim (DESIGN §7) made measurable: for each
registered exclusive algorithm at p ∈ {8, 64, 256} this emits

  * ``trace_eqns``   — jaxpr equation count of the traced SPMD program
    (including nested sub-jaxprs, so a rolled ``lax.scan`` body counts
    once and an unrolled ring pays per round) and the trace seconds;
  * ``compile_seconds`` — XLA compile time of the jitted ``shard_map``
    program (the p=256 *unrolled* ring is tens of seconds — the
    reason the round-table executor exists — so that cell is opt-in
    via ``--full``);
  * ``simulated_seconds`` — the deterministic simulated clock of
    :func:`repro.core.tune.measure_schedule_simulated` under the
    default ICI pricing (device-free walltime proxy, reproducible in
    CI).

The segmented ring is measured in BOTH executor modes (``rolled``:
the single-``lax.scan`` round table; ``unrolled``: one trace site per
round), so the win is a ratio in the same JSON, not a claim.

``--check`` is the CI trace-size budget gate: the p=256 ring's rolled
trace must stay under ``TRACE_EQ_BUDGET`` equations and beat the
unrolled trace by at least ``MIN_ROLLED_WIN``× (the acceptance floor
is 5×; measured is >100×).

Each p needs its own fake-device count, which jax fixes at first
initialization — so the parent process spawns one worker subprocess
per p (``--worker``) and aggregates their rows into
``BENCH_exec.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_JSON = "BENCH_exec.json"
PS = (8, 64, 256)
ALGS = ("123", "1doubling", "two_op", "native", "ring")
PAYLOAD_ELEMS = 256  # int64 -> 2 KiB per rank
TRACE_EQ_BUDGET = 256  # p=256 rolled-ring trace ceiling (measured: ~92)
MIN_ROLLED_WIN = 5.0  # acceptance floor for unrolled/rolled eq ratio
# compile timing runs everywhere EXCEPT the p=256 unrolled ring
# (~30 s of XLA time proving the point; enable with --full)
SLOW_COMPILE_P = 256

MARK = "BENCH_EXEC_ROWS "


def worker(p: int, full: bool) -> list[dict]:
    import numpy as np

    import jax
    from jax import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P_

    from repro.core import monoid as monoid_lib
    from repro.core import schedule as schedule_lib
    from repro.core import tune
    from repro.core.scan_api import ScanSpec, plan
    from repro.launch import mesh as mesh_lib

    assert len(jax.devices()) >= p, (len(jax.devices()), p)
    m = monoid_lib.ADD
    x = np.arange(p * PAYLOAD_ELEMS, dtype=np.int64).reshape(
        p, PAYLOAD_ELEMS)
    nbytes = x[0].nbytes
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("x",))
    truth = mesh_lib.DEFAULT_PROFILE.model("ici")
    rows = []
    for alg in ALGS:
        pl = plan(ScanSpec(kind="exclusive", algorithm=alg), p=p,
                  nbytes=nbytes)
        sched = pl.schedule()
        sim_seconds, _ = tune.measure_schedule_simulated(
            sched, nbytes, truth)
        modes = (("rolled", False), ("unrolled", True)) \
            if alg == "ring" else (("rolled", False),)
        for mode, unrolled in modes:
            ex = schedule_lib.SPMDExecutor("x", unrolled=unrolled)
            fn = shard_map(lambda v: ex.execute(sched, v, m),
                           mesh=mesh, in_specs=P_("x"),
                           out_specs=P_("x"))
            t0 = time.perf_counter()
            eqs = schedule_lib.jaxpr_eqn_count(jax.make_jaxpr(fn)(x))
            trace_s = time.perf_counter() - t0
            row = {
                "p": p, "algorithm": alg, "mode": mode,
                "segments": pl.segments, "rounds": pl.rounds,
                "payload_bytes": nbytes, "trace_eqns": eqs,
                "trace_seconds": trace_s,
                "simulated_seconds": sim_seconds,
            }
            if full or not (unrolled and p >= SLOW_COMPILE_P):
                t0 = time.perf_counter()
                jax.jit(fn).lower(x).compile()
                row["compile_seconds"] = time.perf_counter() - t0
            rows.append(row)
    return rows


_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _spawn_worker(p: int, full: bool) -> list[dict]:
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
    from repro.launch.mesh import fake_device_env

    env = fake_device_env(p)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           str(p)]
    if full:
        cmd.append("--full")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise SystemExit(
            f"exec_bench worker p={p} failed (rc={proc.returncode})\n"
            f"{proc.stdout}\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith(MARK):
            return json.loads(line[len(MARK):])
    raise SystemExit(f"worker p={p} emitted no rows:\n{proc.stdout}")


def check(rows: list[dict]) -> list[str]:
    """The trace-size budget gate (CI): p=256 rolled ring under the
    fixed equation ceiling AND >= MIN_ROLLED_WIN x smaller than the
    unrolled trace of the same schedule."""
    failures = []
    by = {(r["p"], r["algorithm"], r["mode"]): r for r in rows}
    rolled = by.get((256, "ring", "rolled"))
    unrolled = by.get((256, "ring", "unrolled"))
    if rolled is None or unrolled is None:
        return [f"missing p=256 ring rows (have {sorted(by)})"]
    if rolled["trace_eqns"] > TRACE_EQ_BUDGET:
        failures.append(
            f"p=256 rolled ring trace {rolled['trace_eqns']} eqns "
            f"exceeds budget {TRACE_EQ_BUDGET}")
    ratio = unrolled["trace_eqns"] / max(rolled["trace_eqns"], 1)
    if ratio < MIN_ROLLED_WIN:
        failures.append(
            f"rolled trace win {ratio:.1f}x below the "
            f"{MIN_ROLLED_WIN}x floor "
            f"({unrolled['trace_eqns']} -> {rolled['trace_eqns']})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Trace-size / compile-time / simulated-walltime "
                    "benchmark of the schedule executors.")
    ap.add_argument("--worker", type=int, default=None,
                    help="internal: run one device-count cell")
    ap.add_argument("--ps", type=lambda s: tuple(
        int(t) for t in s.split(",") if t), default=PS,
        help="comma-separated rank counts (default 8,64,256)")
    ap.add_argument("--full", action="store_true",
                    help="also compile the p=256 unrolled ring "
                         "(tens of seconds of XLA time)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the p=256 rolled-ring trace is "
                         "under the equation budget and >=5x smaller "
                         "than unrolled (CI gate)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=None, metavar="PATH",
                    help=f"write rows as JSON (default {DEFAULT_JSON})")
    args = ap.parse_args(argv)

    if args.worker is not None:
        rows = worker(args.worker, args.full)
        print(MARK + json.dumps(rows))
        return 0

    rows = []
    for p in args.ps:
        rows.extend(_spawn_worker(p, args.full))
    for r in rows:
        key = f"exec/{r['algorithm']}/{r['mode']}/p{r['p']}"
        print(f"{key}/trace_eqns,{r['trace_eqns']},jaxpr_equations")
        print(f"{key}/trace_s,{r['trace_seconds']:.3f},seconds")
        if "compile_seconds" in r:
            print(f"{key}/compile_s,{r['compile_seconds']:.3f},"
                  f"seconds")
        print(f"{key}/simulated_us,{r['simulated_seconds'] * 1e6:.2f},"
              f"default_ici_clock")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": 1, "benchmark": "exec_bench",
                       "trace_eq_budget": TRACE_EQ_BUDGET,
                       "rows": rows}, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        failures = check(rows)
        if failures:
            raise SystemExit("trace-budget gate failed: "
                             + "; ".join(failures))
        print("trace-budget gate OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
