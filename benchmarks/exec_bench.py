"""Execution-engine benchmark: trace size, compile time, walltime.

The compiled-round-table claim (DESIGN §7) made measurable: for each
registered exclusive algorithm at p ∈ {8, 64, 256} this emits

  * ``trace_eqns``   — jaxpr equation count of the traced SPMD program
    (including nested sub-jaxprs, so a rolled ``lax.scan`` body counts
    once and an unrolled ring pays per round) and the trace seconds;
  * ``compile_seconds`` — XLA compile time of the jitted ``shard_map``
    program (the p=256 *unrolled* ring is tens of seconds — the
    reason the round-table executor exists — so that cell is opt-in
    via ``--full``);
  * ``simulated_seconds`` — the deterministic simulated clock of
    :func:`repro.core.tune.measure_schedule_simulated` under the
    default ICI pricing (device-free walltime proxy, reproducible in
    CI).

The segmented ring is measured in BOTH executor modes (``rolled``:
the single-``lax.scan`` round table; ``unrolled``: one trace site per
round), so the win is a ratio in the same JSON, not a claim.

At p = 64 the fused Pallas round path (DESIGN §7) is measured against
its per-round ``block_combine`` baseline: the pinned S=8 segmented
ring and the fused-doubling scan_total run under
``PallasExecutor(fused=True)`` and ``fused=False``, recording the
kernel-launch and HBM-pass counts from ``collect_stats()`` (asserted
equal to the IR's ``Schedule.kernel_passes``/``kernel_launches``
prediction), the interpret-mode execution walltime, and the bitwise
drift against the SPMD executor on the same int64 payload.

``--check`` is the CI gate: the p=256 ring's rolled trace must stay
under ``TRACE_EQ_BUDGET`` equations and beat the unrolled trace by at
least ``MIN_ROLLED_WIN``×, AND the fused Pallas path must cost at
least ``MIN_FUSED_PASS_WIN``× fewer HBM passes than baseline on the
p=64 S=8 ring, launch fewer kernels than baseline on the p=64
scan_total, match the IR prediction exactly, and show zero drift.

Each p needs its own fake-device count, which jax fixes at first
initialization — so the parent process spawns one worker subprocess
per p (``--worker``) and aggregates their rows into
``BENCH_exec.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_JSON = "BENCH_exec.json"
PS = (8, 64, 256)
ALGS = ("123", "1doubling", "two_op", "native", "ring",
        "halving", "quartering", "reduce_scatter")
PAYLOAD_ELEMS = 256  # int64 -> 2 KiB per rank
TRACE_EQ_BUDGET = 256  # p=256 rolled-ring trace ceiling (measured: ~92)
MIN_ROLLED_WIN = 5.0  # acceptance floor for unrolled/rolled eq ratio
PALLAS_P = 64  # fused-vs-baseline Pallas cell (ISSUE acceptance point)
PALLAS_RING_S = 8  # pinned ring segment count for the pass-count gate
MIN_FUSED_PASS_WIN = 2.0  # baseline/fused HBM-pass floor (measured 2.0)
# compile timing runs everywhere EXCEPT the p=256 unrolled ring
# (~30 s of XLA time proving the point; enable with --full)
SLOW_COMPILE_P = 256

MARK = "BENCH_EXEC_ROWS "


def worker(p: int, full: bool) -> list[dict]:
    import numpy as np

    import jax
    from jax import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P_

    from repro.core import monoid as monoid_lib
    from repro.core import schedule as schedule_lib
    from repro.core import tune
    from repro.core.scan_api import ScanSpec, plan
    from repro.launch import mesh as mesh_lib

    assert len(jax.devices()) >= p, (len(jax.devices()), p)
    m = monoid_lib.ADD
    x = np.arange(p * PAYLOAD_ELEMS, dtype=np.int64).reshape(
        p, PAYLOAD_ELEMS)
    nbytes = x[0].nbytes
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("x",))
    truth = mesh_lib.DEFAULT_PROFILE.model("ici")
    rows = []
    for alg in ALGS:
        pl = plan(ScanSpec(kind="exclusive", algorithm=alg), p=p,
                  nbytes=nbytes)
        sched = pl.schedule()
        sim_seconds, _ = tune.measure_schedule_simulated(
            sched, nbytes, truth)
        modes = (("rolled", False), ("unrolled", True)) \
            if alg == "ring" else (("rolled", False),)
        for mode, unrolled in modes:
            ex = schedule_lib.SPMDExecutor("x", unrolled=unrolled)
            fn = shard_map(lambda v: ex.execute(sched, v, m),
                           mesh=mesh, in_specs=P_("x"),
                           out_specs=P_("x"))
            t0 = time.perf_counter()
            eqs = schedule_lib.jaxpr_eqn_count(jax.make_jaxpr(fn)(x))
            trace_s = time.perf_counter() - t0
            row = {
                "p": p, "algorithm": alg, "mode": mode,
                "segments": pl.segments, "rounds": pl.rounds,
                "payload_bytes": nbytes, "trace_eqns": eqs,
                "trace_seconds": trace_s,
                "simulated_seconds": sim_seconds,
            }
            if full or not (unrolled and p >= SLOW_COMPILE_P):
                t0 = time.perf_counter()
                jax.jit(fn).lower(x).compile()
                row["compile_seconds"] = time.perf_counter() - t0
            rows.append(row)
    if p == PALLAS_P:
        rows.extend(_pallas_rows(p, mesh, m, x, nbytes))
    return rows


def _pallas_rows(p: int, mesh, m, x, nbytes: int) -> list[dict]:
    """Fused-vs-baseline Pallas rows at the acceptance point p=64.

    Two schedules: the pinned S=8 segmented ring (the pass-count gate
    — launches are EQUAL between modes there, the fusion win is one
    sweep per prep round instead of two) and the fused-doubling
    scan_total (the launch-count gate — fused batches each round's
    (payload, total) registers into ONE ``pallas_call``).  Kernel
    stats are read from ``collect_stats()`` over the trace and checked
    against the IR prediction; outputs are compared bitwise against
    the SPMD executor on the same int64 payload."""
    import numpy as np

    import jax
    from jax import shard_map
    from jax.sharding import PartitionSpec as P_

    from repro.core import schedule as schedule_lib
    from repro.core.scan_api import ScanSpec, plan

    rows = []
    cases = (
        ("ring", plan(ScanSpec(kind="exclusive", algorithm="ring",
                               segments=PALLAS_RING_S),
                      p=p, nbytes=nbytes)),
        ("fused_doubling", plan(ScanSpec(kind="scan_total",
                                         algorithm="fused_doubling"),
                                p=p, nbytes=nbytes)),
    )
    for alg, pl_ in cases:
        sched = pl_.schedule()
        ref_fn = shard_map(
            lambda v, s=sched: schedule_lib.SPMDExecutor("x").execute(
                s, v, m),
            mesh=mesh, in_specs=P_("x"), out_specs=P_("x"))
        ref = jax.tree.map(np.asarray, jax.jit(ref_fn)(x))
        for mode, fused in (("pallas_fused", True),
                            ("pallas_baseline", False)):
            ex = schedule_lib.PallasExecutor("x", interpret=True,
                                             fused=fused)
            fn = shard_map(lambda v, e=ex, s=sched: e.execute(s, v, m),
                           mesh=mesh, in_specs=P_("x"),
                           out_specs=P_("x"), check_vma=False)
            with schedule_lib.collect_stats() as st:
                jax.make_jaxpr(fn)(x)
            compiled = jax.jit(fn).lower(x).compile()
            t0 = time.perf_counter()
            out = jax.block_until_ready(compiled(x))
            wall = time.perf_counter() - t0
            drift = max(
                (int(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                 if np.asarray(a).size else 0)
                for a, b in zip(jax.tree.leaves(out),
                                jax.tree.leaves(ref)))
            rows.append({
                "p": p, "algorithm": alg, "mode": mode,
                "segments": pl_.segments, "rounds": pl_.rounds,
                "payload_bytes": nbytes,
                "kernel_launches": st.kernel_launches,
                "hbm_passes": st.hbm_passes,
                "predicted_launches": sched.kernel_launches(
                    m.commutative, fused=fused),
                "predicted_passes": sched.kernel_passes(
                    m.commutative, fused=fused),
                "plan_kernel_passes": pl_.kernel_passes,
                "exec_seconds": wall,
                "max_drift": drift,
            })
    return rows


_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _spawn_worker(p: int, full: bool) -> list[dict]:
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
    from repro.launch.mesh import fake_device_env

    env = fake_device_env(p)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           str(p)]
    if full:
        cmd.append("--full")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise SystemExit(
            f"exec_bench worker p={p} failed (rc={proc.returncode})\n"
            f"{proc.stdout}\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith(MARK):
            return json.loads(line[len(MARK):])
    raise SystemExit(f"worker p={p} emitted no rows:\n{proc.stdout}")


def check(rows: list[dict]) -> list[str]:
    """The CI gates: (1) trace-size budget — p=256 rolled ring under
    the fixed equation ceiling AND >= MIN_ROLLED_WIN x smaller than
    the unrolled trace of the same schedule; (2) fused-kernel budget —
    at p=64 the fused Pallas path pays >= MIN_FUSED_PASS_WIN x fewer
    HBM passes than baseline on the S=8 ring, strictly fewer kernel
    launches on the scan_total butterfly, matches the IR's
    kernel_launches/kernel_passes prediction exactly, and drifts zero
    bits from the SPMD executor."""
    failures = []
    by = {(r["p"], r["algorithm"], r["mode"]): r for r in rows}
    rolled = by.get((256, "ring", "rolled"))
    unrolled = by.get((256, "ring", "unrolled"))
    if rolled is None or unrolled is None:
        return [f"missing p=256 ring rows (have {sorted(by)})"]
    if rolled["trace_eqns"] > TRACE_EQ_BUDGET:
        failures.append(
            f"p=256 rolled ring trace {rolled['trace_eqns']} eqns "
            f"exceeds budget {TRACE_EQ_BUDGET}")
    ratio = unrolled["trace_eqns"] / max(rolled["trace_eqns"], 1)
    if ratio < MIN_ROLLED_WIN:
        failures.append(
            f"rolled trace win {ratio:.1f}x below the "
            f"{MIN_ROLLED_WIN}x floor "
            f"({unrolled['trace_eqns']} -> {rolled['trace_eqns']})")
    failures.extend(_check_pallas(by))
    return failures


def _check_pallas(by: dict) -> list[str]:
    failures = []
    cells = {(alg, mode): by.get((PALLAS_P, alg, mode))
             for alg in ("ring", "fused_doubling")
             for mode in ("pallas_fused", "pallas_baseline")}
    missing = sorted(k for k, v in cells.items() if v is None)
    if missing:
        return [f"missing p={PALLAS_P} pallas rows: {missing}"]
    for (alg, mode), r in cells.items():
        tag = f"p={PALLAS_P} {alg} {mode}"
        if r["kernel_launches"] != r["predicted_launches"] \
                or r["hbm_passes"] != r["predicted_passes"]:
            failures.append(
                f"{tag}: measured kernel stats "
                f"({r['kernel_launches']}L/{r['hbm_passes']}P) != IR "
                f"prediction ({r['predicted_launches']}L/"
                f"{r['predicted_passes']}P)")
        if r["max_drift"] != 0:
            failures.append(
                f"{tag}: nonzero drift {r['max_drift']} vs SPMD")
    ring_f = cells[("ring", "pallas_fused")]
    ring_b = cells[("ring", "pallas_baseline")]
    win = ring_b["hbm_passes"] / max(ring_f["hbm_passes"], 1)
    if win < MIN_FUSED_PASS_WIN:
        failures.append(
            f"fused ring pass win {win:.2f}x below the "
            f"{MIN_FUSED_PASS_WIN}x floor "
            f"({ring_b['hbm_passes']} -> {ring_f['hbm_passes']})")
    st_f = cells[("fused_doubling", "pallas_fused")]
    st_b = cells[("fused_doubling", "pallas_baseline")]
    if st_f["kernel_launches"] >= st_b["kernel_launches"]:
        failures.append(
            f"fused scan_total launches {st_f['kernel_launches']} not "
            f"below baseline {st_b['kernel_launches']}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Trace-size / compile-time / simulated-walltime "
                    "benchmark of the schedule executors.")
    ap.add_argument("--worker", type=int, default=None,
                    help="internal: run one device-count cell")
    ap.add_argument("--ps", type=lambda s: tuple(
        int(t) for t in s.split(",") if t), default=PS,
        help="comma-separated rank counts (default 8,64,256)")
    ap.add_argument("--full", action="store_true",
                    help="also compile the p=256 unrolled ring "
                         "(tens of seconds of XLA time)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the p=256 rolled-ring trace is "
                         "under the equation budget and >=5x smaller "
                         "than unrolled, AND the p=64 fused Pallas "
                         "path beats baseline (>=2x fewer ring HBM "
                         "passes, fewer scan_total launches, zero "
                         "drift) (CI gate)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=None, metavar="PATH",
                    help=f"write rows as JSON (default {DEFAULT_JSON})")
    args = ap.parse_args(argv)

    if args.worker is not None:
        rows = worker(args.worker, args.full)
        print(MARK + json.dumps(rows))
        return 0

    rows = []
    for p in args.ps:
        rows.extend(_spawn_worker(p, args.full))
    for r in rows:
        key = f"exec/{r['algorithm']}/{r['mode']}/p{r['p']}"
        if r["mode"].startswith("pallas_"):
            print(f"{key}/kernel_launches,{r['kernel_launches']},"
                  f"pallas_calls")
            print(f"{key}/hbm_passes,{r['hbm_passes']},payload_sweeps")
            print(f"{key}/exec_s,{r['exec_seconds']:.3f},"
                  f"interpret_walltime")
            print(f"{key}/max_drift,{r['max_drift']},bits_vs_spmd")
            continue
        print(f"{key}/trace_eqns,{r['trace_eqns']},jaxpr_equations")
        print(f"{key}/trace_s,{r['trace_seconds']:.3f},seconds")
        if "compile_seconds" in r:
            print(f"{key}/compile_s,{r['compile_seconds']:.3f},"
                  f"seconds")
        print(f"{key}/simulated_us,{r['simulated_seconds'] * 1e6:.2f},"
              f"default_ici_clock")
    if args.json:
        from repro.core.benchmeta import bench_metadata

        with open(args.json, "w") as f:
            json.dump({"meta": bench_metadata(),
                       "schema_version": 2, "benchmark": "exec_bench",
                       "trace_eq_budget": TRACE_EQ_BUDGET,
                       "min_fused_pass_win": MIN_FUSED_PASS_WIN,
                       "rows": rows}, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        failures = check(rows)
        if failures:
            raise SystemExit("exec-bench gate failed: "
                             + "; ".join(failures))
        print("exec-bench gate OK (trace budget + fused kernel win)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
