"""Context-parallel SSM prefill benchmark: cross-device state carry via
each exscan algorithm (8 fake CPU devices, sequence sharded).

The AFFINE ⊕ here composes (decay, state) pairs — the "expensive
operator" case where the 123-doubling algorithm's q-1 applications beat
two-⊕ doubling's ~2·log2(p).  Algorithms are pinned per run through
``ScanSpec`` (plus ``"auto"``, showing the planner's pick)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

ALGS = ("auto", "123", "1doubling", "two_op")

_CODE = """
import time, json
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.scan_api import ScanSpec
from repro.models.context_parallel import cp_ssm_scan

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(0)
B, S, D = 1, 4096, 1024
a = jnp.asarray(rng.uniform(0.9, 1.0, (B, S, D)), jnp.float32)
b = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
out = {}
for alg in %s:
    spec = ScanSpec(kind="exclusive", monoid="affine", algorithm=alg)
    with jax.set_mesh(mesh):
        f = jax.jit(lambda x, y: cp_ssm_scan(x, y, mesh, spec=spec))
        jax.block_until_ready(f(a, b))
        ts = []
        for _ in range(10):
            t0 = time.perf_counter()
            jax.block_until_ready(f(a, b))
            ts.append(time.perf_counter() - t0)
    out[alg] = min(ts) * 1e6
print("RESULT" + json.dumps(out))
"""


def run(csv_rows: list):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CODE % repr(list(ALGS))],
                          env=env, capture_output=True, text=True,
                          timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT"):])
    for alg, us in res.items():
        csv_rows.append((f"cp_ssm_prefill_p8/{alg}", us,
                         "us_wallclock_cpu"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
