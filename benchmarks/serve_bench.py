"""Scan-service benchmark: fused-batching round win and p50/p99 vs rate.

Drives :class:`repro.serve.ScanService` with the two real request
classes (MoE dispatch scan_totals and compression-offset scalar
exscans, from ``repro.serve.workloads``) in two phases:

  * **burst** — every request submitted at t=0, drained.  This is the
    deterministic cell the CI gate reads: occupancy is maximal, so the
    fused-round win (serial-equivalent rounds / executed rounds) is a
    pure property of the schedules, not of machine speed.
  * **rate sweep** — open-loop Poisson arrivals at each swept rate
    under the service's virtual clock (execution seconds are measured
    for real and pushed onto the clock), reporting queue depth, batch
    occupancy and p50/p99 latency *from nominal arrival time* — the
    service stamps ``t_submit`` at the clock when the batcher observes
    the request, so the bench keeps its own arrival map to charge
    queueing delay honestly.

``--check`` is the CI serving gate: zero post-warmup plan compiles
across ALL phases (the warmup contract of DESIGN §8) and a burst-phase
fused round win of at least ``MIN_FUSED_ROUND_WIN``× over serving the
same requests serially.
"""

from __future__ import annotations

import argparse
import json

DEFAULT_JSON = "BENCH_serve.json"
P = 8
MOE_ARCH = "qwen2_moe_a2_7b"
MAX_BATCH = 8
N_BURST = 48
RATES = (500.0, 5000.0, 50000.0)  # req/s: under / near / over capacity
N_PER_RATE = 200
MOE_POOL = 8  # distinct MoE payloads cycled through (routing is slow)
MIN_FUSED_ROUND_WIN = 2.0  # CI floor; measured ~5x at max_batch=8


def _make_service_and_traffic(seed: int = 0):
    import numpy as np

    from repro import configs
    from repro.serve import ScanService, workloads

    cfg = configs.get_smoke(MOE_ARCH)
    rng = np.random.default_rng(seed)
    buckets = [workloads.moe_bucket(cfg), workloads.compression_bucket()]
    svc = ScanService(P, buckets, max_batch=MAX_BATCH,
                      max_queue=4 * MAX_BATCH * len(buckets))
    moe_pool = [workloads.moe_dispatch_payload(cfg, P, rng, n_tokens=32)
                for _ in range(MOE_POOL)]
    comp_pool = workloads.compression_offset_payloads(
        P, [100, 2_000, 50, 7, 65_536], 0.01, rng=rng, thresholded=True)

    def traffic(n):
        """n (kind, payload) pairs, MoE and compression interleaved."""
        out = []
        for i in range(n):
            if rng.random() < 0.5:
                out.append(("scan_total", moe_pool[i % len(moe_pool)]))
            else:
                out.append(("exclusive", comp_pool[i % len(comp_pool)]))
        return out

    return svc, traffic, rng


def _phase_row(svc, phase: str, extra: dict) -> dict:
    row = {"phase": phase, "p": P, "max_batch": MAX_BATCH,
           "post_warmup_compiles": svc.post_warmup_compiles}
    row.update(svc.metrics.snapshot())
    row.update(extra)
    return row


def run_burst(svc, traffic) -> dict:
    svc.reset_metrics()
    reqs = [svc.submit(payload, kind=kind, now=0.0)
            for kind, payload in traffic(N_BURST)]
    svc.drain()
    assert all(r.status == "done" for r in reqs)
    return _phase_row(svc, "burst", {"n": N_BURST, "rate": None})


def run_rate(svc, traffic, rng, rate: float) -> dict:
    from repro.serve import AdmissionError, workloads
    from repro.serve.metrics import percentile

    svc.reset_metrics()
    arrivals = workloads.poisson_arrivals(rng, rate, N_PER_RATE)
    arrivals += svc.now  # the clock is monotone across phases
    items = traffic(N_PER_RATE)
    arrival_of: dict[int, float] = {}
    finalized = []
    i = 0
    while i < N_PER_RATE or svc.depth:
        now = svc.now
        if svc.depth == 0 and i < N_PER_RATE and arrivals[i] > now:
            now = float(arrivals[i])  # idle: jump to the next arrival
        while i < N_PER_RATE and arrivals[i] <= now:
            kind, payload = items[i]
            try:
                req = svc.submit(payload, kind=kind, now=now)
                arrival_of[req.rid] = float(arrivals[i])
            except AdmissionError:
                pass  # overload backpressure; counted in metrics
            i += 1
        finalized.extend(svc.tick(now))
    lat = [r.t_done - arrival_of[r.rid] for r in finalized
           if r.status == "done"]
    return _phase_row(svc, "rate", {
        "n": N_PER_RATE, "rate": rate,
        "arrival_latency_p50_s": percentile(lat, 50),
        "arrival_latency_p99_s": percentile(lat, 99),
    })


def check(rows: list[dict]) -> list[str]:
    """The CI serving gate (burst determinism + warmup contract)."""
    failures = []
    burst = next((r for r in rows if r["phase"] == "burst"), None)
    if burst is None:
        return ["no burst row"]
    if burst["completed"] != burst["n"]:
        failures.append(
            f"burst completed {burst['completed']}/{burst['n']}")
    win = burst["fused_round_win"]
    if not win >= MIN_FUSED_ROUND_WIN:
        failures.append(
            f"burst fused round win {win:.2f}x below the "
            f"{MIN_FUSED_ROUND_WIN}x floor "
            f"({burst['rounds_serial_equiv']} serial-equiv rounds -> "
            f"{burst['rounds_executed']} executed)")
    compiles = rows[-1]["post_warmup_compiles"]
    if compiles != 0:
        failures.append(
            f"{compiles} plan compiles after warmup (the warmup "
            f"contract requires 0 across every phase)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Continuous-batching scan-service benchmark: "
                    "fused round win and latency vs request rate.")
    ap.add_argument("--rates", type=lambda s: tuple(
        float(t) for t in s.split(",") if t), default=RATES,
        help="comma-separated request rates in req/s "
             f"(default {','.join(str(r) for r in RATES)})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="fail unless the burst phase wins >= "
                         f"{MIN_FUSED_ROUND_WIN}x rounds over serial "
                         "and zero plans compile after warmup (CI)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=None, metavar="PATH",
                    help=f"write rows as JSON (default {DEFAULT_JSON})")
    args = ap.parse_args(argv)

    svc, traffic, rng = _make_service_and_traffic(args.seed)
    warm = svc.warmup()
    print(f"warmup: {warm['fused_plans_primed']} fused plans over "
          f"{warm['buckets']} buckets "
          f"({warm['cache']['misses']} cache entries built)")

    rows = [run_burst(svc, traffic)]
    for rate in args.rates:
        rows.append(run_rate(svc, traffic, rng, rate))

    for r in rows:
        key = f"serve/{r['phase']}" + (
            f"/rate{r['rate']:g}" if r["rate"] else "")
        print(f"{key}/completed,{r['completed']},requests")
        print(f"{key}/occupancy,{r['mean_occupancy']:.2f},"
              f"requests_per_batch")
        print(f"{key}/fused_round_win,{r['fused_round_win']:.2f},"
              f"serial_over_fused_rounds")
        if r["phase"] == "rate":
            print(f"{key}/p50_ms,{r['arrival_latency_p50_s']*1e3:.3f},"
                  f"from_arrival")
            print(f"{key}/p99_ms,{r['arrival_latency_p99_s']*1e3:.3f},"
                  f"from_arrival")
            print(f"{key}/timed_out,{r['timed_out']},requests")
            print(f"{key}/rejected,"
                  f"{r['rejected_overload']},overload_backpressure")
    print(f"post-warmup plan compiles: {rows[-1]['post_warmup_compiles']}")

    if args.json:
        from repro.core.benchmeta import bench_metadata

        with open(args.json, "w") as f:
            json.dump({"meta": bench_metadata(),
                       "schema_version": 1, "benchmark": "serve_bench",
                       "p": P, "max_batch": MAX_BATCH,
                       "min_fused_round_win": MIN_FUSED_ROUND_WIN,
                       "rows": rows}, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        failures = check(rows)
        if failures:
            raise SystemExit("serving gate failed: "
                             + "; ".join(failures))
        print("serving gate OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
