"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,value,derived`` CSV.  Modules:
  * round_counts          — Theorem 1 rounds/⊕ table (exact)
  * plan_table            — ScanSpec("auto") planner decisions per
                            (p, payload, interconnect tier)
  * exscan_table1         — paper Table 1/Fig 1 analogue (measured on a
                            fake-device mesh + α-β-γ modeled for pods)
  * moe_dispatch          — in-situ MoE layer, ScanSpec algorithm sweep
  * ssm_context_parallel  — in-situ CP-SSM prefill, algorithm sweep
  * roofline summary      — from the latest dry-run JSON, if present
"""

from __future__ import annotations

import json
import os
import sys
import traceback

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(HERE), "src"))
sys.path.insert(0, os.path.dirname(HERE))

DRYRUN_JSON = os.path.join(os.path.dirname(HERE), "dryrun_results.json")


def roofline_rows(csv_rows: list):
    if not os.path.exists(DRYRUN_JSON):
        return csv_rows
    with open(DRYRUN_JSON) as f:
        cells = json.load(f)
    for c in cells:
        if c.get("status") != "ok":
            continue
        if c.get("mesh") != "16x16":
            continue  # multi-pod pass is compile-proof only (no probes)
        key = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        csv_rows.append((key + "/bound_ms",
                         1e3 * max(c["compute_s"], c["memory_s"],
                                   c["collective_s"]),
                         c["dominant"]))
        csv_rows.append((key + "/mfu_bound", c["mfu_bound"], "fraction"))
    return csv_rows


def main() -> None:
    from benchmarks import exscan_table1, moe_dispatch, plan_table, \
        round_counts, ssm_context_parallel

    rows: list = []
    modules = [
        ("round_counts", round_counts.run),
        ("plan_table", plan_table.run),
        ("exscan_table1", exscan_table1.run),
        ("moe_dispatch", moe_dispatch.run),
        ("ssm_context_parallel", ssm_context_parallel.run),
        ("roofline", roofline_rows),
    ]
    failures = 0
    for name, fn in modules:
        try:
            fn(rows)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# BENCH FAILED: {name}", file=sys.stderr)
            traceback.print_exc()
    print("name,value,derived")
    for r in rows:
        print(",".join(str(x) for x in r))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
