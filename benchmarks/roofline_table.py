"""Render the §Roofline markdown table from dry-run JSON files."""

from __future__ import annotations

import json
import os
import sys


def load(paths):
    cells = []
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                cells.extend(json.load(f))
    return cells


def fmt(cells):
    rows = []
    rows.append(
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful/HLO | MFU bound |")
    rows.append("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("status") == "skipped":
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"skip: {c['reason'][:40]}… | — | — |")
            continue
        if c.get("status") != "ok":
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"**FAILED** | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3f} | {c['memory_s']:.3f} "
            f"| {c['collective_s']:.3f} | {c['dominant']} "
            f"| {c.get('useful_flops_fraction', 0):.2f} "
            f"| {c.get('mfu_bound', 0):.3f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    paths = sys.argv[1:] or ["dryrun_singlepod.json", "dryrun_multipod.json"]
    print(fmt(load(paths)))
