"""In-situ MoE dispatch benchmark: full MoE layer forward wall time with
each exscan algorithm driving the global-offset collective (8 fake CPU
devices, 2 data x 4 model).  The exscan runs once per MoE layer per
step, on an (E,)-int vector — the paper's small-m regime.  The sweep is
driven through ``ScanSpec`` (including ``"auto"``, which shows what the
cost-model planner picks for this payload)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

ALGS = ("auto", "123", "1doubling", "two_op", "native")

_CODE = """
import time, json
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro import configs
from repro.core.scan_api import ScanSpec
from repro.models.model import Model

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
out = {}
rng = np.random.default_rng(0)
for alg in %s:
    cfg = configs.get_smoke(
        "qwen2_moe_a2_7b",
        scan=ScanSpec(kind="exclusive", algorithm=alg))
    m = Model(cfg, mesh)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)
    with jax.set_mesh(mesh):
        f = jax.jit(lambda p, t: m.forward(p, t)[0])
        jax.block_until_ready(f(params, tokens))
        ts = []
        for _ in range(10):
            t0 = time.perf_counter()
            jax.block_until_ready(f(params, tokens))
            ts.append(time.perf_counter() - t0)
    out[alg] = min(ts) * 1e6
print("RESULT" + json.dumps(out))
"""


def run(csv_rows: list):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CODE % repr(list(ALGS))],
                          env=env, capture_output=True, text=True,
                          timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT"):])
    for alg, us in res.items():
        csv_rows.append((f"moe_forward_p8/{alg}", us, "us_wallclock_cpu"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
