"""Online self-tuning bench: drift detection, gated installs, no thrash.

The scenario the controller exists for: a service is executing scans
under a calibrated profile when the fabric shifts — here, the "dci"
tier's per-round latency α jumps 4× mid-run (a degraded link, a
throttled NIC).  Every plan priced under the stale constants is now
wrong in exactly the paper's regime: the mid-m winner map moves.

The bench streams a fixed cycle of (tier, p, m) executions through a
:class:`repro.core.autotune.AutoTuner` under a **deterministic
simulated clock**: each execution plans under the *installed* profile
(the controller's view), then its executed schedule is priced under
the *true* constants of the moment (the fabric's view) — so a stale
profile pays real simulated seconds for its wrong algorithm choices.

Gated claims (``--check``, the CI smoke):

  * the controller detects the drift and installs a refitted profile
    within the detection budget, with fit residual under the gate;
  * the install drops stale plan-cache entries (count > 0);
  * the pinned (p, m) winner cell flips from the pre-drift to the
    post-drift algorithm through the *installed* profile;
  * total simulated walltime after convergence is within 5% of an
    oracle planner that had the true constants from the start;
  * a stable-constants control run installs NOTHING (no thrash).

Results land in ``BENCH_autotune.json`` next to the other artifacts.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

DEFAULT_JSON = "BENCH_autotune.json"

# -- scenario pins ----------------------------------------------------------

DRIFT_FACTOR = 4.0  # the dci α shift the fabric undergoes mid-run
DRIFT_AT = 96  # execution index at which the true constants shift
N_EXECUTIONS = 240
CAPACITY = 24  # per-tier reservoir bound (sliding window)
REFIT_EVERY = 12  # one workload cycle between refit attempts
GATE_DRIFT = 0.3  # install at >= ~1.4x constant change
GATE_RESIDUAL = 0.25
MIN_SAMPLES = 12
DETECT_BUDGET = 6 * CAPACITY  # executions allowed from drift to install
WALLTIME_TOLERANCE = 0.05  # post-convergence vs oracle

# The pinned winner cell: dci tier, p=8, m=256 KiB.  Under the default
# dci pricing the block-halving exscan wins (bandwidth-lean); under
# 4x α the round count dominates and two_op takes it.
PIN_P, PIN_M = 8, 262_144
PIN_PRE, PIN_POST = "halving", "two_op"

# Workload cycle: dci and ici cells interleaved, m spanning the
# α-dominated to β-dominated regimes so the NNLS sees feature spread.
_DCI_CELLS = [("pod", p, m) for p in (4, 8)
              for m in (512, 8192, 262_144)]
_ICI_CELLS = [(None, p, m) for p in (4, 8)
              for m in (512, 8192, 262_144)]
CELLS = [c for pair in zip(_DCI_CELLS, _ICI_CELLS) for c in pair]


def _shift_dci_alpha(profile, factor: float):
    return dataclasses.replace(profile, tiers=tuple(
        (n, dataclasses.replace(cm, alpha=cm.alpha * factor)
         if n == "dci" else cm)
        for n, cm in profile.tiers))


def _sim_seconds(sched, nbytes: int, cm) -> float:
    """The simulated clock: the TRUE constants priced on the executed
    schedule's exact features (same regressors the fit consumes, so
    calibration data from a known fabric recovers it exactly)."""
    from repro.core import tune

    hops, wire, op_bytes = tune.schedule_features(
        sched, nbytes, commutative=True)
    return cm.cost(hops=int(hops), serial_bytes=wire, ops=0,
                   payload_bytes=0, op_bytes=op_bytes)


def run_scenario(*, drift: bool) -> dict:
    """Stream the workload through the controller; ``drift`` selects
    the shifting-fabric scenario vs the stable-constants control."""
    from repro.core import scan_api
    from repro.core.autotune import AutoTuner, DriftGate
    from repro.launch import mesh as mesh_lib

    base = mesh_lib.DEFAULT_PROFILE
    truth_pre = base
    truth_post = _shift_dci_alpha(base, DRIFT_FACTOR) if drift else base
    spec = scan_api.ScanSpec(kind="exclusive", monoid="add")

    prev = mesh_lib.install_profile(None)
    scan_api.plan_cache_clear()
    tuner = AutoTuner(
        base,
        gate=DriftGate(drift=GATE_DRIFT, max_residual=GATE_RESIDUAL,
                       min_samples=MIN_SAMPLES),
        capacity=CAPACITY, refit_every=REFIT_EVERY,
        mesh_fingerprint="autotune-bench")
    installs: list[dict] = []
    controller_seconds: list[float] = []
    oracle_seconds: list[float] = []
    try:
        with scan_api.use_cost_model(mesh_lib.axis_cost_model):
            pin_pre = scan_api.plan(
                spec.over("pod"), PIN_P, nbytes=PIN_M).algorithm
            for i in range(N_EXECUTIONS):
                truth = truth_pre if i < DRIFT_AT else truth_post
                axis, p, m = CELLS[i % len(CELLS)]
                tier = "dci" if axis == "pod" else "ici"
                # the controller's view: plan under the installed
                # profile; the fabric's view: pay true seconds for it
                pl = scan_api.plan(spec.over(axis), p, nbytes=m)
                seconds = _sim_seconds(pl.schedule(), m,
                                       truth.model(tier))
                controller_seconds.append(seconds)
                opl = scan_api.plan(spec.over(axis), p, nbytes=m,
                                    cost_model=truth)
                oracle_seconds.append(_sim_seconds(opl.schedule(), m,
                                                   truth.model(tier)))
                tuner.record(pl.schedule(), m, seconds, tier=tier,
                             algorithm=pl.algorithm)
                res = tuner.maybe_refit()
                if res.installed:
                    installs.append({
                        "execution": i,
                        "drift": dict(res.drift),
                        "residuals": dict(res.residuals),
                        "plans_dropped": res.plans_dropped,
                    })
            pin_post = scan_api.plan(
                spec.over("pod"), PIN_P, nbytes=PIN_M).algorithm
    finally:
        mesh_lib.install_profile(prev)

    converge = installs[-1]["execution"] if installs else None
    row = {
        "scenario": "drift" if drift else "stable",
        "executions": N_EXECUTIONS,
        "drift_at": DRIFT_AT if drift else None,
        "installs": len(installs),
        "install_log": installs,
        "refits": tuner.refits,
        "plans_dropped": tuner.plans_dropped,
        "reservoirs": tuner.reservoir_sizes(),
        "pinned_cell": {"tier": "dci", "p": PIN_P, "nbytes": PIN_M,
                        "pre": pin_pre, "post": pin_post},
        "converged_at": converge,
    }
    if drift:
        row["detect_executions"] = (converge - DRIFT_AT
                                    if converge is not None else None)
        if converge is not None:
            post = slice(converge + 1, None)
            ctrl = sum(controller_seconds[post])
            orac = sum(oracle_seconds[post])
            row["post_convergence_seconds"] = ctrl
            row["oracle_seconds"] = orac
            row["walltime_ratio"] = ctrl / orac if orac else None
            fit_dci = tuner.profile.model("dci")
            truth_dci = truth_post.model("dci")
            row["fitted_dci_alpha"] = fit_dci.alpha
            row["truth_dci_alpha"] = truth_dci.alpha
            row["final_residual"] = max(
                dict(installs[-1]["residuals"]).values())
    return row


def check(rows: list[dict]) -> list[str]:
    by = {r["scenario"]: r for r in rows}
    drift, stable = by.get("drift"), by.get("stable")
    failures = []
    if drift is None or stable is None:
        return ["missing scenario rows"]
    if not drift["installs"]:
        failures.append("drift scenario installed no refit")
        return failures
    if drift["detect_executions"] is None or \
            drift["detect_executions"] > DETECT_BUDGET:
        failures.append(
            f"drift detected in {drift['detect_executions']} "
            f"executions, budget {DETECT_BUDGET}")
    if drift["final_residual"] > GATE_RESIDUAL:
        failures.append(
            f"converged fit residual {drift['final_residual']:.3e} "
            f"over the {GATE_RESIDUAL} gate")
    if drift["plans_dropped"] <= 0:
        failures.append("install dropped no stale plan-cache entries")
    pin = drift["pinned_cell"]
    if (pin["pre"], pin["post"]) != (PIN_PRE, PIN_POST):
        failures.append(
            f"pinned winner cell (p={PIN_P}, m={PIN_M}) went "
            f"{pin['pre']} -> {pin['post']}, expected "
            f"{PIN_PRE} -> {PIN_POST}")
    ratio = drift.get("walltime_ratio")
    if ratio is None or not (1.0 - 1e-9) <= ratio \
            <= 1.0 + WALLTIME_TOLERANCE:
        failures.append(
            f"post-convergence walltime {ratio} vs oracle, "
            f"tolerance {WALLTIME_TOLERANCE}")
    if stable["installs"] != 0:
        failures.append(
            f"stable control run installed {stable['installs']} "
            f"profiles (thrash)")
    if stable["refits"] < 1:
        failures.append("stable control run never attempted a refit")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when any gated claim fails "
                         "(CI smoke)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=DEFAULT_JSON, metavar="PATH")
    args = ap.parse_args(argv)

    rows = [run_scenario(drift=True), run_scenario(drift=False)]
    for r in rows:
        line = (f"{r['scenario']}: installs={r['installs']} "
                f"refits={r['refits']} "
                f"plans_dropped={r['plans_dropped']}")
        if r["scenario"] == "drift":
            line += (f" detect={r['detect_executions']}ex "
                     f"ratio={r.get('walltime_ratio'):.4f} "
                     f"pin={r['pinned_cell']['pre']}->"
                     f"{r['pinned_cell']['post']}")
        print(line)
    if args.json:
        from repro.core.benchmeta import bench_metadata

        with open(args.json, "w") as f:
            json.dump({"meta": bench_metadata(),
                       "schema_version": 1,
                       "benchmark": "autotune_bench",
                       "drift_factor": DRIFT_FACTOR,
                       "detect_budget": DETECT_BUDGET,
                       "walltime_tolerance": WALLTIME_TOLERANCE,
                       "rows": rows}, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        failures = check(rows)
        if failures:
            for msg in failures:
                print(f"AUTOTUNE FAIL: {msg}")
            return 1
        print("autotune gates OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
