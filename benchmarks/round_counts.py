"""Theorem 1 table: rounds and ⊕ applications vs p for the three
exclusive-scan algorithms (exact, from the message-schedule oracle)."""

from __future__ import annotations

from repro.core import oracle

PS = (4, 8, 16, 32, 36, 64, 128, 256, 512, 1024)


def run(csv_rows: list):
    for p in PS:
        for alg in ("two_op", "1doubling", "123"):
            st = oracle.verify(p, alg)
            csv_rows.append((f"rounds/{alg}/p{p}", st.rounds, "rounds"))
            csv_rows.append((f"ops/{alg}/p{p}", st.result_path_ops,
                             "oplus_result_path"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
