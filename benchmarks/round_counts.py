"""Theorem 1 table: rounds and ⊕ applications vs p for the three
exclusive-scan algorithms (exact, from the message-schedule oracle),
plus the pipelined segmented ring's p−2+S rounds measured by executing
its schedule IR in the numpy simulator executor against the plan's
prediction, plus the fused-scan round law — k concurrent small scans
packed into one payload ride the SINGLE-scan round count, not k× —
plus the commutativity-elision ⊕ law: butterfly exchange rounds cost
one ⊕ instead of two and fused scan_total (scan_reduce) rounds two
instead of three for commutative monoids, consistently across the
IR's ``op_count``, the plan's prediction and the simulator-executed
measurement (``--check`` turns any drift into a build failure)."""

from __future__ import annotations

import argparse
import json

from repro.core import oracle
from repro.core import schedule as schedule_lib
from repro.core.scan_api import ScanSpec, plan, plan_fused

DEFAULT_JSON = "BENCH_round_counts.json"

PS = (4, 8, 16, 32, 36, 64, 128, 256, 512, 1024)
RING_PS = (4, 8, 16, 36, 64)  # simulator-executed, keep p moderate
RING_SS = (1, 4, 16)
FUSED_PS = (8, 36, 64, 256)  # fused k-scan round-law rows
FUSED_K = 4
ELISION_PS = (4, 8, 16, 32)  # commutative ⊕-elision rows (pow-2 p)


def run(csv_rows: list, check: bool = False):
    for p in PS:
        for alg in ("two_op", "1doubling", "123"):
            st = oracle.verify(p, alg)
            csv_rows.append((f"rounds/{alg}/p{p}", st.rounds, "rounds"))
            csv_rows.append((f"ops/{alg}/p{p}", st.result_path_ops,
                             "oplus_result_path"))
    drift = []
    # block-distributed mid-m builders: closed-form rounds
    # (oracle.rounds_*) vs the IR vs the simulator-executed schedule —
    # the row-splitting algorithms can't run on the free monoid, so
    # they verify through verify_plan (numerics + stats) instead of
    # oracle.verify, with the closed form drift-checked explicitly
    closed = {"halving": oracle.rounds_halving,
              "quartering": oracle.rounds_quartering,
              "reduce_scatter": oracle.rounds_reduce_scatter}
    for p in PS:
        for alg, form in closed.items():
            pl = plan(ScanSpec(kind="exclusive", algorithm=alg),
                      p=p, nbytes=64)
            key = f"rounds/{alg}/p{p}"
            csv_rows.append((key, pl.rounds, "rounds_predicted"))
            csv_rows.append((key + "_closed", form(p), "closed_form"))
            if pl.rounds != form(p):
                drift.append((key, {"plan": pl.rounds,
                                    "closed_form": form(p)}))
            if p <= 64:  # simulator-executed for moderate p
                res = schedule_lib.verify_plan(pl)
                csv_rows.append((key + "_measured",
                                 res["rounds_measured"],
                                 "simulator_executor"))
                if not res["ok"]:
                    drift.append((key, res))
        # the reduce-scatter depth law the paper cites:
        # 2⌈log₂p⌉ rounds at powers of two
        if p & (p - 1) == 0:
            want = 2 * (p.bit_length() - 1)
            if oracle.rounds_reduce_scatter(p) != want:
                drift.append((f"rounds/reduce_scatter/p{p}",
                              {"closed_form":
                               oracle.rounds_reduce_scatter(p),
                               "2ceil_log2_p": want}))
    for p in RING_PS:
        for S in RING_SS:
            pl = plan(ScanSpec(kind="exclusive", algorithm="ring",
                               segments=S), p=p, nbytes=S * 64)
            res = schedule_lib.verify_plan(pl)
            key = f"rounds/ring_S{S}/p{p}"
            csv_rows.append((key, pl.rounds, "rounds_predicted"))
            csv_rows.append((key + "_measured", res["rounds_measured"],
                             "simulator_executor"))
            if not res["ok"]:
                drift.append((key, res))
    # fused round law: k small concurrent exscans fused into one packed
    # payload must cost the single-scan round count (not k×) — the
    # tentpole's α amortization, asserted against the simulator
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    for p in FUSED_PS:
        single = plan(spec, p=p, nbytes=8 * FUSED_K)
        fp = plan_fused([spec] * FUSED_K, p, [8] * FUSED_K)
        key = f"rounds/fused_k{FUSED_K}/p{p}"
        csv_rows.append((key, fp.rounds, "rounds_fused"))
        csv_rows.append((key + "_single", single.rounds,
                         "rounds_single_scan"))
        if not fp.fused or fp.rounds != single.rounds:
            drift.append((key, {"fused": fp.fused,
                                "rounds": fp.rounds,
                                "single": single.rounds}))
        elif check:
            res = fp.verify()
            if not res["ok"]:
                drift.append((key, res))
    # commutativity-elided ⊕ counts: for commutative monoids the
    # butterfly exchange computes ONE combine order (2->1 ⊕/round) and
    # the fused scan_total butterfly folds the window total once
    # (3->2 ⊕/round); the IR's op_count, the plan's prediction and the
    # simulator-executed measurement must all agree (affine rows keep
    # the non-commutative counts as the baseline)
    for p in ELISION_PS:
        cells = (("butterfly", "allreduce", "add", "affine"),
                 ("fused_doubling", "scan_total", "add", "affine"))
        for alg, kind, comm_m, noncomm_m in cells:
            for mono in (comm_m, noncomm_m):
                pl = plan(ScanSpec(kind=kind, algorithm=alg,
                                   monoid=mono), p=p, nbytes=64)
                key = f"ops/{alg}/{mono}/p{p}"
                csv_rows.append((key, pl.op_applications,
                                 "oplus_predicted"))
                sched = pl.schedule()
                commutative = mono == comm_m
                if pl.op_applications != sched.op_count(commutative):
                    drift.append((key, {
                        "plan": pl.op_applications,
                        "ir": sched.op_count(commutative)}))
                res = schedule_lib.verify_plan(pl)
                csv_rows.append((key + "_measured",
                                 res["ops_measured"],
                                 "simulator_executor"))
                if not res["ok"]:
                    drift.append((key, res))
            comm = plan(ScanSpec(kind=kind, algorithm=alg,
                                 monoid=comm_m), p=p, nbytes=64)
            noncomm = plan(ScanSpec(kind=kind, algorithm=alg,
                                    monoid=noncomm_m), p=p, nbytes=64)
            if comm.op_applications >= noncomm.op_applications:
                drift.append((f"ops/{alg}/p{p}", {
                    "commutative": comm.op_applications,
                    "noncommutative": noncomm.op_applications,
                    "expected": "commutative strictly fewer"}))
    if check and drift:
        raise SystemExit(
            f"plan/measurement drift in {len(drift)} cells: {drift}")
    return csv_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail on plan-vs-simulator drift (CI smoke)")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON,
                    default=None, metavar="PATH",
                    help=f"also write rows as JSON "
                         f"(default {DEFAULT_JSON})")
    args = ap.parse_args()
    rows = run([], check=args.check)
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.json:
        from repro.core.benchmeta import bench_metadata

        with open(args.json, "w") as f:
            json.dump({"meta": bench_metadata(),
                       "schema_version": 1,
                       "benchmark": "round_counts",
                       "rows": [[k, v, note] for k, v, note in rows]},
                      f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
