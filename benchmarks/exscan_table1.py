"""Paper Table 1 / Figure 1 analogue.

The paper benchmarks MPI_Exscan vs two-⊕ doubling vs 1-doubling vs
123-doubling on a 36-node cluster over m ∈ {1..100k} MPI_LONGs
(MPI_BXOR).  Here the four algorithms run as ppermute programs:

  (a) MEASURED on an N-fake-CPU-device mesh (relative comparison only —
      one physical core executes all ranks, so times are dominated by
      per-round dispatch overhead, which is exactly the paper's
      round-count regime);
  (b) MODELED for TPU v5e pods with the α-β-γ cost model
      t = rounds·α + rounds·(m_bytes)/B_link + ops·m·γ,
      α = 1 µs/ppermute (ICI launch+hop), B = 50 GB/s, γ from 819 GB/s
      HBM streaming of the ⊕ operands.

The round/⊕ counts themselves are asserted against Theorem 1 by the
test suite; this benchmark reports the latency consequences.
"""

from __future__ import annotations

import json
import subprocess
import sys
import os

from repro.core import oracle

ALGS = ("two_op", "1doubling", "123", "native")
EMS = (1, 10, 100, 1000, 10_000, 100_000)

ALPHA = 1e-6  # per-round launch+hop latency
B_LINK = 50e9
B_HBM = 819e9

_MEASURE = """
import os, time, json
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map
from repro.core.scan_api import ScanSpec, scan

p = {p}
mesh = Mesh(np.array(jax.devices()).reshape(p), ("x",))
out = {{}}
for alg in {algs}:
    spec = ScanSpec(kind="exclusive", monoid="xor", algorithm=alg,
                    axis_name="x")
    for m in {ems}:
        x = np.arange(p * m, dtype=np.int64).reshape(p, m)
        f = jax.jit(shard_map(lambda v: scan(v, spec),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        f(x)  # compile+warm
        reps = 30 if m <= 1000 else 10
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            ts.append(time.perf_counter() - t0)
        out[f"{{alg}}/{{m}}"] = min(ts) * 1e6
print("RESULT" + json.dumps(out))
"""


def modeled_us(alg: str, p: int, m: int, itemsize: int = 8) -> float:
    if alg == "native":  # all-gather + local fold
        bytes_wire = p * m * itemsize
        t = ALPHA + bytes_wire / B_LINK + (p - 1) * m * itemsize / B_HBM
        return t * 1e6
    st = oracle.verify(p, alg)
    rounds, ops = st.rounds, st.result_path_ops
    t = rounds * ALPHA + rounds * m * itemsize / B_LINK \
        + ops * 2 * m * itemsize / B_HBM
    return t * 1e6


def measured(p: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["JAX_ENABLE_X64"] = "1"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _MEASURE.format(p=p, algs=repr(list(ALGS)), ems=repr(list(EMS)))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def run(csv_rows: list):
    # measured on 8 fake devices (relative; see module docstring)
    res = measured(8)
    for m in EMS:
        for alg in ALGS:
            csv_rows.append((f"exscan_measured_p8/{alg}/m{m}",
                             res[f"{alg}/{m}"], "us_wallclock_cpu"))
    # modeled for the paper's p=36 and the pod scales
    for p in (36, 256, 512):
        for m in EMS:
            for alg in ALGS:
                csv_rows.append((f"exscan_modeled_p{p}/{alg}/m{m}",
                                 modeled_us(alg, p, m), "us_abg_model"))
    return csv_rows


if __name__ == "__main__":
    rows = run([])
    for r in rows:
        print(",".join(str(x) for x in r))
