"""Interpreter-startup hook (auto-imported by ``site`` whenever
``src/`` is on PYTHONPATH): apply the jax forward-compat backfills
before any user code runs, so snippets doing ``from jax import
shard_map`` at the top work on images pinning an older jax.

Python imports exactly ONE sitecustomize module, so this file would
otherwise shadow the environment's own startup hooks (e.g. coverage.py
subprocess measurement); to avoid that, after applying the backfills we
chain-load the next ``sitecustomize.py`` found on ``sys.path``.

Deliberately defensive — any failure (jax absent, etc.) must never
break unrelated python processes that merely have src/ on their path.
"""


def _apply_backfills():
    try:
        from repro import _jax_compat

        _jax_compat.apply()
    except Exception:  # noqa: BLE001
        pass


def _chain_next_sitecustomize():
    """Run the sitecustomize this file shadows, if any."""
    import os
    import sys

    here = os.path.dirname(os.path.abspath(__file__))
    for entry in sys.path:
        try:
            cand_dir = os.path.abspath(entry or os.getcwd())
            if cand_dir == here:
                continue
            cand = os.path.join(cand_dir, "sitecustomize.py")
            if os.path.isfile(cand):
                import runpy

                runpy.run_path(cand, run_name="sitecustomize")
                break
        except Exception:  # noqa: BLE001
            break


_apply_backfills()
_chain_next_sitecustomize()
