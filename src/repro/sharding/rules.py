"""Logical-axis partition rules (MaxText-style).

Every parameter/activation dimension carries a *logical* axis name; a
rule table maps logical names to mesh axes.  Changing a sharding
strategy (the §Perf hillclimb lever) means editing ONE table, not the
model code.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Mesh axis names (see launch/mesh.py):
#   single pod: ("data", "model");  multi-pod: ("pod", "data", "model")

# logical axis -> mesh axes (None = replicated)
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": ("data",),  # context/sequence parallelism (long_500k)
    "embed_act": None,
    # params — FSDP shards the d_model ("embed") dim over the data axes,
    # TP shards heads / ffn-hidden / experts / vocab over "model".
    "embed": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),  # after duplication to TP degree
    "head_dim": None,
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),  # expert parallelism
    "expert_mlp": None,
    "d_inner": ("model",),  # mamba inner channels
    "d_state": None,
    "conv": None,
    "norm": None,
    # kv cache
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_kv": ("model",),
    # long-context decode: sequence-sharded cache
    "cache_seq_shard": ("data",),
    # fallback when kv heads can't shard over TP: cache seq over model
    "cache_seq_tp": ("model",),
    # layer-stacking axis of scanned params
    "layers": None,
}


@dataclasses.dataclass(frozen=True)
class Rules:
    table: dict

    def mesh_axes(self, logical: tuple[str | None, ...], mesh: Mesh):
        """Resolve logical axes to a PartitionSpec valid for ``mesh``.

        Axes absent from the mesh (e.g. "pod" on a single-pod mesh) are
        dropped; a dim is left unsharded unless its size is divisible by
        the product of the mapped mesh axis sizes (caller guarantees the
        shape, we guarantee validity).
        """
        spec = []
        for name in logical:
            if name is None:
                spec.append(None)
                continue
            mapped = self.table.get(name)
            if mapped is None:
                spec.append(None)
                continue
            if isinstance(mapped, str):
                mapped = (mapped,)
            present = tuple(a for a in mapped if a in mesh.axis_names)
            spec.append(present if present else None)
        return P(*spec)

    def shard(self, logical, mesh: Mesh, shape=None):
        """NamedSharding for a logical annotation; if ``shape`` is given,
        drop shardings that do not divide the dimension."""
        spec = self.mesh_axes(logical, mesh)
        if shape is not None:
            spec = divisible_spec(spec, shape, mesh)
        return NamedSharding(mesh, spec)


def divisible_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from a spec wherever they don't divide the dim."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept = []
        prod = 1
        for a in axes:
            if dim % (prod * axis_size[a]) == 0:
                kept.append(a)
                prod *= axis_size[a]
        out.append(tuple(kept) if kept else None)
    return P(*out)


DEFAULT = Rules(DEFAULT_RULES)

# FSDP + sequence-parallel strategy (§Perf hillclimb): no tensor
# parallelism — the "model" axis carries (a) an extra FSDP factor for
# params/optimizer and (b) the activations' SEQUENCE dim, so the only
# per-layer collectives are the FSDP weight all-gathers and a KV gather
# in attention, instead of TP's 2+ full-activation reductions per layer.
FSDP_SP_RULES = dict(
    DEFAULT_RULES,
    **{
        "seq": ("model",),
        "seq_kv": None,
        "embed": ("pod", "data", "model"),
        "heads": None,
        "kv_heads": None,
        "mlp": None,
        "d_inner": None,
        "vocab": None,
        "experts": ("model",),  # EP stays on "model"
        "cache_kv": None,
        "cache_seq": ("model",),
    },
)

# Weight-stationary decode (§Perf): small per-step token counts make
# moving activations cheaper than FSDP-gathering weights — activations
# carry their d_model dim sharded over the FSDP axes (partial-sum
# matmuls + tiny psums), batch replicated outside attention; weights
# never move.  The KV cache stays batch-sharded.
DECODE_WS_RULES = dict(
    DEFAULT_RULES,
    **{
        "batch": None,
        "embed_act": ("pod", "data"),
    },
)

STRATEGIES = {
    "tp": Rules(DEFAULT_RULES),
    "fsdp_sp": Rules(FSDP_SP_RULES),
    "decode_ws": Rules(DECODE_WS_RULES),
}


def rules_for(cfg) -> Rules:
    return STRATEGIES[getattr(cfg, "sharding_strategy", "tp")]


def make_rules(**overrides) -> Rules:
    table = dict(DEFAULT_RULES)
    table.update(overrides)
    return Rules(table)


def tree_shardings(rules: Rules, logical_tree, mesh: Mesh, shape_tree):
    """Map a pytree of logical annotations + shapes to NamedShardings."""
    return jax.tree.map(
        lambda log, shp: rules.shard(log, mesh, shp.shape),
        logical_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
