"""Activation-sharding context: logical constraints inside model code.

Layer code calls ``constrain(x, "batch", None, "heads")`` at the few
places where GSPMD's propagation would otherwise choose a bad layout
(e.g. resharding a million-token batch instead of all-gathering a
0.5 GB weight — observed in the baseline dry-run, see EXPERIMENTS.md
§Perf iteration 0).  Logical names resolve through the same rule table
as parameters; axes that don't divide are dropped, and with no active
context (plain single-device tests) the call is a no-op.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding

from repro.sharding import rules as rules_lib

_tls = threading.local()


@contextlib.contextmanager
def use_mesh_rules(mesh, rules=None):
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = (mesh, rules or rules_lib.DEFAULT)
    try:
        yield
    finally:
        _tls.ctx = prev


def active() -> bool:
    return getattr(_tls, "ctx", None) is not None


def constrain(x, *logical):
    ctx = getattr(_tls, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if mesh.devices.size == 1:
        return x
    spec = rules.mesh_axes(logical, mesh)
    spec = rules_lib.divisible_spec(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
