"""Forward-compatibility backfills for older pinned jax.

The codebase is written against the current jax API (``jax.shard_map``,
``jax.set_mesh``, ``lax.axis_size``, ``check_vma=``).  Some images pin
an older jax (e.g. 0.4.37) where those names live under
``jax.experimental.shard_map`` / ``with mesh:`` / ``lax.psum(1, axis)``.
``apply()`` backfills the missing attributes in place — a no-op on
current jax — so the same sources run on both.

Imported from ``repro/__init__.py`` (covers anything that imports this
package first) and from ``src/sitecustomize.py`` (covers subprocess
snippets that do ``from jax import shard_map`` before importing repro,
as the test helpers' fake-device subprocesses do).
"""

from __future__ import annotations

import contextlib
import functools


def apply() -> None:
    import jax
    from jax import lax

    if not hasattr(lax, "axis_size"):
        def axis_size(axis_name):
            """Size of a named mesh axis (product over a tuple)."""
            return lax.psum(1, axis_name)

        lax.axis_size = axis_size

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      *, check_vma=None, check_rep=None, **kwargs):
            if check_rep is None:
                check_rep = check_vma
            if check_rep is not None:
                kwargs["check_rep"] = check_rep
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh
