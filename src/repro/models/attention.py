"""GQA attention: chunked full-sequence path + cached decode path.

Memory discipline: the (S, S) score matrix is never materialized — the
query axis is processed in ``cfg.attn_chunk`` chunks with ``lax.scan``
(q-chunk scores are (B, KV, G, C, S)).  This is the XLA-expressible
flash-style formulation that both lowers on the CPU dry-run backend and
fuses well on TPU.  GQA is computed in grouped form (no KV repetition).

Variants: RoPE, attention-score softcap (gemma2), sliding window
(gemma2 local layers), non-causal (hubert encoder).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import rmsnorm, rope, softcap
from repro.sharding.ctx import constrain

NEG_INF = -1e30


def _grouped_scores(q, k, scale, cap):
    """q: (B,C,KV,G,hd)  k: (B,S,KV,hd)  ->  (B,KV,G,C,S)."""
    s = jnp.einsum("bckgd,bskd->bkgcs", q, k,
                   preferred_element_type=jnp.float32)
    return softcap(s * scale, cap)


def _apply_mask(scores, mask):
    return jnp.where(mask, scores, NEG_INF)


def _attend(scores, v):
    """scores: (B,KV,G,C,S) f32; v: (B,S,KV,hd) -> (B,C,KV,G,hd)."""
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgcs,bskd->bckgd", w.astype(v.dtype), v)


def attention_core(
    q: jax.Array,  # (B, Sq, H, hd), rope applied
    k: jax.Array,  # (B, Skv, KV, hd), rope applied
    v: jax.Array,  # (B, Skv, KV, hd)
    pos_q: jax.Array,  # (B, Sq) int32
    pos_k: jax.Array,  # (B, Skv) int32
    *,
    causal: bool,
    window: int,
    attn_softcap: float,
    chunk: int,
    kv_len: jax.Array | None = None,  # (B,) valid cache length (decode)
):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)

    def block(qc, pq):
        # qc: (B, C, KV, G, hd); pq: (B, C)
        scores = _grouped_scores(qc, k, scale, attn_softcap)
        mask = jnp.ones((B, 1, 1, qc.shape[1], k.shape[1]), bool)
        pk = pos_k[:, None, None, None, :]
        pqe = pq[:, None, None, :, None]
        if causal:
            mask &= pk <= pqe
        if window:
            mask &= pk > pqe - window
        if kv_len is not None:
            mask &= pk < kv_len[:, None, None, None, None]
        return _attend(_apply_mask(scores, mask), v)

    if Sq <= chunk:
        out = block(qg, pos_q)
    else:
        assert Sq % chunk == 0, (Sq, chunk)
        n = Sq // chunk
        qs = qg.reshape(B, n, chunk, KV, G, hd).swapaxes(0, 1)
        ps = pos_q.reshape(B, n, chunk).swapaxes(0, 1)
        out = lax.scan(
            lambda _, qp: (None, block(*qp)), None, (qs, ps)
        )[1]  # (n, B, C, KV, G, hd)
        out = out.swapaxes(0, 1).reshape(B, Sq, KV, G, hd)
    return out.reshape(B, Sq, H, hd)


def attention_block(
    cfg,
    p: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    *,
    window: int,
    cache: dict | None = None,
    cache_len: jax.Array | None = None,
):
    """Pre-norm attention sub-block.  Returns (residual_out, new_cache).

    Full-sequence mode (cache=None): self-attention over x.
    Decode mode: x is (B, 1, d); cache holds (k, v) of shape
    (B, S_max, KVd, hd) with ``cache_len`` valid entries; kv heads are
    stored duplicated to the TP degree when n_kv < TP (see DESIGN §5).
    """
    B, S, _ = x.shape
    hd = cfg.head_dim_
    xn = rmsnorm(x, p["norm1"], cfg.norm_eps)
    q = constrain(jnp.einsum("bsd,dh->bsh", xn, p["wq"]),
                  "batch", "seq", "heads").reshape(B, S, cfg.n_heads, hd)
    k = constrain(jnp.einsum("bsd,dh->bsh", xn, p["wk"]),
                  "batch", "seq_kv", "kv_heads").reshape(
        B, S, cfg.n_kv_heads, hd)
    v = constrain(jnp.einsum("bsd,dh->bsh", xn, p["wv"]),
                  "batch", "seq_kv", "kv_heads").reshape(
        B, S, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = attention_core(
            q, k, v, positions, positions,
            causal=cfg.causal, window=window,
            attn_softcap=cfg.attn_softcap, chunk=cfg.attn_chunk,
        )
        new_cache = None
    else:
        dup = cache["k"].shape[2] // cfg.n_kv_heads
        if dup > 1:
            k = jnp.repeat(k, dup, axis=2)
            v = jnp.repeat(v, dup, axis=2)
        ck = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        cv = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        new_cache = {"k": ck, "v": cv}
        S_max = ck.shape[1]
        pos_k = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32),
                                 (B, S_max))
        kv_len = jnp.full((B,), cache_len + S, jnp.int32)
        out = attention_core(
            q, ck, cv, positions, pos_k,
            causal=cfg.causal, window=window,
            attn_softcap=cfg.attn_softcap, chunk=cfg.attn_chunk,
            kv_len=kv_len,
        )
    y = constrain(jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1),
                             p["wo"]), "batch", "seq", "embed_act")
    return x + y, new_cache
