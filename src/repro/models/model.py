"""Model assembly: pattern-unit scan, forward, decode, loss.

The layer stack runs as ``lax.scan`` over pattern repeats (HLO contains
each distinct layer kind once — compile time at 512 devices stays flat
in depth).  Each repeat body is ``jax.checkpoint``-ed (activation
rematerialization), the standard memory/compute trade at scale.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import params as PD
from repro.models import rwkv as rwkv_lib
from repro.models.attention import attention_block
from repro.models.common import rmsnorm, softcap, swiglu
from repro.models.config import ModelConfig
from repro.models.mamba import init_mamba_cache, mamba_block
from repro.models.moe import moe_block
from repro.models.rwkv import rwkv_block
from repro.sharding import rules as rules_lib
from repro.sharding.ctx import constrain, use_mesh_rules


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    mesh: Any  # jax.sharding.Mesh — needed by the MoE shard_map

    # ------------------------- params -------------------------

    def init_params(self, key):
        return PD.init_params(self.cfg, key)

    def abstract_params(self):
        return PD.abstract_params(self.cfg)

    def param_shardings(self, rules):
        return PD.param_shardings(self.cfg, self.mesh, rules)

    # ------------------------- layers -------------------------

    def _ffn(self, spec, p, x):
        """Post-attention FFN half of a block. Returns (x, aux)."""
        cfg = self.cfg
        if spec.use_moe:
            return moe_block(cfg, p, x, self.mesh)
        xn = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y = swiglu(xn, p["w_gate"], p["w_up"], p["w_down"])
        return x + y, jnp.zeros((2,), jnp.float32)

    def _layer(self, spec, p, x, positions, cache=None, cache_len=None):
        cfg = self.cfg
        if spec.kind == "attn":
            x, new_cache = attention_block(
                cfg, p, x, positions, window=spec.sliding_window,
                cache=cache, cache_len=cache_len)
            x, aux = self._ffn(spec, p, x)
        elif spec.kind == "mamba":
            x, new_cache = mamba_block(cfg, p, x, cache=cache)
            x, aux = self._ffn(spec, p, x)
        elif spec.kind == "rwkv":
            x, new_cache = rwkv_block(cfg, p, x, cache=cache,
                                      mesh=self.mesh)
            aux = jnp.zeros((2,), jnp.float32)
        else:
            raise ValueError(spec.kind)
        return x, aux, new_cache

    # ------------------------- forward -------------------------

    def _embed(self, p_top, tokens, prefix_embeds=None):
        """tokens: (B, S_tok) int32 or None; prefix_embeds: (B, n, d) —
        vlm patch embeddings (prepended) or audio frame embeddings (the
        whole input).  Frontends are stubs per the assignment."""
        cfg = self.cfg
        if tokens is not None:
            x = jnp.take(p_top["tok_embed"], tokens, axis=0)
            if cfg.frontend == "vision" and prefix_embeds is not None:
                x = jnp.concatenate(
                    [prefix_embeds.astype(x.dtype), x], axis=1)
        else:
            x = prefix_embeds  # audio: frame embeddings are the input
        return x

    def _stack(self, params, x, positions):
        """Scan the layer stack. Returns (x, aux_sum)."""
        cfg = self.cfg
        pattern = cfg.pattern()

        def body(carry, layer_params):
            h, aux = carry
            for j, spec in enumerate(pattern):
                h, aux_j, _ = self._layer(spec, layer_params[j], h,
                                          positions)
                aux = aux + aux_j
            return (h, aux), None

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        carry = (x, jnp.zeros((2,), jnp.float32))
        if cfg.unroll_stack:
            for r in range(cfg.n_repeats):
                layer_params = jax.tree.map(lambda t: t[r],
                                            params["blocks"])
                carry, _ = body(carry, layer_params)
        else:
            carry, _ = lax.scan(body, carry, params["blocks"])
        return carry

    def logits_fn(self, params, x):
        cfg = self.cfg
        x = rmsnorm(x, params["top"]["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            w = params["top"]["tok_embed"].T
        else:
            w = params["top"]["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
        logits = softcap(logits, cfg.logit_softcap)
        vp = PD.vocab_padded(cfg)
        if vp != cfg.vocab:
            vmask = jnp.arange(vp) < cfg.vocab
            logits = jnp.where(vmask, logits, -1e30)
        return logits

    def forward(self, params, tokens, prefix_embeds=None, positions=None):
        """Full-sequence forward (train / prefill). Returns (logits, aux)."""
        with use_mesh_rules(self.mesh, rules_lib.rules_for(self.cfg)):
            x = self._embed(params["top"], tokens, prefix_embeds)
            x = constrain(x, "batch", "seq", "embed_act")
            B, S, _ = x.shape
            if positions is None:
                positions = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), (B, S))
            x, aux = self._stack(params, x, positions)
            return self.logits_fn(params, x), aux

    def loss(self, params, batch):
        """batch: {"tokens" or "embeds", "labels", optional "prefix"}.
        Next-token CE for causal LMs; per-position CE for encoders.

        The CE is vocab-shard-safe: no full-vocab softmax materializes
        off-shard — max/logsumexp/label-pick all reduce over the sharded
        vocab axis locally + one tiny (B, S) cross-shard reduction, and
        shapes stay round (shift via roll + mask, not odd slicing).
        See EXPERIMENTS.md §Perf iteration 0.
        """
        cfg = self.cfg
        tokens = batch.get("tokens")
        prefix = batch.get("embeds") if cfg.frontend == "audio" else \
            batch.get("prefix")
        logits, aux = self.forward(params, tokens, prefix)
        with use_mesh_rules(self.mesh, rules_lib.rules_for(self.cfg)):
            return self._loss_inner(logits, aux, batch)

    def _loss_inner(self, logits, aux, batch):
        cfg = self.cfg
        n_moe = sum(1 for s in cfg.pattern() if s.use_moe) * cfg.n_repeats
        aux = aux / max(n_moe, 1)  # per-MoE-layer means
        logits = constrain(logits, "batch", "seq", "vocab")
        labels = batch["labels"]
        B, S_l = labels.shape
        n_prefix = logits.shape[1] - S_l
        if cfg.causal and not cfg.encoder_only:
            # predict labels[t+1] at position t; last position masked
            labels = jnp.roll(labels, -1, axis=1)
            weights = jnp.concatenate(
                [jnp.ones((B, S_l - 1), jnp.float32),
                 jnp.zeros((B, 1), jnp.float32)], axis=1)
        else:
            weights = jnp.ones((B, S_l), jnp.float32)
        if n_prefix:  # vlm: prefix positions carry no labels
            labels = jnp.concatenate(
                [jnp.zeros((B, n_prefix), labels.dtype), labels], axis=1)
            weights = jnp.concatenate(
                [jnp.zeros((B, n_prefix), jnp.float32), weights], axis=1)
        logits32 = logits.astype(jnp.float32)
        zmax = jnp.max(logits32, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(logits32 - zmax), axis=-1)) + \
            zmax[..., 0]
        vp = logits.shape[-1]
        onehot = jax.nn.one_hot(labels, vp, dtype=jnp.float32)
        label_logit = jnp.sum(logits32 * onehot, axis=-1)
        nll = (lse - label_logit) * weights
        ce = jnp.sum(nll) / jnp.maximum(jnp.sum(weights), 1.0)
        lb_loss = aux[0] * 0.01  # load-balance coefficient
        metrics = {"ce": ce, "load_balance": aux[0], "dropped": aux[1]}
        return ce + lb_loss, metrics

    # ------------------------- decode -------------------------

    def init_cache(self, batch: int, max_len: int, kv_dup: int = 1):
        """Stacked-by-repeat caches, one entry per pattern position."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        r = cfg.n_repeats
        caches = []
        for spec in cfg.pattern():
            if spec.kind == "attn":
                kvd = cfg.n_kv_heads * kv_dup
                c = {
                    "k": jnp.zeros(
                        (r, batch, max_len, kvd, cfg.head_dim_), dtype),
                    "v": jnp.zeros(
                        (r, batch, max_len, kvd, cfg.head_dim_), dtype),
                }
            elif spec.kind == "mamba":
                c = jax.tree.map(
                    lambda t: jnp.broadcast_to(t, (r, *t.shape)).copy(),
                    init_mamba_cache(cfg, batch, dtype))
            else:
                c = jax.tree.map(
                    lambda t: jnp.broadcast_to(t, (r, *t.shape)).copy(),
                    rwkv_lib.init_rwkv_cache(cfg, batch, dtype))
            caches.append(c)
        return tuple(caches)

    def abstract_cache(self, batch: int, max_len: int, kv_dup: int = 1):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, kv_dup))

    def cache_logical_axes(self, seq_sharded: bool = False,
                           kv_shardable: bool = True):
        """Logical-axis tree matching init_cache's structure.

        seq_sharded: long-context mode — cache seq over the data axis.
        kv_shardable: False when no kv duplication makes the heads dim
        divisible by TP (then seq shards over "model" instead)."""
        cfg = self.cfg
        if seq_sharded:
            seq_ax, b_ax = "cache_seq_shard", None
        elif not kv_shardable:
            seq_ax, b_ax = "cache_seq_tp", "cache_batch"
        else:
            seq_ax, b_ax = "cache_seq", "cache_batch"
        kv_ax = "cache_kv" if kv_shardable else None
        out = []
        for spec in cfg.pattern():
            if spec.kind == "attn":
                ax = ("layers", b_ax, seq_ax, kv_ax, None)
                out.append({"k": ax, "v": ax})
            elif spec.kind == "mamba":
                out.append({
                    "conv": ("layers", b_ax, None, "d_inner"),
                    "h": ("layers", b_ax, "d_inner", None),
                })
            else:
                out.append({
                    "shift": ("layers", b_ax, None, None),
                    "cm_shift": ("layers", b_ax, None, None),
                    "state": ("layers", b_ax, "heads", None, None),
                })
        return tuple(out)

    def decode_step(self, params, cache, tokens, cache_len):
        """One-token decode.  tokens: (B, 1) int32; cache_len: scalar.

        Returns (logits (B, 1, V), new_cache)."""
        return self.serve_step(params, cache, tokens, cache_len)

    def serve_step(self, params, cache, tokens, cache_len,
                   prefix_embeds=None, last_only=False):
        """Serving step: decode (S=1) or prefill (S>1) into the cache.

        tokens: (B, S) int32; cache_len: scalar i32 (valid cache length
        before this call).  Returns (logits, new_cache); with
        ``last_only`` logits cover only the final position (prefill
        avoids materializing (B, S, vocab))."""
        cfg = self.cfg
        with use_mesh_rules(self.mesh, rules_lib.rules_for(self.cfg)):
            return self._serve_step_inner(params, cache, tokens, cache_len,
                                          prefix_embeds, last_only)

    def _serve_step_inner(self, params, cache, tokens, cache_len,
                          prefix_embeds, last_only):
        cfg = self.cfg
        x = self._embed(params["top"], tokens, prefix_embeds)
        x = constrain(x, "batch", None, None)
        B, S, _ = x.shape
        positions = cache_len + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, S))
        pattern = cfg.pattern()

        def body(h, scan_in):
            layer_params, layer_cache = scan_in
            new_caches = []
            for j, spec in enumerate(pattern):
                h, _, nc = self._layer(spec, layer_params[j], h, positions,
                                       cache=layer_cache[j],
                                       cache_len=cache_len)
                new_caches.append(nc)
            return h, tuple(new_caches)

        if cfg.unroll_stack:
            new_caches = []
            for r in range(cfg.n_repeats):
                lp = jax.tree.map(lambda t: t[r], params["blocks"])
                lc = jax.tree.map(lambda t: t[r], cache)
                x, nc = body(x, (lp, lc))
                new_caches.append(nc)
            new_cache = jax.tree.map(
                lambda *ts: jnp.stack(ts), *new_caches)
        else:
            x, new_cache = lax.scan(body, x, (params["blocks"], cache))
        if last_only:
            x = x[:, -1:]
        return self.logits_fn(params, x), new_cache
