"""Shared layer primitives."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.ctx import constrain


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    normed = x32 * lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jax.nn.silu(constrain(jnp.einsum("...d,df->...f", x, w_gate),
                              "batch", "seq", "mlp"))
    u = constrain(jnp.einsum("...d,df->...f", x, w_up),
                  "batch", "seq", "mlp")
    return constrain(jnp.einsum("...f,fd->...d", g * u, w_down),
                     "batch", "seq", "embed_act")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """RWKV token shift: x_{t-1} along the seq axis.  x: (B, S, D).
    ``prev``: (B, 1, D) carry-in from the previous chunk/step."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)
