"""RWKV6 ("Finch") block: data-dependent-decay linear attention.

State per head is a (hd, hd) matrix updated as
    S_t = diag(w_t) S_t-1 + k_t ⊗ v_t,      out_t = r_t · (S_t-1 + u⊙k_t ⊗ v_t)
— an AFFINE-monoid recurrence, scanned in chunks exactly like mamba.py
(the chunk-boundary carry across sequence-sharded devices is composed
with the paper's exscan; see models/context_parallel.py).

Simplifications vs the reference implementation (noted per DESIGN §2):
data-dependent decay uses a single linear projection instead of the
LoRA-factored one, and group-norm on the wkv output is an RMS norm per
head.  Neither changes parallel structure, FLOP shape or state layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.monoid import affine_combine as _affine
from repro.models.common import rmsnorm, token_shift
from repro.sharding.ctx import constrain

HEAD_DIM = 64
WKV_CHUNK = 32


def _lerp(x, prev, mu):
    return x + (prev - x) * mu


def wkv_scan_chunked(w, kv, s0, chunk=WKV_CHUNK):
    """S_t = w_t * S_{t-1} + kv_t.  w: (B,S,H,hd,1), kv: (B,S,H,hd,hd).

    Returns (S_prev per step: (B,S,H,hd,hd), S_final: (B,H,hd,hd)) —
    note the *exclusive* (pre-update) state is returned, as the wkv
    output reads S_{t-1}."""
    B, S = kv.shape[:2]
    if S % chunk:
        chunk = S
    n = S // chunk
    w_c = w.reshape(B, n, chunk, *w.shape[2:]).swapaxes(0, 1)
    kv_c = kv.reshape(B, n, chunk, *kv.shape[2:]).swapaxes(0, 1)

    def body(s_in, wkv_):
        wc, kvc = wkv_
        cum_a, cum_b = lax.associative_scan(_affine, (wc, kvc), axis=1)
        s_incl = cum_a * s_in[:, None] + cum_b  # (B,C,H,hd,hd)
        s_prev = jnp.concatenate([s_in[:, None], s_incl[:, :-1]], axis=1)
        return s_incl[:, -1], s_prev

    s_final, s_prevs = lax.scan(body, s0, (w_c, kv_c))
    s_prevs = s_prevs.swapaxes(0, 1).reshape(B, S, *kv.shape[2:])
    return s_prevs, s_final


def rwkv_block(cfg, p, x, *, cache=None, mesh=None):
    """Full RWKV6 layer (time-mix + channel-mix).  x: (B, S, d).

    cache (decode): {"shift": (B,1,d), "cm_shift": (B,1,d),
                     "state": (B,H,hd,hd) f32}.

    Under the fsdp_sp strategy (sequence sharded over "model") the wkv
    recurrence runs CONTEXT-PARALLEL: local chunk scans + the paper's
    exscan (``cfg.scan_spec``, planner-selected algorithm) carrying the
    (decay, state) AFFINE monoid across sequence shards
    (models/context_parallel.py)."""
    B, S, d = x.shape
    hd = HEAD_DIM
    H = d // hd

    # ---------------- time mix ----------------
    xn = rmsnorm(x, p["norm1"], cfg.norm_eps)
    prev = cache["shift"] if cache is not None else None
    xp = token_shift(xn, prev)
    xr = _lerp(xn, xp, p["mu_r"])
    xk = _lerp(xn, xp, p["mu_k"])
    xv = _lerp(xn, xp, p["mu_v"])
    xw = _lerp(xn, xp, p["mu_w"])
    xg = _lerp(xn, xp, p["mu_g"])
    r = constrain(jnp.einsum("bsd,de->bse", xr, p["wr"]),
                  "batch", "seq", "heads").reshape(B, S, H, hd)
    k = constrain(jnp.einsum("bsd,de->bse", xk, p["wk"]),
                  "batch", "seq", "heads").reshape(B, S, H, hd)
    v = constrain(jnp.einsum("bsd,de->bse", xv, p["wv"]),
                  "batch", "seq", "heads").reshape(B, S, H, hd)
    g = jax.nn.silu(constrain(jnp.einsum("bsd,de->bse", xg, p["wg"]),
                              "batch", "seq", "heads"))
    # Finch data-dependent decay in (0, 1)
    logw = -jnp.exp(
        jnp.clip(
            jnp.einsum("bsd,de->bse", xw, p["w_decay"]) + p["decay_bias"],
            -8.0, 4.0,
        ).astype(jnp.float32)
    )
    w = jnp.exp(logw).reshape(B, S, H, hd)
    u = p["bonus_u"].reshape(H, hd)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]  # (B,S,H,hd,hd)
    w_b = w[..., :, None]  # decay broadcasts over the v dim

    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    use_cp = (cache is None and mesh is not None
              and cfg.sharding_strategy == "fsdp_sp"
              and S % tp == 0 and S >= tp and tp > 1)
    if use_cp:
        from repro.models.context_parallel import cp_wkv_scan

        n_bt = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n_bt *= mesh.shape[a]
        s_prev = cp_wkv_scan(w_b, kv, mesh, seq_axis="model",
                             spec=cfg.scan_spec,
                             batch_sharded=(B % n_bt == 0))
        s_final = None  # training path: final state unused
    elif cache is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        s_prev, s_final = wkv_scan_chunked(w_b, kv, s0)
    elif S == 1:  # decode
        s0 = cache["state"]
        s_prev = s0[:, None]
        s_final = w_b[:, 0] * s0 + kv[:, 0]
    else:  # prefill into cache
        s_prev, s_final = wkv_scan_chunked(w_b, kv, cache["state"])

    att = s_prev + u.astype(jnp.float32)[..., :, None] * kv
    out = jnp.einsum("bshi,bshij->bshj", r.astype(jnp.float32), att)
    # per-head RMS norm (stand-in for reference group-norm)
    var = jnp.mean(out * out, axis=-1, keepdims=True)
    out = out * lax.rsqrt(var + cfg.norm_eps)
    out = (out.reshape(B, S, d).astype(x.dtype)) * g
    x = x + constrain(jnp.einsum("bse,ed->bsd", out, p["wo"]),
                      "batch", "seq", "embed_act")

    # ---------------- channel mix ----------------
    xn2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
    prev2 = cache["cm_shift"] if cache is not None else None
    xp2 = token_shift(xn2, prev2)
    xk2 = _lerp(xn2, xp2, p["mu_ck"])
    xr2 = _lerp(xn2, xp2, p["mu_cr"])
    kk = constrain(jnp.einsum("bsd,df->bsf", xk2, p["cm_wk"]),
                   "batch", "seq", "mlp")
    kk = jnp.square(jax.nn.relu(kk))
    cm = jnp.einsum("bsf,fd->bsd", kk, p["cm_wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr2, p["cm_wr"]))
    x = x + rr * cm

    new_cache = None
    if cache is not None:
        new_cache = {"shift": xn[:, -1:], "cm_shift": xn2[:, -1:],
                     "state": s_final}
    return x, new_cache


def init_rwkv_cache(cfg, batch, dtype):
    d = cfg.d_model
    H = d // HEAD_DIM
    return {
        "shift": jnp.zeros((batch, 1, d), dtype),
        "cm_shift": jnp.zeros((batch, 1, d), dtype),
        "state": jnp.zeros((batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
    }
