"""Context-parallel SSM/linear-attention prefill via the paper's exscan.

With the sequence sharded over the data axis, each device scans only its
local chunk; the carry-in state of device r is the composition of ALL
earlier devices' chunk summaries — exactly an exclusive prefix "sum"
under the (associative, expensive, non-commutative) state-composition
operator:

    mamba / diagonal SSM:  (A, B) with  h_out = A * h_in + B      (AFFINE)
    rwkv wkv state:        (w, S) with  S_out = diag(w) S_in + S  (AFFINE,
                            decay broadcast over the value dim)

This is the paper's headline scenario: m is small (one state vector),
⊕ is costly, and the number of communication rounds dominates — the
123-doubling algorithm performs q = ceil(log2(p-1)+log2(4/3)) ppermute
rounds with q-1 state compositions, vs 1+ceil(log2(p-1)) rounds for the
shift-based scan and ~2 log2 p compositions for two-⊕ doubling.  Both
entry points take a :class:`~repro.core.scan_api.ScanSpec` (default
``algorithm="auto"``: the planner weighs rounds against the AFFINE
monoid's ⊕ cost and picks accordingly); the legacy ``algorithm=`` string
kwarg is kept as a compatibility alias.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.scan_api import ScanSpec, scan
from repro.models.mamba import ssm_scan_chunked
from repro.models.rwkv import wkv_scan_chunked

# Default policy for the chunk-summary carry: AFFINE state composition,
# planner-selected algorithm.
CARRY_SPEC = ScanSpec(kind="exclusive", monoid="affine", algorithm="auto")


def _batch_spec(mesh, batch_sharded):
    if not batch_sharded:
        return None
    bt = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return bt or None


def _carry_spec(spec: ScanSpec | None, algorithm: str | None,
                seq_axis: str) -> ScanSpec:
    """Resolve the (spec, legacy algorithm kwarg) pair onto seq_axis."""
    spec = spec if spec is not None else CARRY_SPEC
    if algorithm is not None:  # legacy string path
        spec = spec.over(seq_axis, algorithm=algorithm)
    return spec.over(seq_axis, kind="exclusive", monoid="affine")


def cp_ssm_scan(a, b, mesh, *, seq_axis: str = "data",
                spec: ScanSpec | None = None,
                algorithm: str | None = None,
                batch_sharded: bool = False):
    """Distributed h_t = a_t h_{t-1} + b_t with seq sharded over
    ``seq_axis``.  a, b: (B, S_global, ...) logically; returns h of the
    same shape.  Call under jit with ``mesh`` set."""
    cspec = _carry_spec(spec, algorithm, seq_axis)

    def local(a_l, b_l):
        Bsz = a_l.shape[0]
        h0 = jnp.zeros((Bsz, *a_l.shape[2:]), a_l.dtype)
        # local chunk scan (Pallas kernel on TPU; XLA scan elsewhere)
        hs, _ = ssm_scan_chunked(a_l, b_l, h0)
        # chunk summary: A_total = prod a, B_total = h_final from zero
        a_tot = jnp.prod(a_l, axis=1)
        b_tot = hs[:, -1]
        # cross-device carry: the paper's collective, AFFINE monoid
        _a_in, b_in = scan((a_tot, b_tot), cspec)
        # carry entering this shard: global h0 = 0, so h_in = B-part
        h_in = b_in
        # correct local states:  h'_t = cum_a_t * h_in + h_t
        cum_a = jnp.cumprod(a_l, axis=1)
        hs = hs + cum_a * h_in[:, None]
        return hs

    bspec = _batch_spec(mesh, batch_sharded)
    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bspec, seq_axis), P(bspec, seq_axis)),
        out_specs=P(bspec, seq_axis),
        check_vma=False,
    )(a, b)


def cp_wkv_scan(w, kv, mesh, *, seq_axis: str = "data",
                spec: ScanSpec | None = None,
                algorithm: str | None = None,
                batch_sharded: bool = False):
    """Distributed RWKV wkv state scan, sequence-sharded.

    w: (B, S, H, hd, 1) decays; kv: (B, S, H, hd, hd) outer products.
    Returns the *pre-update* state S_{t-1} per position (as rwkv_block
    consumes) for the full sequence."""
    cspec = _carry_spec(spec, algorithm, seq_axis)

    def local(w_l, kv_l):
        Bsz = w_l.shape[0]
        s0 = jnp.zeros((Bsz, *kv_l.shape[2:]), kv_l.dtype)
        s_prev, s_final = wkv_scan_chunked(w_l, kv_l, s0)
        w_tot = jnp.prod(w_l, axis=1)
        w_in, s_in = scan((w_tot, s_final), cspec)
        # correct: S'_prev[t] = cumw_prev[t] * s_in + s_prev[t]
        cum_w = jnp.cumprod(w_l, axis=1)
        cum_w_prev = jnp.concatenate(
            [jnp.ones_like(cum_w[:, :1]), cum_w[:, :-1]], axis=1)
        return s_prev + cum_w_prev * s_in[:, None]

    bspec = _batch_spec(mesh, batch_sharded)
    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bspec, seq_axis), P(bspec, seq_axis)),
        out_specs=P(bspec, seq_axis),
        check_vma=False,
    )(w, kv)
