"""Mamba (selective SSM) block: chunked scan, O(chunk) state memory.

TPU adaptation (DESIGN §2/§3): the (B, S, d_inner, d_state) step tensors
are never materialized for the whole sequence — an outer ``lax.scan``
walks ``ssm_chunk``-sized chunks carrying the (B, d_inner, d_state)
boundary state, and within a chunk a log-depth ``associative_scan``
solves the recurrence on the VPU.  On TPU the inner scan is served by
the Pallas kernel (kernels/ssm_chunk_scan.py); the XLA formulation here
is used on CPU and for the 512-device dry-run lowering.

Context parallelism: when the sequence is sharded, the chunk-boundary
carry across *devices* is composed with the paper's 123-doubling exscan
under the AFFINE monoid (models/context_parallel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.monoid import affine_combine as _affine
from repro.models import params as P
from repro.models.common import rmsnorm
from repro.sharding.ctx import constrain

SSM_CHUNK = 64


def ssm_scan_chunked(a, b, h0, chunk=SSM_CHUNK):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: (B, S, ...).

    Returns (h: (B, S, ...), h_final: (B, ...)).
    """
    Bsz, S = a.shape[:2]
    if S % chunk:
        chunk = S  # short sequences: single chunk
    n = S // chunk
    a_c = a.reshape(Bsz, n, chunk, *a.shape[2:]).swapaxes(0, 1)
    b_c = b.reshape(Bsz, n, chunk, *b.shape[2:]).swapaxes(0, 1)

    def body(h_in, ab):
        ac, bc = ab
        cum_a, cum_b = lax.associative_scan(_affine, (ac, bc), axis=1)
        h = cum_a * h_in[:, None] + cum_b
        return h[:, -1], h

    h_final, hs = lax.scan(body, h0, (a_c, b_c))
    hs = hs.swapaxes(0, 1).reshape(Bsz, S, *a.shape[2:])
    return hs, h_final


def _causal_conv(x, conv_w, conv_b, prev=None):
    """Depthwise causal conv along seq.  x: (B,S,di), conv_w: (K,di).

    prev: (B, K-1, di) carry for decode/chunked mode (None = zero pad).
    Returns (y, new_prev)."""
    K = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * conv_w[i] for i in range(K)
    )
    return y + conv_b, xp[:, -(K - 1):]


def mamba_block(cfg, p, x, *, cache=None):
    """Pre-norm Mamba sub-block.  x: (B, S, d).

    cache (decode): {"conv": (B, K-1, di), "h": (B, di, ds)}.
    Returns (residual_out, new_cache)."""
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    dtr = P.dt_rank(cfg)
    xn = rmsnorm(x, p["norm1"], cfg.norm_eps)
    xz = constrain(jnp.einsum("bsd,de->bse", xn, p["in_proj"]),
                   "batch", "seq", "d_inner")
    x_in, z = jnp.split(xz, 2, axis=-1)

    conv_prev = cache["conv"] if cache is not None else None
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_prev)
    x_c = jax.nn.silu(x_c)

    dbc = jnp.einsum("bsi,ie->bse", x_c, p["x_proj"])
    dt_raw = dbc[..., :dtr]
    b_ssm = dbc[..., dtr : dtr + ds]
    c_ssm = dbc[..., dtr + ds :]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"]) + p["dt_bias"]
    )  # (B,S,di)
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, ds)
    # discretize: a = exp(dt*A) ; b = dt * B_t * x_t
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * a_mat)  # (B,S,di,ds)
    b = (dt * x_c).astype(jnp.float32)[..., None] * \
        b_ssm.astype(jnp.float32)[:, :, None, :]

    if cache is None:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
        hs, h_final = ssm_scan_chunked(a, b, h0)
        new_h = h_final
    elif S == 1:  # decode
        h0 = cache["h"]
        hs = a * h0[:, None] + b
        new_h = hs[:, -1]
    else:  # prefill into cache
        hs, new_h = ssm_scan_chunked(a, b, cache["h"])
    y = jnp.einsum("bsin,bsn->bsi", hs, c_ssm.astype(jnp.float32))
    y = (y.astype(x.dtype) + x_c * p["d_skip"]) * jax.nn.silu(z)
    out = constrain(jnp.einsum("bsi,id->bsd", y, p["out_proj"]),
                    "batch", "seq", "embed_act")
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": new_h}
    return x + out, new_cache


def init_mamba_cache(cfg, batch, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }
