"""Expert-parallel MoE layer with exscan-based global dispatch accounting.

Design (DESIGN.md §3.1): experts are sharded over the "model" mesh axis
(EP == TP degree); tokens travel to their experts with a single
``all_to_all`` per direction inside ``shard_map``.  Buffers are
capacity-padded — (src, expert)-capacity ``cap`` keeps every shape
static — and the *drop policy* is GLOBAL and deterministic: a token is
kept iff its global position within its expert (across all token-holding
devices) is under the expert's global capacity.  That global position is

    global_pos = exscan(per-device expert counts)[expert] + local_pos

computed with the paper's exclusive scan over the data axes — a
(num_experts,)-int vector per MoE layer per step: exactly the small-m,
latency-dominated regime the paper targets.  The capacity accounting
also needs the *global* per-expert dispatch counts (the capacity
allreduce), so both ride ONE fused "scan_total" schedule
(``scan_api.scan_with_total``): at power-of-two group counts the fused
(prefix, total) butterfly delivers offsets AND totals in the
allreduce's ⌈log₂p⌉ rounds instead of exscan + allreduce back to
back.  The planner (``cfg.scan_spec``, default ``algorithm="auto"``)
picks the round-optimal schedule for the axis size; benchmarks pin
explicit algorithms via ``scan=ScanSpec(algorithm=...)`` to compare
them in-situ (each pin maps onto its with-total variant).  The fused
totals are exact dispatch counts, so the load-balance metric's
expert-fraction term comes straight from them — no second top-k pass
over the full logits outside the manual region.

The per-slot position *within* a device is the Pallas moe_routing kernel
on TPU and its pure-jnp oracle elsewhere (kernels/ops.py dispatches).
"""

from __future__ import annotations



import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import scan_api
from repro.kernels import ref as kref
from repro.models import params as PD
from repro.models.common import rmsnorm, swiglu


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _swiglu_experts(t, gate, up, down):
    """t: (E_l, n, d); weights: (E_l, d, f) / (E_l, f, d)."""
    g = jax.nn.silu(jnp.einsum("end,edf->enf", t, gate))
    u = jnp.einsum("end,edf->enf", t, up)
    return jnp.einsum("enf,efd->end", g * u, down)


def _swiglu_experts_ws(t, gate, up, down, fsdp_axes):
    """Weight-STATIONARY expert FFN (§Perf, decode cells): expert
    weights stay sharded on their d_model dim over the FSDP axes; the
    (tiny) token activations move instead — one dynamic d-slice, two
    psums of (E_l, n, f)/(E_l, n, d) activations — eliminating the
    per-step FSDP weight all-gather that dominates decode memory/wire.

    t: (E_l, n, d) full-d tokens; gate/up: (E_l, d_l, f);
    down: (E_l, f, d_l) where d_l = d / prod(fsdp_axes sizes)."""
    d_l = gate.shape[1]
    idx = jnp.int32(0)
    n_shards = 1
    for ax in fsdp_axes:
        size = lax.axis_size(ax)
        idx = idx * size + lax.axis_index(ax)
        n_shards *= size
    t_l = lax.dynamic_slice_in_dim(t, idx * d_l, d_l, axis=2)
    g = jnp.einsum("end,edf->enf", t_l, gate)
    u = jnp.einsum("end,edf->enf", t_l, up)
    g = lax.psum(g, fsdp_axes)
    u = lax.psum(u, fsdp_axes)
    h = jax.nn.silu(g) * u
    out_l = jnp.einsum("enf,efd->end", h, down)  # (E_l, n, d_l)
    # reassemble full d: every shard contributes its slice
    out = jnp.zeros(t.shape, out_l.dtype)
    out = lax.dynamic_update_slice_in_dim(out, out_l, idx * d_l, axis=2)
    return lax.psum(out, fsdp_axes)


def moe_ffn(cfg, p, x, mesh):
    """MoE feed-forward on normed input x: (B, S, d) -> (y, aux_metrics).

    Must be called under jit with shardings of ``mesh``; internally drops
    to shard_map for dispatch.
    """
    e_pad = PD.experts_padded(cfg)
    e_real = cfg.n_experts
    k = cfg.top_k
    tp = mesh.shape["model"]
    e_local = e_pad // tp
    bt = batch_axes(mesh)
    n_data = 1
    for a in bt:
        n_data *= mesh.shape[a]

    B, S, d = x.shape
    bt_w = bt  # weight FSDP axes — independent of token sharding
    if n_data > 1 and B % n_data != 0:
        # batch too small to shard (e.g. long-context decode, B=1):
        # replicate tokens over the data axes instead.
        bt = ()
        n_data = 1
    n0_full = (B // max(n_data, 1)) * S  # tokens per data-shard
    # fsdp_sp strategy: the sequence dim is ALREADY sharded over "model"
    # — each rank dispatches its own seq shard, no slicing or gather.
    seq_sp = (cfg.sharding_strategy == "fsdp_sp"
              and S % tp == 0 and S >= tp)
    # weight-stationary expert FFN for small token counts (decode):
    # moves activations instead of FSDP-gathering expert weights.
    n_fsdp = 1
    for a in bt_w:
        n_fsdp *= mesh.shape[a]
    ws = (bool(bt_w) and d % n_fsdp == 0 and B * S * k <= 4096
          and cfg.moe_weight_stationary)
    if ws:
        # ws needs IDENTICAL tokens on every FSDP rank (the d-sliced
        # partial products psum across them): replicate the (tiny)
        # token set instead of batch-sharding it.  Duplicated routing
        # for <=4096 slots is noise; the weight all-gather it replaces
        # is the whole expert stack per step.
        bt = ()
        n_data = 1
        n0_full = B * S
    # Token-split over the model axis ("sequence-parallel MoE"): each
    # model rank dispatches 1/tp of the tokens, so expert FLOPs are not
    # duplicated across TP.  Tiny decode batches fall back to the
    # replicated-dispatch path (identical y on every model rank).
    token_split = (not seq_sp) and n0_full % tp == 0 and n0_full >= tp

    def local_moe(xl, router, gate, up, down):
        # xl: (B_l, S, d) — one data-shard's tokens, full d (replicated
        # across the model axis at entry unless seq_sp).
        B_l, S_l, _ = xl.shape
        toks_all = xl.reshape(B_l * S_l, d)
        if seq_sp:
            n0 = B_l * S_l
            toks = toks_all
            scan_axes = bt + ("model",)
            n_groups = n_data * tp
        elif token_split:
            n0 = (B_l * S_l) // tp
            m_rank = lax.axis_index("model")
            toks = lax.dynamic_slice_in_dim(toks_all, m_rank * n0, n0, 0)
            scan_axes = bt + ("model",)
            n_groups = n_data * tp
        else:
            n0 = B_l * S_l
            toks = toks_all
            scan_axes = bt
            n_groups = n_data
        logits = jnp.einsum("nd,de->ne", toks, router).astype(jnp.float32)
        emask = jnp.arange(e_pad) < e_real
        logits = jnp.where(emask, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = lax.top_k(probs, k)  # (n0, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        # local positions within each expert (Pallas kernel on TPU)
        positions, counts = kref.moe_routing_ref(top_e, e_pad)
        counts = counts.astype(jnp.int32)  # (e_pad,)

        # ---- the paper's collective: global dispatch offsets fused
        # with the capacity allreduce (one scan_total schedule) ----
        if len(scan_axes) >= 1 and n_groups > 1:
            offsets, totals = scan_api.scan_with_total(
                counts, cfg.scan_spec.over(
                    scan_axes if len(scan_axes) > 1 else scan_axes[0],
                    kind="exclusive", monoid="add"))
        else:
            offsets = jnp.zeros_like(counts)
            totals = counts

        cap = max(8, int(cfg.capacity_factor * n0 * k / e_pad))
        cap_global = cap * n_groups
        flat_e = top_e.reshape(-1)  # (n0*k,)
        flat_pos = positions.reshape(-1)
        global_pos = offsets[flat_e] + flat_pos
        keep = (flat_pos < cap) & (global_pos < cap_global)

        # scatter into (e_pad * cap, d) send buffer (drop out-of-bounds)
        slot = jnp.where(keep, flat_e * cap + flat_pos, e_pad * cap)
        toks_rep = jnp.repeat(toks, k, axis=0)  # (n0*k, d)
        buf = jnp.zeros((e_pad * cap, d), xl.dtype)
        buf = buf.at[slot].set(toks_rep, mode="drop")

        # dispatch: (tp, e_local*cap, d) -> all_to_all over "model"
        buf = buf.reshape(tp, e_local * cap, d)
        recv = lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                              tiled=False)
        # recv: (tp_src, e_local, cap, d) -> (e_local, tp_src*cap, d)
        recv = recv.reshape(tp, e_local, cap, d).transpose(1, 0, 2, 3)
        recv = recv.reshape(e_local, tp * cap, d)

        if ws:
            out = _swiglu_experts_ws(recv, gate, up, down, bt_w)
        else:
            out = _swiglu_experts(recv, gate, up, down)

        # reverse trip
        out = out.reshape(e_local, tp, cap, d).transpose(1, 0, 2, 3)
        out = out.reshape(tp, e_local * cap, d)
        back = lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                              tiled=False)
        back = back.reshape(e_pad * cap, d)

        # combine: gather own slots, weight by (renormalized) gate probs
        got = jnp.take(back, jnp.minimum(slot, e_pad * cap - 1), axis=0)
        valid = (keep & (slot < e_pad * cap))[:, None]
        got = jnp.where(valid, got, 0)
        weighted = got.reshape(n0, k, d) * top_p[..., None].astype(xl.dtype)
        y = weighted.sum(axis=1)  # (n0, d)
        kept = keep.reshape(n0, k).astype(jnp.float32)
        if token_split:
            y = lax.all_gather(y.reshape(1, n0, d), "model", axis=0,
                               tiled=True)
            kept = lax.all_gather(kept.reshape(1, n0, k), "model", axis=0,
                                  tiled=True)
        # totals: global per-expert dispatch counts (identical on every
        # rank — replicated dispatch computes the same counts, sharded
        # dispatch all-reduced them in the fused scan)
        return (y.reshape(B_l, S_l, d), kept.reshape(B_l, S_l, k),
                totals)

    bt_spec = bt if bt else None
    seq_spec = "model" if seq_sp else None
    wspec = bt_w if ws else None  # weight-stationary: keep FSDP dim
    y, kept, totals = jax.shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(
            P(bt_spec, seq_spec, None),
            P(None, None),
            P("model", wspec, None),
            P("model", wspec, None),
            P("model", None, wspec),
        ),
        out_specs=(P(bt_spec, seq_spec, None),
                   P(bt_spec, seq_spec, None),
                   P(None)),
        check_vma=False,
    )(x, p["router"], p["moe_gate"], p["moe_up"], p["moe_down"])

    # ---- metrics computed under GSPMD (outside the manual region) ----
    # the fused scan's totals are the exact global (token, slot) counts
    # per expert, so the load-balance fraction term needs no second
    # routing pass: frac_e = totals_e / n_tokens
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    emask = jnp.arange(e_pad) < e_real
    logits = jnp.where(emask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    frac = totals.astype(jnp.float32) / (B * S)
    pmean = probs.reshape(-1, e_pad).mean(axis=0)
    lb = e_real * jnp.sum(frac[:e_real] * pmean[:e_real]) / k
    dropped = 1.0 - jnp.mean(kept)
    aux = jnp.stack([lb, dropped])
    return y, aux


def moe_block(cfg, p, x, mesh):
    """Pre-norm MoE FFN sub-block with optional shared experts."""
    xn = rmsnorm(x, p["norm2"], cfg.norm_eps)
    y, aux = moe_ffn(cfg, p, xn, mesh)
    if cfg.n_shared_experts:
        y = y + swiglu(xn, p["shared_gate"], p["shared_up"],
                       p["shared_down"])
    return x + y, aux
