"""Model configuration and layer-pattern machinery.

A model is a stack of ``n_layers`` layers formed by repeating a
``pattern`` unit (e.g. jamba's 8-layer mamba/attention interleave,
gemma2's local/global pair).  The stack is executed with ``lax.scan``
over pattern *repeats* so the lowered HLO contains each distinct layer
kind exactly once — essential to keep 512-device dry-run compiles fast
and the compiled program small.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Literal

from repro.core.scan_api import ScanSpec

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating pattern unit."""

    kind: str  # "attn" | "mamba" | "rwkv"
    use_moe: bool = False
    sliding_window: int = 0  # >0: local attention with this window


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert_ff: int = 0  # per-expert hidden (d_ff used if 0)

    # --- attention variants ---
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0  # gemma2 final-logit softcap
    attn_softcap: float = 0.0  # gemma2 attention-score softcap
    sliding_window: int = 0  # applied to "local" pattern positions
    local_global_period: int = 0  # gemma2: alternate local/global attn
    causal: bool = True
    encoder_only: bool = False

    # --- SSM (mamba) ---
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    attn_period: int = 0  # hybrid: one attn layer per this many layers

    # --- modality frontend stub ---
    frontend: str = ""  # "" | "vision" | "audio"
    n_prefix: int = 0  # stub prefix-embedding positions (vlm)

    # --- runtime ---
    dtype: str = "bfloat16"
    # Scan collective policy for every exscan site (MoE dispatch,
    # context-parallel SSM/WKV carries, gradient compression): the
    # planner resolves "auto" per call site from (p, payload bytes,
    # monoid cost) — see core/scan_api.py and DESIGN.md §7.  Call sites
    # read ``cfg.scan_spec`` and re-target it with ``.over(axes, ...)``.
    scan: ScanSpec = ScanSpec(kind="exclusive", algorithm="auto")
    # DEPRECATED: pre-planner string knob.  When set, overrides
    # ``scan.algorithm`` (compatibility shim; use ``scan=ScanSpec(...)``).
    exscan_algorithm: str | None = None
    capacity_factor: float = 1.25
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_chunk: int = 512  # q-chunk for memory-bounded attention
    # unroll the layer stack instead of lax.scan — used by the dry-run's
    # cost probes (XLA cost_analysis counts while bodies once)
    unroll_stack: bool = False
    remat: bool = True
    remat_policy: str = "nothing"  # "nothing" | "dots"
    # decode-path MoE: keep expert weights FSDP-sharded and move the
    # (tiny) activations instead of gathering weights (§Perf)
    moe_weight_stationary: bool = True
    # parallelism strategy (sharding/rules.py):
    #   "tp"      — FSDP over (pod, data) + tensor parallel over "model"
    #   "fsdp_sp" — FSDP over all axes + sequence parallel over "model"
    #               (no per-layer TP activation reductions)
    sharding_strategy: str = "tp"

    @property
    def scan_spec(self) -> ScanSpec:
        """The effective ScanSpec, honouring the deprecated
        ``exscan_algorithm`` string override."""
        if self.exscan_algorithm is not None:
            warnings.warn(
                "ModelConfig.exscan_algorithm is deprecated; pass "
                "scan=ScanSpec(algorithm=...) instead",
                DeprecationWarning, stacklevel=2)
            return dataclasses.replace(
                self.scan, algorithm=self.exscan_algorithm)
        return self.scan

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def moe_d_ff(self) -> int:
        return self.d_expert_ff or self.d_ff

    # ----------------------- pattern -----------------------

    def pattern(self) -> tuple[LayerSpec, ...]:
        """The repeating layer unit; len divides n_layers."""
        if self.family == "ssm":
            return (LayerSpec("rwkv"),)
        if self.family == "hybrid":
            # jamba: one attention layer per `attn_period` mamba-ish
            # layers, MoE on every second layer of the unit.
            period = self.attn_period or 8
            unit = []
            for j in range(period):
                kind = "attn" if j == period // 2 else "mamba"
                unit.append(LayerSpec(kind, use_moe=(j % 2 == 1)))
            return tuple(unit)
        if self.local_global_period:
            # gemma2: (local, global) alternation
            return (
                LayerSpec("attn", use_moe=False,
                          sliding_window=self.sliding_window),
                LayerSpec("attn", use_moe=False, sliding_window=0),
            )
        moe = self.n_experts > 0
        return (LayerSpec("attn", use_moe=moe),)

    @property
    def n_repeats(self) -> int:
        unit = len(self.pattern())
        if self.n_layers % unit:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern unit {unit}"
            )
        return self.n_layers // unit

    # ----------------------- accounting -----------------------

    def param_count(self) -> int:
        """Exact parameter count (matches init_params)."""
        from repro.models import params as P  # lazy, avoids cycle

        return P.count_params(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts)."""
        from repro.models import params as P

        return P.count_params(self, active_only=True)

    def model_flops_per_token(self, seq_len: int, training: bool) -> float:
        """6·N_active per token (+ attention window term), the §Roofline
        MODEL_FLOPS convention; fwd-only is 1/3 of the training value."""
        n = self.active_param_count()
        base = 6.0 * n
        # attention score/value FLOPs: 12 * H * hd * attended_len
        attended = _mean_attended(self, seq_len)
        attn = 12.0 * self.n_heads * self.head_dim_ * attended * (
            self._attn_layer_fraction()
        )
        total = (base + attn * self.n_layers / max(self.n_layers, 1))
        return total if training else total / 3.0

    def _attn_layer_fraction(self) -> float:
        pat = self.pattern()
        return sum(1 for s in pat if s.kind == "attn") / len(pat)


def _mean_attended(cfg: ModelConfig, seq_len: int) -> float:
    if cfg.sliding_window and cfg.local_global_period:
        local = min(cfg.sliding_window, seq_len)
        full = (seq_len + 1) / 2 if cfg.causal else seq_len
        return (local + full) / 2
    if cfg.causal:
        return (seq_len + 1) / 2
    return float(seq_len)
