"""Parameter definitions: one table drives init, eval-shape, sharding.

Every layer kind declares its parameters as ``ParamDef(shape, logical
axes, init)``.  From that single source we derive:
  * ``init_params``      — PRNG materialization (smoke tests, examples),
  * ``abstract_params``  — ShapeDtypeStructs (512-device dry-run lowers
                           without allocating a byte),
  * ``logical_axes``     — pytree of logical-axis tuples consumed by
                           sharding.rules,
  * ``count_params``     — exact totals (MODEL_FLOPS accounting).

Stacked layers: block params get a leading ("layers",) axis of length
``n_repeats`` and are consumed by ``lax.scan`` (see model.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import LayerSpec, ModelConfig

LANE = 128


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "fan_in"  # "fan_in" | "zeros" | "ones" | "normal"
    # marks routed-expert weights for active-param accounting
    routed_expert: bool = False

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def vocab_padded(cfg: ModelConfig) -> int:
    """Pad vocab to a lane multiple so TP sharding always divides."""
    return round_up(cfg.vocab, LANE)


def experts_padded(cfg: ModelConfig) -> int:
    """Pad expert count to a multiple of 16 (the TP/EP degree) so the
    expert dim shards; padded experts are masked off in the router."""
    return round_up(cfg.n_experts, 16) if cfg.n_experts else 0


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


# ----------------------------- per-kind defs -----------------------------


def _ffn_defs(cfg: ModelConfig, use_moe: bool) -> dict[str, ParamDef]:
    d = cfg.d_model
    out: dict[str, ParamDef] = {"norm2": ParamDef((d,), ("norm",), "ones")}
    if not use_moe:
        ff = cfg.d_ff
        out.update(
            w_gate=ParamDef((d, ff), ("embed", "mlp")),
            w_up=ParamDef((d, ff), ("embed", "mlp")),
            w_down=ParamDef((ff, d), ("mlp", "embed")),
        )
        return out
    e = experts_padded(cfg)
    ffe = cfg.moe_d_ff
    out.update(
        router=ParamDef((d, e), ("embed", None), "normal"),
        moe_gate=ParamDef((e, d, ffe), ("experts", "embed", "expert_mlp"),
                          routed_expert=True),
        moe_up=ParamDef((e, d, ffe), ("experts", "embed", "expert_mlp"),
                        routed_expert=True),
        moe_down=ParamDef((e, ffe, d), ("experts", "expert_mlp", "embed"),
                          routed_expert=True),
    )
    if cfg.n_shared_experts:
        ffs = cfg.n_shared_experts * ffe
        out.update(
            shared_gate=ParamDef((d, ffs), ("embed", "mlp")),
            shared_up=ParamDef((d, ffs), ("embed", "mlp")),
            shared_down=ParamDef((ffs, d), ("mlp", "embed")),
        )
    return out


def _attn_defs(cfg: ModelConfig, spec: LayerSpec) -> dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.n_heads, cfg.n_kv_heads
    out = {
        "norm1": ParamDef((d,), ("norm",), "ones"),
        "wq": ParamDef((d, h * hd), ("embed", "heads")),
        "wk": ParamDef((d, kv * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, kv * hd), ("embed", "kv_heads")),
        "wo": ParamDef((h * hd, d), ("heads", "embed")),
    }
    out.update(_ffn_defs(cfg, spec.use_moe))
    return out


def _mamba_defs(cfg: ModelConfig, spec: LayerSpec) -> dict[str, ParamDef]:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    dtr = dt_rank(cfg)
    out = {
        "norm1": ParamDef((d,), ("norm",), "ones"),
        "in_proj": ParamDef((d, 2 * di), ("embed", "d_inner")),
        "conv_w": ParamDef((cfg.d_conv, di), ("conv", "d_inner")),
        "conv_b": ParamDef((di,), ("d_inner",), "zeros"),
        "x_proj": ParamDef((di, dtr + 2 * ds), ("d_inner", None)),
        "dt_proj": ParamDef((dtr, di), (None, "d_inner")),
        "dt_bias": ParamDef((di,), ("d_inner",), "zeros"),
        "a_log": ParamDef((di, ds), ("d_inner", "d_state"), "ones"),
        "d_skip": ParamDef((di,), ("d_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("d_inner", "embed")),
    }
    out.update(_ffn_defs(cfg, spec.use_moe))
    return out


def _rwkv_defs(cfg: ModelConfig, spec: LayerSpec) -> dict[str, ParamDef]:
    d, ff = cfg.d_model, cfg.d_ff
    out = {
        "norm1": ParamDef((d,), ("norm",), "ones"),
        # time-mix interpolation coefficients (token shift)
        "mu_r": ParamDef((d,), ("norm",), "zeros"),
        "mu_k": ParamDef((d,), ("norm",), "zeros"),
        "mu_v": ParamDef((d,), ("norm",), "zeros"),
        "mu_w": ParamDef((d,), ("norm",), "zeros"),
        "mu_g": ParamDef((d,), ("norm",), "zeros"),
        "wr": ParamDef((d, d), ("embed", "heads")),
        "wk": ParamDef((d, d), ("embed", "heads")),
        "wv": ParamDef((d, d), ("embed", "heads")),
        "wg": ParamDef((d, d), ("embed", "heads")),
        # data-dependent decay (Finch): w_t = exp(-exp(decay(x_t)))
        "w_decay": ParamDef((d, d), ("embed", "heads"), "zeros"),
        "decay_bias": ParamDef((d,), ("heads",), "zeros"),
        "bonus_u": ParamDef((d,), ("heads",), "zeros"),
        "wo": ParamDef((d, d), ("heads", "embed")),
        # channel mix
        "norm2": ParamDef((d,), ("norm",), "ones"),
        "mu_ck": ParamDef((d,), ("norm",), "zeros"),
        "mu_cr": ParamDef((d,), ("norm",), "zeros"),
        "cm_wk": ParamDef((d, ff), ("embed", "mlp")),
        "cm_wv": ParamDef((ff, d), ("mlp", "embed")),
        "cm_wr": ParamDef((d, d), ("embed", "mlp")),
    }
    return out


_KIND_DEFS = {"attn": _attn_defs, "mamba": _mamba_defs, "rwkv": _rwkv_defs}


def block_defs(cfg: ModelConfig, spec: LayerSpec) -> dict[str, ParamDef]:
    return _KIND_DEFS[spec.kind](cfg, spec)


def model_defs(cfg: ModelConfig):
    """Full model: returns (top_level_defs, per_position_block_defs)."""
    d = cfg.d_model
    vp = vocab_padded(cfg)
    top: dict[str, ParamDef] = {}
    if cfg.frontend != "audio":
        top["tok_embed"] = ParamDef((vp, d), ("vocab", "embed"), "normal")
    top["final_norm"] = ParamDef((d,), ("norm",), "ones")
    if not cfg.tie_embeddings:
        top["lm_head"] = ParamDef((d, vp), ("embed", "vocab"))
    blocks = tuple(block_defs(cfg, spec) for spec in cfg.pattern())
    return top, blocks


# ----------------------------- materialize -----------------------------


def _iter_defs(cfg: ModelConfig) -> Iterator[tuple[tuple, ParamDef, bool]]:
    """Yields (path, def, stacked) for every parameter."""
    top, blocks = model_defs(cfg)
    for name, d in top.items():
        yield (name,), d, False
    for j, defs in enumerate(blocks):
        for name, d in defs.items():
            yield ("blocks", j, name), d, True


def _stacked(d: ParamDef, n_repeats: int) -> ParamDef:
    return ParamDef((n_repeats, *d.shape), ("layers", *d.axes), d.init,
                    d.routed_expert)


def _build(cfg: ModelConfig, leaf_fn):
    top, blocks = model_defs(cfg)
    r = cfg.n_repeats
    out_top = {k: leaf_fn(d) for k, d in top.items()}
    out_blocks = tuple(
        {k: leaf_fn(_stacked(d, r)) for k, d in defs.items()}
        for defs in blocks
    )
    return {"top": out_top, "blocks": out_blocks}


def abstract_params(cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)

    def leaf(d: ParamDef):
        return jax.ShapeDtypeStruct(d.shape, dtype)

    return _build(cfg, leaf)


def logical_axes(cfg: ModelConfig):
    return _build(cfg, lambda d: d.axes)


def init_params(cfg: ModelConfig, key: jax.Array):
    dtype = jnp.dtype(cfg.dtype)
    defs_list = list(_iter_defs(cfg))
    keys = jax.random.split(key, len(defs_list))
    vals = {}
    r = cfg.n_repeats
    for k, (path, d, stacked) in zip(keys, defs_list):
        shape = (r, *d.shape) if stacked else d.shape
        if d.init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif d.init == "ones":
            v = jnp.ones(shape, dtype)
        elif d.init == "normal":
            v = (0.02 * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
        else:  # fan_in
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 1.0 / math.sqrt(fan_in)
            v = (scale * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
        if path[-1] == "a_log":
            # mamba: A = -exp(a_log); init a_log = log(1..d_state)
            ds = d.shape[-1]
            base = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
            v = jnp.broadcast_to(base, shape).astype(dtype)
        vals[path] = v
    top = {p[0]: v for p, v in vals.items() if len(p) == 1}
    n_pos = len(cfg.pattern())
    blocks = tuple(
        {p[2]: v for p, v in vals.items()
         if len(p) == 3 and p[0] == "blocks" and p[1] == j}
        for j in range(n_pos)
    )
    return {"top": top, "blocks": blocks}


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = 0
    r = cfg.n_repeats
    e_pad = experts_padded(cfg)
    for _, d, stacked in _iter_defs(cfg):
        n = int(np.prod(d.shape)) * (r if stacked else 1)
        if active_only and d.routed_expert and e_pad:
            n = n * cfg.top_k // e_pad
        total += n
    return total


def param_shardings(cfg: ModelConfig, mesh, rules):
    """NamedSharding pytree matching abstract_params' structure."""
    axes = logical_axes(cfg)
    shapes = abstract_params(cfg)
    return jax.tree.map(
        lambda log, shp: rules.shard(log, mesh, shp.shape),
        axes,
        shapes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(el, (str, type(None))) for el in x),
    )
