"""Request bucketing for the scan service: the admission key.

A :class:`Bucket` names one request class — (kind, monoid, per-rank
shape, dtype).  Everything the continuous batcher does hangs off this
key:

  * requests inside one bucket are *fusable*: same monoid, same kind,
    identical per-rank payload signature, so ``plan_fused`` can pack
    them into one flat buffer and ride a single schedule's rounds;
  * the plan-key space of a bucket is *closed*: the only payload sizes
    the planner ever sees are ``k * bucket.nbytes`` for batch sizes
    k in 1..max_batch, which is what makes the startup warmup contract
    (steady state never compiles) provable via ``plan_cache_info()``
    rather than hoped for.

Buckets are declared up front (``ScanService(buckets=...)``); admission
derives the key of each incoming payload with :func:`bucket_key` and
rejects shapes outside the declared set (unless the service opts into
dynamic buckets, which forfeit the warmup guarantee for their first
batches).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import monoid as monoid_lib
from repro.core.scan_api import KINDS, ScanSpec


def bucket_key(kind: str, monoid, shape, dtype) -> tuple:
    """The canonical admission key: (kind, monoid name, per-rank shape,
    numpy dtype str)."""
    return (kind, monoid_lib.get(monoid).name,
            tuple(int(d) for d in shape), np.dtype(dtype).str)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One declared request class of the scan service.

    Attributes:
      kind: scan kind ("exclusive" | "scan_total" | ...).
      monoid: monoid registry name (or Monoid; normalized to its name).
      shape: per-rank payload shape (the service adds the leading rank
        axis; scalars use ``()``).
      dtype: numpy dtype (normalized to its ``str`` form).
      name: display label for metrics/benchmark rows.
    """

    kind: str = "exclusive"
    monoid: str = "add"
    shape: tuple = ()
    dtype: str = "<i4"
    name: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        object.__setattr__(self, "monoid",
                           monoid_lib.get(self.monoid).name)
        shape = self.shape
        if isinstance(shape, int):  # shape=(5) typo-friendliness
            shape = (shape,)
        object.__setattr__(self, "shape",
                           tuple(int(d) for d in shape))
        object.__setattr__(self, "dtype", np.dtype(self.dtype).str)
        if not self.name:
            shp = "x".join(map(str, self.shape)) or "scalar"
            object.__setattr__(
                self, "name",
                f"{self.kind}/{self.monoid}/{shp}/"
                f"{np.dtype(self.dtype).name}")

    @property
    def key(self) -> tuple:
        return bucket_key(self.kind, self.monoid, self.shape,
                          self.dtype)

    @property
    def nbytes(self) -> int:
        """Per-rank payload bytes m — the planner's message size."""
        return int(math.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def spec(self, axis_name=None) -> ScanSpec:
        """The ScanSpec every request in this bucket plans under."""
        return ScanSpec(kind=self.kind, monoid=self.monoid,
                        algorithm="auto", axis_name=axis_name,
                        payload_bytes=self.nbytes)

    def validate(self, payload, p: int) -> np.ndarray:
        """Check ``payload`` is a (p, *shape) array of this bucket's
        dtype; returns it as numpy.  Raises ValueError on mismatch."""
        arr = np.asarray(payload)
        want = (p,) + self.shape
        if arr.shape != want:
            raise ValueError(
                f"bucket {self.name!r} expects payload shape {want}, "
                f"got {arr.shape}")
        if np.dtype(arr.dtype).str != self.dtype:
            raise ValueError(
                f"bucket {self.name!r} expects dtype {self.dtype}, "
                f"got {np.dtype(arr.dtype).str}")
        return arr


def bucket_of(payload, *, kind: str = "exclusive",
              monoid: str = "add") -> Bucket:
    """Derive the bucket a (p, *shape) payload belongs to (the leading
    axis is the rank axis and is NOT part of the bucket shape)."""
    arr = np.asarray(payload)
    if arr.ndim < 1:
        raise ValueError("service payloads carry a leading rank axis; "
                         f"got a {arr.ndim}-d array")
    return Bucket(kind=kind, monoid=monoid, shape=arr.shape[1:],
                  dtype=arr.dtype)
