"""ScanService: continuous batching of live scan requests.

The paper's small-payload scans are latency-bound — cost ≈ α·q, not
bytes — so a service facing many concurrent small exscan requests wins
exactly one way: amortize the α·q round cost across requests.
``fused_scan``/``plan_fused`` already do that for a static list; this
module is the dynamic version — the LightScan-style continuous-batching
loop over live traffic:

    submit(payload) ──admission──▶ bucket queues ──tick──▶ batches
                                                     │
                                    plan_fused(k specs) per bucket
                                      ├─ fused:  ONE packed schedule,
                                      │          k requests / α·q rounds
                                      └─ serial: k solo plans (the cost
                                                 model said packing loses)

Admission is by :class:`~repro.serve.bucket.Bucket` key (kind, monoid,
per-rank shape, dtype) with queue-depth backpressure; each ``tick``
drains up to ``max_batch`` compatible requests per bucket into one
``plan_fused`` decision and executes it.  Clocking is caller-supplied
(``now``) so the same service runs under the benchmark's virtual clock
or a wall clock; execution time is measured for real around the
executor and pushed onto the clock, which is what makes queueing delay
— and therefore p50/p99 latency vs request rate — come out of the
bench honestly.

Warmup contract: a bucket's plan-key space is closed — the only
payload sizes the planner can see are k·bucket.nbytes for
k in 1..max_batch — so :meth:`ScanService.warmup` primes every
(bucket, k) plan up front and :attr:`ScanService.post_warmup_compiles`
(the ``plan_cache_info()`` miss counter delta) proves steady state
never compiles.  The serve bench gates on it being zero.

Deadline semantics: deadlines are *admission-to-start* — a request
whose deadline has passed when its bucket is drained is dropped
(status "timeout", never executed, counted in metrics); once a request
makes it into an executing batch it completes even if its deadline
expires mid-execution (the batch is already on the wire).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any

from repro.core import schedule as schedule_lib
from repro.core.scan_api import (
    current_cost_model, plan_cache_info, plan_fused)
from repro.serve.bucket import Bucket, bucket_key
from repro.serve.metrics import ServiceMetrics


class AdmissionError(RuntimeError):
    """A request the service refused to queue.

    ``reason`` is machine-readable: "unknown_bucket" (shape/dtype/
    monoid outside the declared set), "overload" (queue-depth
    backpressure — retry later), or "bad_payload" (malformed array).
    """

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


@dataclasses.dataclass
class ScanRequest:
    """One queued scan: payload + bucket + timing.

    ``status`` walks queued → done | timeout.  ``result`` is the scan
    output (for scan_total buckets: the (prefix, total) tuple);
    ``latency`` is completion time minus submit time under the
    service clock.
    """

    rid: int
    bucket: Bucket
    payload: Any
    t_submit: float
    deadline: float | None = None
    status: str = "queued"
    result: Any = None
    t_done: float | None = None

    @property
    def latency(self) -> float | None:
        return None if self.t_done is None else \
            self.t_done - self.t_submit


class ScanService:
    """Continuous-batching scan server over one executor.

    Args:
      p: rank count every request's payload carries (leading axis).
      buckets: declared :class:`Bucket` set — the admissible request
        classes.  Warmup covers exactly these.
      axis_name: mesh axis for the specs (None for the simulator).
      max_batch: per-bucket batch-occupancy cap per tick (also the
        warmup's largest primed k).
      max_queue: total queued-request cap; admission beyond it raises
        ``AdmissionError("overload")`` — the backpressure signal.
      default_timeout: seconds after submit at which an un-started
        request is dropped (None: requests never expire).
      executor: schedule executor (default: the numpy
        ``SimulatorExecutor`` — device-free serving, exact stats).
      cost_model: pricing for the fuse-vs-serial decision (default:
        the ambient model at construction, captured so warmup and
        steady state share one plan-cache key space).
      admit_unknown: auto-declare buckets for unseen shapes instead of
        rejecting (forfeits the warmup guarantee for their first
        batches; off by default).
    """

    def __init__(self, p: int, buckets, *, axis_name=None,
                 max_batch: int = 16, max_queue: int = 256,
                 default_timeout: float | None = None,
                 executor=None, cost_model=None,
                 admit_unknown: bool = False):
        if p < 1:
            raise ValueError(f"need p >= 1 ranks, got {p}")
        if max_batch < 1:
            raise ValueError(f"need max_batch >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"need max_queue >= 1, got {max_queue}")
        self.p = int(p)
        self.axis_name = axis_name
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.default_timeout = default_timeout
        self.executor = executor if executor is not None else \
            schedule_lib.SimulatorExecutor()
        self.cost_model = cost_model if cost_model is not None else \
            current_cost_model()
        self.admit_unknown = bool(admit_unknown)
        self.buckets: dict[tuple, Bucket] = {}
        self._queues: dict[tuple, deque] = {}
        for b in buckets:
            if b.key in self.buckets:
                raise ValueError(f"duplicate bucket {b.name!r}")
            self.buckets[b.key] = b
            self._queues[b.key] = deque()
        self.metrics = ServiceMetrics()
        self._rid = itertools.count()
        self._rr = 0  # round-robin offset across bucket queues
        self._now = 0.0
        self._warmup_misses: int | None = None
        self.last_decision = None  # the latest batch's FusedPlan
        self._autotuner = None
        self._autotune_tier = "ici"

    # -- clock ---------------------------------------------------------

    @property
    def now(self) -> float:
        """The service clock: max of every caller-supplied ``now`` and
        the accumulated execution time."""
        return self._now

    def _advance(self, now) -> float:
        if now is not None:
            self._now = max(self._now, float(now))
        return self._now

    # -- admission -----------------------------------------------------

    @property
    def depth(self) -> int:
        """Total queued (not yet executed) requests."""
        return sum(len(q) for q in self._queues.values())

    def submit(self, payload, *, kind: str = "exclusive",
               monoid: str = "add", now: float | None = None,
               deadline: float | None = None,
               timeout: float | None = None) -> ScanRequest:
        """Admit one request, or raise :class:`AdmissionError`.

        ``deadline`` is absolute (service clock); ``timeout`` is
        relative to now and wins over ``default_timeout``.  Returns the
        queued :class:`ScanRequest` (its ``result`` materializes after
        a ``tick`` executes the batch it lands in).
        """
        t = self._advance(now)
        self.metrics.submitted += 1
        import numpy as np

        arr = np.asarray(payload)
        if arr.ndim < 1 or arr.shape[0] != self.p:
            self.metrics.rejected_unknown += 1
            raise AdmissionError(
                "bad_payload",
                f"payload must carry a leading rank axis of {self.p}; "
                f"got shape {arr.shape}")
        key = bucket_key(kind, monoid, arr.shape[1:], arr.dtype)
        bucket = self.buckets.get(key)
        if bucket is None:
            if not self.admit_unknown:
                self.metrics.rejected_unknown += 1
                raise AdmissionError(
                    "unknown_bucket",
                    f"no declared bucket for key {key}; declared: "
                    f"{[b.name for b in self.buckets.values()]}")
            bucket = Bucket(kind=kind, monoid=monoid,
                            shape=arr.shape[1:], dtype=arr.dtype)
            self.buckets[key] = bucket
            self._queues[key] = deque()
        if self.depth >= self.max_queue:
            self.metrics.rejected_overload += 1
            raise AdmissionError(
                "overload",
                f"queue depth {self.depth} at max_queue="
                f"{self.max_queue}; backpressure — retry later")
        arr = bucket.validate(arr, self.p)
        if timeout is not None:
            deadline = t + timeout
        elif deadline is None and self.default_timeout is not None:
            deadline = t + self.default_timeout
        req = ScanRequest(rid=next(self._rid), bucket=bucket,
                          payload=arr, t_submit=t, deadline=deadline)
        self._queues[key].append(req)
        self.metrics.admitted += 1
        self.metrics.queue_depth = self.depth
        return req

    # -- warmup --------------------------------------------------------

    def warmup(self) -> dict:
        """Prime the plan cache over the closed plan-key space of the
        declared buckets: every (bucket, batch size k) for k in
        1..max_batch — both the k solo plans and the packed-payload
        candidate ``plan_fused`` prices (planning builds the schedule
        IR too, so no tick ever traces a new round structure).  Records
        the cache-miss baseline that
        :attr:`post_warmup_compiles` measures against.
        """
        primed = 0
        for bucket in self.buckets.values():
            spec = bucket.spec(self.axis_name)
            for k in range(1, self.max_batch + 1):
                plan_fused([spec] * k, self.p, [bucket.nbytes] * k,
                           cost_model=self.cost_model)
                primed += 1
        info = plan_cache_info()
        self._warmup_misses = info["misses"]
        return {"buckets": len(self.buckets),
                "fused_plans_primed": primed, "cache": info}

    def install_cost_model(self, cost_model, *,
                           rewarm: bool = True) -> dict | None:
        """Swap the service's pricing (a recalibrated profile or plain
        :class:`~repro.core.scan_api.CostModel`) and — by default —
        re-``warmup()`` immediately.

        A profile swap changes every plan-cache key the service's
        buckets resolve to, so without the re-warm the next tick of
        every (bucket, k) pair would miss the cache and re-plan inline;
        re-warming restores the zero-post-warmup-compile contract
        before any queued request is drained (the profile-swap test
        pins this).  Returns the warmup report, or None when
        ``rewarm=False`` (the caller owns the warmup timing)."""
        self.cost_model = cost_model
        return self.warmup() if rewarm else None

    def attach_autotuner(self, tuner, *, tier: str | None = None):
        """Wire a :class:`~repro.core.autotune.AutoTuner` into the
        serving loop: every executed batch feeds one measured sample
        (features summed over the batch's executed schedules against
        the measured execution seconds), ``tick`` drives the refit
        cadence, and an install triggers :meth:`install_cost_model`
        so the zero-compile contract survives the swap."""
        self._autotuner = tuner
        if tier is not None:
            self._autotune_tier = tier
        else:
            prof = tuner.profile
            self._autotune_tier = prof.tier_for_axis(self.axis_name) \
                if hasattr(prof, "tier_for_axis") else "ici"
        tuner.subscribe(lambda profile: self.install_cost_model(
            profile, rewarm=self._warmup_misses is not None))
        return tuner

    @property
    def post_warmup_compiles(self) -> int | None:
        """Plan-cache misses since :meth:`warmup` (None before warmup).
        The steady-state contract — and the serve bench's CI gate — is
        that this stays 0: every batch size of every declared bucket
        was primed, so serving never compiles."""
        if self._warmup_misses is None:
            return None
        return plan_cache_info()["misses"] - self._warmup_misses

    # -- the continuous batcher ----------------------------------------

    def _expire(self, queue: deque, now: float) -> list[ScanRequest]:
        expired = []
        kept = deque()
        for req in queue:
            if req.deadline is not None and req.deadline <= now:
                req.status = "timeout"
                req.t_done = now
                self.metrics.timed_out += 1
                expired.append(req)
            else:
                kept.append(req)
        queue.clear()
        queue.extend(kept)
        return expired

    def tick(self, now: float | None = None) -> list[ScanRequest]:
        """One batcher step: for each bucket with queued requests
        (round-robin start for fairness), drop expired requests, drain
        up to ``max_batch`` into ONE ``plan_fused`` decision, execute
        it, and stamp completions.  Returns every request finalized
        this tick (done and timed out); the clock advances by the
        measured execution seconds, so latencies include queueing AND
        service time."""
        self._advance(now)
        finalized: list[ScanRequest] = []
        keys = list(self._queues)
        if keys:
            self._rr = (self._rr + 1) % len(keys)
            keys = keys[self._rr:] + keys[:self._rr]
        for key in keys:
            queue = self._queues[key]
            finalized.extend(self._expire(queue, self._now))
            if not queue:
                continue
            batch = [queue.popleft()
                     for _ in range(min(self.max_batch, len(queue)))]
            finalized.extend(self._run_batch(self.buckets[key], batch))
        self.metrics.queue_depth = self.depth
        if self._autotuner is not None:
            # the refit cadence rides the batcher: an install fires
            # the attach-time subscriber, which re-prices and re-warms
            self._autotuner.maybe_refit()
        return finalized

    def _run_batch(self, bucket: Bucket,
                   batch: list[ScanRequest]) -> list[ScanRequest]:
        spec = bucket.spec(self.axis_name)
        k = len(batch)
        t0 = time.perf_counter()
        fp = plan_fused([spec] * k, self.p, [bucket.nbytes] * k,
                        cost_model=self.cost_model)
        self.last_decision = fp
        xs = [req.payload for req in batch]
        t_exec = time.perf_counter()
        with schedule_lib.collect_stats() as st:
            results = fp.execute(xs, executor=self.executor)
        t1 = time.perf_counter()
        seconds = t1 - t0
        self._now += seconds
        if self._autotuner is not None:
            # execution-only seconds against the executed schedules'
            # exact pricing features (planning time is not fabric time)
            if fp.fused:
                scheds = [fp.packed.schedule()]
                sizes = [fp.packed.payload_bytes]
            else:
                scheds = [pl.schedule() for pl in fp.plans]
                sizes = [pl.payload_bytes for pl in fp.plans]
            self._autotuner.record(
                scheds, sizes, t1 - t_exec, tier=self._autotune_tier,
                monoid=bucket.monoid, stats=st,
                algorithm=fp.packed.algorithm, kind=bucket.kind)
        serial_rounds = sum(pl.rounds for pl in fp.plans)
        self.metrics.record_batch(
            k, fused=fp.fused, rounds=st.rounds,
            serial_rounds=serial_rounds, ops=st.op_applications,
            seconds=seconds)
        for req, res in zip(batch, results):
            req.result = res
            req.status = "done"
            req.t_done = self._now
            self.metrics.record_completion(req.latency)
        return batch

    def drain(self, now: float | None = None, *,
              max_ticks: int = 10_000) -> list[ScanRequest]:
        """Tick until every queue is empty; returns all finalized
        requests.  ``max_ticks`` guards against a caller submitting
        faster than the loop drains (raises RuntimeError)."""
        self._advance(now)
        done: list[ScanRequest] = []
        for _ in range(max_ticks):
            if self.depth == 0:
                return done
            done.extend(self.tick())
        raise RuntimeError(
            f"drain() did not empty the queues in {max_ticks} ticks "
            f"(depth={self.depth})")

    def reset_metrics(self) -> ServiceMetrics:
        """Fresh metrics (benchmark phases); the warmup baseline and
        queues are untouched."""
        self.metrics = ServiceMetrics()
        self.metrics.queue_depth = self.depth
        return self.metrics
