"""Request generators: the scan service's live traffic, drawn from the
real consumers.

Two request classes dominate the repo's small-m scan traffic, and both
generators here are wired to the exact code those consumers run:

  * **MoE dispatch** (``models/moe.py``): per step, per MoE layer, each
    data-rank exscans its per-expert dispatch counts AND allreduces the
    capacity totals — ONE fused scan_total of a (e_pad,)-int32 vector.
    :func:`moe_dispatch_payload` routes random tokens through the same
    ``kernels.ref.moe_routing_ref`` oracle the layer uses (the Pallas
    kernel's reference), so the count vectors have the layer's real
    distribution, and :func:`moe_bucket` derives e_pad from the same
    ``models.params.experts_padded`` padding rule.

  * **Gradient-compression offsets** (``optim/compression.py``): the
    compact-layout offset per leaf group is an exclusive scan of a
    per-rank scalar slot count — k concurrent scalar exscans per sync.
    :func:`compression_offset_payloads` computes the counts with the
    module's own :func:`~repro.optim.compression.leaf_slot_counts`
    (optionally jittered, the variable-count thresholding case).

Arrival processes are the benchmark's job; :func:`poisson_arrivals`
builds the open-loop Poisson timeline the serve bench sweeps.
"""

from __future__ import annotations

import numpy as np

from repro.models import params as PD
from repro.optim.compression import leaf_slot_counts
from repro.serve.bucket import Bucket


# ---------------------------------------------------------------------------
# MoE dispatch-offset + capacity requests (models/moe.py traffic)
# ---------------------------------------------------------------------------


def moe_bucket(cfg, name: str = "") -> Bucket:
    """The bucket of one MoE layer's dispatch accounting: a scan_total
    (offsets fused with the capacity allreduce, exactly the
    ``scan_with_total`` call in ``models/moe.py``) of the padded
    per-expert count vector."""
    e_pad = PD.experts_padded(cfg)
    if not e_pad:
        raise ValueError("config has no experts (n_experts == 0)")
    return Bucket(kind="scan_total", monoid="add", shape=(e_pad,),
                  dtype=np.int32, name=name or "moe_dispatch")


def moe_dispatch_payload(cfg, p: int, rng: np.random.Generator,
                         n_tokens: int = 64) -> np.ndarray:
    """One request's payload: per-rank per-expert dispatch counts,
    (p, e_pad) int32 — each rank's top-k routing of ``n_tokens`` random
    tokens through the SAME counting oracle the MoE layer runs
    (``kernels.ref.moe_routing_ref``)."""
    from repro.kernels import ref as kref

    e_pad = PD.experts_padded(cfg)
    k = max(1, cfg.top_k)
    rows = []
    for _ in range(p):
        assignment = rng.integers(0, max(cfg.n_experts, 1),
                                  size=(n_tokens, k)).astype(np.int32)
        _, counts = kref.moe_routing_ref(assignment, e_pad)
        rows.append(np.asarray(counts, dtype=np.int32))
    return np.stack(rows, axis=0)


# ---------------------------------------------------------------------------
# Compression-offset requests (optim/compression.py traffic)
# ---------------------------------------------------------------------------


def compression_bucket(name: str = "") -> Bucket:
    """The bucket of one leaf group's compact-layout offset exscan: a
    per-rank scalar slot count (shape ``()``, int32)."""
    return Bucket(kind="exclusive", monoid="add", shape=(),
                  dtype=np.int32, name=name or "compression_offsets")


def compression_offset_payloads(
        p: int, leaf_sizes, k_fraction: float = 0.01, *,
        rng: np.random.Generator | None = None,
        thresholded: bool = False) -> list[np.ndarray]:
    """One gradient sync's offset-scan payloads: per leaf group, the
    (p,)-int32 per-rank slot counts — ``leaf_slot_counts`` from the
    compression module itself.  ``thresholded=True`` jitters each
    rank's count below the top-k budget (the threshold-crossing case
    where ranks genuinely differ and the exscan is load-bearing)."""
    counts = leaf_slot_counts(leaf_sizes, k_fraction)
    payloads = []
    for c in counts:
        per_rank = np.full((p,), c, dtype=np.int32)
        if thresholded:
            if rng is None:
                raise ValueError("thresholded counts need an rng")
            per_rank = rng.integers(1, c + 1, size=(p,)).astype(
                np.int32)
        payloads.append(per_rank)
    return payloads


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


def poisson_arrivals(rng: np.random.Generator, rate: float,
                     n: int) -> np.ndarray:
    """n open-loop Poisson arrival times at ``rate`` requests/second
    (exponential inter-arrivals, starting at the first gap)."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    return np.cumsum(rng.exponential(1.0 / rate, size=n))
