"""Continuous-batching scan service (DESIGN.md §8).

The serving story for the paper's latency-dominated small scans:
live requests are admitted into shape/dtype/monoid buckets, a
continuous batcher drains each bucket into ONE fused schedule per tick
(``plan_fused`` decides fuse-vs-serial by the cost model), the plan
cache is warmed over the declared bucket set at startup so steady
state never compiles, and a metrics surface reports queue depth, batch
occupancy, rounds per request and p50/p99 latency.
``benchmarks/serve_bench.py`` drives it at swept request rates.
"""

from repro.serve.bucket import Bucket, bucket_key, bucket_of
from repro.serve.metrics import ServiceMetrics, percentile
from repro.serve.service import (
    AdmissionError, ScanRequest, ScanService)
from repro.serve import workloads

__all__ = [
    "AdmissionError",
    "Bucket",
    "ScanRequest",
    "ScanService",
    "ServiceMetrics",
    "bucket_key",
    "bucket_of",
    "percentile",
    "workloads",
]
