"""Metrics surface of the scan service: counters, gauges, percentiles.

One :class:`ServiceMetrics` per service (resettable per benchmark
phase); ``snapshot()`` is the single dict shape the serve bench JSON,
the tests and any external scraper consume.  The latency list is kept
raw so percentiles are exact — the serve bench runs thousands of
requests, not millions, and p99 from a reservoir would wobble the CI
gate.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def percentile(values, q: float) -> float:
    """Exact percentile of a sequence of seconds (NaN when empty) —
    shared by the service metrics and the launch drivers' per-step
    latency reporting."""
    vals = [v for v in values if v is not None]
    if not vals:
        return float("nan")
    return float(np.percentile(np.asarray(vals, dtype=np.float64), q))


@dataclasses.dataclass
class ServiceMetrics:
    """Counters and distributions of one service (or bench phase).

    Round accounting keeps BOTH sides of the paper's claim: of every
    executed batch the service records the rounds it actually paid
    (``rounds_executed`` — measured by ``collect_stats`` around the
    real execution, not predicted) and what the same requests would
    have paid served serially (``rounds_serial_equiv`` — the sum of the
    k solo plans' rounds).  Their ratio is the fused-batching win the
    serve bench gates on.
    """

    submitted: int = 0
    admitted: int = 0
    rejected_overload: int = 0  # queue-depth backpressure
    rejected_unknown: int = 0  # shape/dtype/monoid outside the buckets
    completed: int = 0
    timed_out: int = 0
    batches: int = 0
    fused_batches: int = 0
    occupancy_sum: int = 0
    rounds_executed: int = 0
    rounds_serial_equiv: int = 0
    ops_executed: int = 0
    service_seconds: float = 0.0
    latencies: list = dataclasses.field(default_factory=list)
    queue_depth: int = 0  # gauge: set by the service every tick

    @property
    def rejected(self) -> int:
        return self.rejected_overload + self.rejected_unknown

    def record_batch(self, k: int, *, fused: bool, rounds: int,
                     serial_rounds: int, ops: int, seconds: float):
        self.batches += 1
        self.fused_batches += 1 if fused else 0
        self.occupancy_sum += k
        self.rounds_executed += rounds
        self.rounds_serial_equiv += serial_rounds
        self.ops_executed += ops
        self.service_seconds += seconds

    def record_completion(self, latency: float):
        self.completed += 1
        self.latencies.append(latency)

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches \
            else float("nan")

    @property
    def rounds_per_request(self) -> float:
        return self.rounds_executed / self.completed if self.completed \
            else float("nan")

    @property
    def fused_round_win(self) -> float:
        """serial-equivalent rounds / executed rounds (>1 means the
        continuous batcher amortized α·q across requests)."""
        return self.rounds_serial_equiv / self.rounds_executed \
            if self.rounds_executed else float("nan")

    def latency_percentile(self, q: float) -> float:
        return percentile(self.latencies, q)

    def snapshot(self) -> dict:
        """The one metrics shape everything consumes (bench JSON rows,
        tests, scrapers)."""
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "rejected_overload": self.rejected_overload,
            "rejected_unknown": self.rejected_unknown,
            "completed": self.completed,
            "timed_out": self.timed_out,
            "queue_depth": self.queue_depth,
            "batches": self.batches,
            "fused_batches": self.fused_batches,
            "mean_occupancy": self.mean_occupancy,
            "rounds_executed": self.rounds_executed,
            "rounds_serial_equiv": self.rounds_serial_equiv,
            "rounds_per_request": self.rounds_per_request,
            "fused_round_win": self.fused_round_win,
            "ops_executed": self.ops_executed,
            "service_seconds": self.service_seconds,
            "latency_p50_s": self.latency_percentile(50),
            "latency_p99_s": self.latency_percentile(99),
            "latency_mean_s": (float(np.mean(self.latencies))
                               if self.latencies else float("nan")),
        }
