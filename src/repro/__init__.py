"""Reproduction of "Communication Round and Computation Efficient
Exclusive Prefix-Sums Algorithms (for MPI_Exscan)" as a jax/TPU system.

Importing the package applies the jax forward-compat backfills (see
``repro._jax_compat``) so the current-API sources also run on images
that pin an older jax.
"""

from repro import _jax_compat

_jax_compat.apply()
