"""Chunked diagonal-SSM scan — the engine's affine-monoid instance.

Computes the linear recurrence ``h_t = a_t * h_{t-1} + b_t`` over a long
token axis (RWKV6 / Mamba-style diagonal state updates).  This is the
per-device "local chunk scan" half of the context-parallel SSM: the
cross-device half composes per-device (A, B) chunk summaries with the
paper's 123-doubling exscan under the AFFINE monoid (core.collectives).

Since the single-pass chunked scan engine (``kernels.scan_engine``,
DESIGN §7) this module no longer carries its own kernel or its own
private copy of the affine combine: the recurrence is the engine's
chunked scan instantiated with ``core.monoid.affine_combine`` (the ONE
definition, shared with the AFFINE monoid and the model-side XLA
scans).  The VMEM carry holds the affine pair (∏a so far, h_last), so
the chunk summary (A_total, B_total) also comes out of the SAME single
HBM pass — the old second ``prod`` traversal of ``a`` is gone.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.scan_engine import affine_chunk_scan, \
    affine_chunk_summary

__all__ = ["ssm_chunk_scan", "ssm_chunk_summary"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_chunk_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    """Solve h_t = a_t * h_{t-1} + b_t along axis 0.

    Args:
      a, b: (T, D) with T % chunk == 0 and D % 128 == 0 (wrapper pads).
      h0: (1, D) initial state.

    Returns:
      h: (T, D) states after each step; h_final: (1, D).
    """
    T, D = a.shape
    assert a.shape == b.shape and h0.shape == (1, D)
    assert T % chunk == 0, (T, chunk)
    return affine_chunk_scan(a, b, h0, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_chunk_summary(
    a: jax.Array, b: jax.Array, *, chunk: int = 256, interpret: bool = False
):
    """Compute only the chunk summary (A_total, B_total) of a device's
    whole sequence slice: h_out = A_total * h_in + B_total.

    This is the payload of the cross-device exscan (AFFINE monoid).
    One engine pass: the carry's a-leaf chains the per-chunk decay
    products, so A_total needs no second traversal of ``a``.
    """
    assert a.shape == b.shape and a.dtype == b.dtype
    return affine_chunk_summary(a, b, chunk=chunk, interpret=interpret)
