"""Chunked diagonal-SSM scan Pallas TPU kernel.

Computes the linear recurrence ``h_t = a_t * h_{t-1} + b_t`` over a long
token axis (RWKV6 / Mamba-style diagonal state updates).  This is the
per-device "local chunk scan" half of the context-parallel SSM: the
cross-device half composes per-device (A, B) chunk summaries with the
paper's 123-doubling exscan under the AFFINE monoid (core.collectives).

TPU adaptation: sequential grid over time-chunks with the running state
in VMEM scratch; within a chunk the recurrence is solved with a
log-depth associative scan on the (a, b) affine pairs, vectorized over
the state dimension on the VPU.  One HBM pass, no recompute.

Outputs both the full state trajectory and the chunk summary
(A_total, B_total) with ``h_out = A_total * h_in + B_total`` — the value
fed to the collective exscan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _affine(lo, hi):
    a1, b1 = lo
    a2, b2 = hi
    return a2 * a1, a2 * b1 + b2


def _ssm_kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    a = a_ref[...]
    b = b_ref[...]
    # log-depth scan over the chunk: cum_a[t] = prod a_0..t,
    # cum_b[t] = state after absorbing steps 0..t with h_{-1}=0.
    cum_a, cum_b = lax.associative_scan(_affine, (a, b), axis=0)
    h_in = carry_ref[...]
    h = cum_a * h_in + cum_b
    h_ref[...] = h
    carry_ref[...] = h[-1:, :]

    # on the last chunk, expose the final state
    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        hlast_ref[...] = h[-1:, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_chunk_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    """Solve h_t = a_t * h_{t-1} + b_t along axis 0.

    Args:
      a, b: (T, D) with T % chunk == 0 and D % 128 == 0 (wrapper pads).
      h0: (1, D) initial state.

    Returns:
      h: (T, D) states after each step; h_final: (1, D).
    """
    T, D = a.shape
    assert a.shape == b.shape and h0.shape == (1, D)
    assert T % chunk == 0, (T, chunk)
    grid = (T // chunk,)
    h, h_final = pl.pallas_call(
        _ssm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, D), lambda i: (i, 0)),
            pl.BlockSpec((chunk, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, D), b.dtype),
            jax.ShapeDtypeStruct((1, D), b.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, D), b.dtype)],
        interpret=interpret,
    )(a, b, h0)
    return h, h_final


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_chunk_summary(
    a: jax.Array, b: jax.Array, *, chunk: int = 256, interpret: bool = False
):
    """Compute only the chunk summary (A_total, B_total) of a device's
    whole sequence slice: h_out = A_total * h_in + B_total.

    This is the payload of the cross-device exscan (AFFINE monoid).
    Implemented with the same kernel machinery: scan then take last.
    """
    T, D = a.shape
    h0 = jnp.zeros((1, D), b.dtype)
    # A_total = prod(a); B_total = scan with h_in = 0 → h_final.
    _, b_total = ssm_chunk_scan(a, b, h0, chunk=chunk, interpret=interpret)
    # product of decays via scan on (a, 0) pairs would need a second pass;
    # a plain log-depth cumprod of the last row is cheaper:
    a_total = jnp.prod(a, axis=0, keepdims=True)
    return a_total, b_total
