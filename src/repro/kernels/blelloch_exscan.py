"""Block-tiled exclusive prefix-sum Pallas TPU kernel.

The on-chip counterpart of the paper's collective: inside one device,
the "m element" local vectors are scanned along a (possibly long) row
axis.  TPU adaptation (see DESIGN.md §2): instead of the PRAM Blelloch
up/down-sweep tree (a GPU-shared-memory idiom), we exploit the fact that
a Pallas TPU grid executes *sequentially* on a core, so a single VMEM
scratch register carries the running block total — one pass over HBM,
work-efficient (each element touched once), with the intra-block scan
vectorized on the VPU (8x128 lanes) via ``jnp.cumsum``.

Grid: one program per row-block.  BlockSpec tiles (block_rows, width)
into VMEM; width is lane-padded to a multiple of 128 by the ops.py
wrapper, block_rows chosen so the tile fits comfortably in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _exscan_kernel(x_ref, o_ref, carry_ref):
    """One grid step: o = carry + exclusive_cumsum(x); carry += sum(x)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...]
    incl = jnp.cumsum(x, axis=0)
    carry = carry_ref[...]
    o_ref[...] = carry + incl - x  # exclusive within block, shifted by carry
    carry_ref[...] = carry + incl[-1:, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def blelloch_exscan(
    x: jax.Array, *, block_rows: int = 256, interpret: bool = False
) -> jax.Array:
    """Exclusive prefix sum over axis 0 of a 2D array.

    Args:
      x: (n, d) array; n must be a multiple of ``block_rows`` and d a
        multiple of 128 (the ops.py wrapper pads arbitrary shapes).
      block_rows: rows per VMEM tile.
    """
    n, d = x.shape
    assert n % block_rows == 0, (n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        _exscan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), x.dtype)],
        interpret=interpret,
    )(x)
