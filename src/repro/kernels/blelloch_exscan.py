"""Block-tiled exclusive prefix-sum Pallas TPU kernel.

The on-chip counterpart of the paper's collective: inside one device,
the "m element" local vectors are scanned along a (possibly long) row
axis.  TPU adaptation (see DESIGN.md §2): instead of the PRAM Blelloch
up/down-sweep tree (a GPU-shared-memory idiom), we exploit the fact that
a Pallas TPU grid executes *sequentially* on a core, so a single VMEM
scratch register carries the running block total — one pass over HBM,
work-efficient (each element touched once), with the intra-block scan
vectorized on the VPU (8x128 lanes) via ``jnp.cumsum``.

Grid: one program per row-block.  BlockSpec tiles (block_rows, width)
into VMEM; width is lane-padded to a multiple of 128 by the ops.py
wrapper, block_rows chosen so the tile fits comfortably in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _exscan_kernel(x_ref, o_ref, carry_ref):
    """One grid step: o = carry + exclusive_cumsum(x); carry += sum(x)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...]
    incl = jnp.cumsum(x, axis=0)
    carry = carry_ref[...]
    o_ref[...] = carry + incl - x  # exclusive within block, shifted by carry
    carry_ref[...] = carry + incl[-1:, :]


def _combine_kernel(op, a_ref, b_ref, o_ref):
    """One grid step of the block combine: o = a ⊕ b on a VMEM tile."""
    o_ref[...] = op(a_ref[...], b_ref[...])


def _masked_combine_kernel(op, a_ref, b_ref, k_ref, o_ref):
    """Fused masked combine: o = keep ? a ⊕ b : b, one VMEM pass.

    ``k_ref`` is the (1, 1) keep scalar in SMEM (scalars must be 2D
    in scalar memory).  The select runs on the combine output inside
    the tile, so a masked SPMD round (a rank with no source) costs
    the same single pass as an unmasked one — no separate
    fixup/select sweeps over HBM."""
    keep = k_ref[0, 0] != 0
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jnp.where(keep, op(a, b), b)


@functools.partial(jax.jit,
                   static_argnames=("op", "block_rows", "interpret"))
def block_combine(a: jax.Array, b: jax.Array, op, *,
                  keep: jax.Array | None = None,
                  block_rows: int = 256,
                  interpret: bool = False) -> jax.Array:
    """Elementwise ⊕ of two same-shape arrays, tiled through VMEM.

    This is the on-chip lowering of a schedule-IR ``RoundStep`` combine
    (``core.schedule.PallasExecutor``): each communication round's
    recv ⊕ W runs as a Pallas grid over lane-padded row blocks — the
    same sequential-grid pattern as the exscan kernel above, but with a
    caller-supplied elementwise monoid op (``Monoid.leaf_op``) instead
    of cumsum.

    Args:
      a, b: same shape/dtype; ``a`` is the low-rank-side operand.
      op: elementwise jnp function applied to whole VMEM tiles.
      keep: optional scalar predicate (the SPMD receive mask).  When
        given, the kernel computes ``keep ? a ⊕ b : b`` fused in one
        pass — the masked-combine path of a schedule's shift round —
        instead of a combine kernel plus a separate select sweep.
    """
    assert a.shape == b.shape and a.dtype == b.dtype, (a, b)
    shape = a.shape
    n = a.size
    lane = 128
    flat_a = a.reshape(-1)
    flat_b = b.reshape(-1)
    pad = (-n) % lane
    if pad:
        flat_a = jnp.pad(flat_a, (0, pad))
        flat_b = jnp.pad(flat_b, (0, pad))
    wa = flat_a.reshape(-1, lane)
    wb = flat_b.reshape(-1, lane)
    rows = wa.shape[0]
    br = min(block_rows, rows)
    rpad = (-rows) % br
    if rpad:
        wa = jnp.pad(wa, ((0, rpad), (0, 0)))
        wb = jnp.pad(wb, ((0, rpad), (0, 0)))
    grid = (wa.shape[0] // br,)
    tile = pl.BlockSpec((br, lane), lambda i: (i, 0))
    if keep is None:
        out = pl.pallas_call(
            functools.partial(_combine_kernel, op),
            grid=grid,
            in_specs=[tile, tile],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct(wa.shape, a.dtype),
            interpret=interpret,
        )(wa, wb)
    else:
        k = jnp.reshape(jnp.asarray(keep, jnp.int32), (1, 1))
        out = pl.pallas_call(
            functools.partial(_masked_combine_kernel, op),
            grid=grid,
            in_specs=[tile, tile,
                      pl.BlockSpec(memory_space=pltpu.SMEM)],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct(wa.shape, a.dtype),
            interpret=interpret,
        )(wa, wb, k)
    return out.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def blelloch_exscan(
    x: jax.Array, *, block_rows: int = 256, interpret: bool = False
) -> jax.Array:
    """Exclusive prefix sum over axis 0 of a 2D array.

    Args:
      x: (n, d) array; n must be a multiple of ``block_rows`` and d a
        multiple of 128 (the ops.py wrapper pads arbitrary shapes).
      block_rows: rows per VMEM tile.
    """
    n, d = x.shape
    assert n % block_rows == 0, (n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        _exscan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), x.dtype)],
        interpret=interpret,
    )(x)
