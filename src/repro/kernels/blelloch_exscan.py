"""Rank-local exclusive prefix scan — the engine's sum instance.

The on-chip counterpart of the paper's collective: inside one device,
the "m element" local vectors are scanned along a (possibly long) row
axis.  TPU adaptation (see DESIGN.md §2): instead of the PRAM Blelloch
up/down-sweep tree (a GPU-shared-memory idiom), a Pallas TPU grid
executes *sequentially* on a core, so a single VMEM scratch register
carries the running block total — one pass over HBM, work-efficient,
with the intra-block scan vectorized on the VPU.

Since the single-pass chunked scan engine (``kernels.scan_engine``,
DESIGN §7) this module is a thin compatibility surface: the cumsum-only
kernel is gone and :func:`blelloch_exscan` is the engine's add-monoid
instance (``scan_engine.monoid_exscan`` serves any elementwise monoid
with the same one-pass kernel).  :func:`block_combine` — the
``PallasExecutor`` per-round ⊕ hook — also lives in the engine now,
with identity-valued padding; it is re-exported here for existing
importers.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.scan_engine import block_combine, monoid_exscan

__all__ = ["block_combine", "blelloch_exscan"]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def blelloch_exscan(
    x: jax.Array, *, block_rows: int = 256, interpret: bool = False
) -> jax.Array:
    """Exclusive prefix sum over axis 0 of a 2D array.

    Args:
      x: (n, d) array; n must be a multiple of ``block_rows`` and d a
        multiple of 128 (the ops.py wrapper pads arbitrary shapes).
      block_rows: rows per VMEM tile.
    """
    return monoid_exscan(x, "add", block_rows=block_rows,
                         interpret=interpret)
