"""Single-pass chunked scan engine: ONE Pallas kernel family for every
on-chip scan in the repo (DESIGN §7 "Kernel engine").

LightScan-style single-pass chunked scans dominate multi-pass/tree
formulations on accelerators: a sequential grid walks chunk-sized row
blocks while a VMEM carry register holds the running prefix, so the
payload crosses HBM exactly once.  This module generalizes that idiom
over the core :mod:`repro.core.monoid` algebra and backs three callers:

  * the rank-local pre/post phase of every device plan
    (``kernels.blelloch_exscan.blelloch_exscan`` → :func:`monoid_exscan`
    — no longer cumsum-only: any elementwise monoid);
  * the Mamba/RWKV SSM chunk scan (``kernels.ssm_chunk_scan`` →
    :func:`affine_chunk_scan` / :func:`affine_chunk_summary`, the
    affine-monoid instance — its private ``_affine`` duplicate of the
    core monoid is gone);
  * the per-round ⊕ hooks of ``core.schedule.PallasExecutor``
    (:func:`tree_combine`, :func:`tree_exchange`,
    :func:`tree_scan_reduce`): a round's recv ⊕ W combine, its
    receive-mask/side select, and the store of the result run in ONE
    grid pass, and the k payload leaves of a round (fused-layout slots,
    scan_reduce's (P, T) pair) are batched into a single ``pallas_call``
    so k payloads cost one HBM traversal, not k.

Padding uses the *monoid identity* (not literal zeros), so non-zero-
identity monoids (max/min/mul, the affine pair) can never read garbage
from padded lanes — identity ⊕ identity = identity keeps pad lanes
inert even if a caller stops truncating.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import monoid as monoid_lib

LANE = 128  # TPU lane width: last dim of every tile

# ---------------------------------------------------------------------------
# Monoid adapter: which monoids the engine serves, identities for padding
# ---------------------------------------------------------------------------

_OP_NAMES = {
    jnp.add: "add",
    jnp.multiply: "mul",
    jnp.maximum: "max",
    jnp.minimum: "min",
    jnp.bitwise_xor: "xor",
}


def leaf_identity(name: str, dtype):
    """Identity *scalar* of an elementwise monoid at ``dtype`` — the
    pad value for lane/row padding (max/min are dtype-dependent)."""
    dtype = jnp.dtype(dtype)
    if name in ("add", "xor"):
        return 0
    if name == "mul":
        return 1
    is_int = jnp.issubdtype(dtype, jnp.integer)
    if name == "max":
        return int(jnp.iinfo(dtype).min) if is_int else float("-inf")
    if name == "min":
        return int(jnp.iinfo(dtype).max) if is_int else float("inf")
    raise KeyError(f"no identity scalar for monoid {name!r}")


def _op_identity(op, dtype):
    """Pad identity for a raw ``op`` callable (the ``block_combine``
    compatibility surface receives ops, not monoids).  Unknown ops keep
    the legacy zero pad — padded lanes are always truncated from the
    output, so this is a hardening default, not a correctness one."""
    name = _OP_NAMES.get(op)
    return leaf_identity(name, dtype) if name is not None else 0


def supports(m: monoid_lib.Monoid) -> bool:
    """Can the engine serve this monoid on-chip?  Elementwise monoids
    (``leaf_op``) and the affine pair; MATMUL falls back to plain XLA."""
    return m.leaf_op is not None or m.name == "affine"


@functools.lru_cache(maxsize=None)
def _tuple_combine(op):
    """Lift an elementwise ``op`` to the engine's tuple-of-leaves
    combine signature (cached so jit sees one stable callable per op)."""

    def combine(lo, hi):
        return tuple(op(a, b) for a, b in zip(lo, hi))

    return combine


# The affine instance uses the ONE core definition — no private copy.
_affine_combine = monoid_lib.affine_combine


# ---------------------------------------------------------------------------
# The chunked scan kernel: sequential grid + VMEM carry, any monoid
# ---------------------------------------------------------------------------


def _scan_body(combine, n_in, exclusive, traj, fin, *refs):
    """One grid step of the single-pass chunked scan.

    ``refs``: n_in chunk inputs, n_in (1, D) init rows, len(traj)
    trajectory outputs, len(fin) final rows, n_in VMEM carry scratch.
    The carry holds the inclusive prefix of every prior chunk; one
    ``associative_scan`` + one carry combine serve the whole chunk.
    """
    x_refs = refs[:n_in]
    init_refs = refs[n_in:2 * n_in]
    k = 2 * n_in
    out_refs = refs[k:k + len(traj)]
    fin_refs = refs[k + len(traj):k + len(traj) + len(fin)]
    carry_refs = refs[k + len(traj) + len(fin):]

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _seed():
        for c, ini in zip(carry_refs, init_refs):
            c[...] = ini[...]

    xs = tuple(r[...] for r in x_refs)
    incl = lax.associative_scan(combine, xs, axis=0)
    cvals = tuple(c[...] for c in carry_refs)
    full = combine(cvals, incl)  # (1, D) carry broadcasts over chunk
    if exclusive:
        outs = tuple(jnp.concatenate([c, f[:-1]], axis=0)
                     for c, f in zip(cvals, full))
    else:
        outs = full
    for o_ref, j in zip(out_refs, traj):
        o_ref[...] = outs[j]
    last = tuple(f[-1:, :] for f in full)
    for c, l in zip(carry_refs, last):
        c[...] = l

    @pl.when(i == pl.num_programs(0) - 1)
    def _finish():
        for f_ref, j in zip(fin_refs, fin):
            f_ref[...] = last[j]


def chunked_scan(xs, init, combine, *, exclusive=False, traj=(0,),
                 final=(), chunk=256, interpret=False):
    """Single-pass chunked scan over axis 0 of (T, D) leaf tuples.

    ``combine`` takes/returns tuples of leaves; ``init`` seeds the VMEM
    carry ((1, D) rows — the exclusive prefix of row 0).  ``traj``
    selects which leaves' trajectories are written, ``final`` which
    leaves' inclusive totals come back as (1, D) rows.  Returns
    ``(trajectory_leaves, final_leaves)``.
    """
    xs = tuple(xs)
    init = tuple(init)
    n_in = len(xs)
    T, D = xs[0].shape
    if T % chunk:
        raise ValueError(f"rows {T} not a multiple of chunk {chunk}")
    traj = tuple(traj)
    final = tuple(final)
    x_spec = pl.BlockSpec((chunk, D), lambda i: (i, 0))
    row_spec = pl.BlockSpec((1, D), lambda i: (0, 0))
    out_shape = ([jax.ShapeDtypeStruct((T, D), xs[j].dtype)
                  for j in traj]
                 + [jax.ShapeDtypeStruct((1, D), xs[j].dtype)
                    for j in final])
    kernel = functools.partial(_scan_body, combine, n_in, exclusive,
                               traj, final)
    outs = pl.pallas_call(
        kernel,
        grid=(T // chunk,),
        in_specs=[x_spec] * n_in + [row_spec] * n_in,
        out_specs=[x_spec] * len(traj) + [row_spec] * len(final),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, D), x.dtype) for x in xs],
        interpret=interpret,
    )(*xs, *init)
    return tuple(outs[:len(traj)]), tuple(outs[len(traj):])


@functools.partial(jax.jit,
                   static_argnames=("monoid", "block_rows", "interpret"))
def monoid_exscan(x, monoid: str = "add", *, block_rows: int = 256,
                  interpret: bool = False):
    """Exclusive scan of (n, d) rows under any elementwise monoid —
    the rank-local phase of every device plan.  Row 0 gets the monoid
    identity; row t the ⊕ of rows [0, t)."""
    m = monoid_lib.get(monoid)
    if m.leaf_op is None:
        raise ValueError(f"monoid {monoid!r} is not elementwise")
    n, d = x.shape
    if n % block_rows:
        raise ValueError(f"rows {n} not a multiple of {block_rows}")
    init = jnp.full((1, d), leaf_identity(m.name, x.dtype), x.dtype)
    (out,), _ = chunked_scan(
        (x,), (init,), _tuple_combine(m.leaf_op), exclusive=True,
        traj=(0,), final=(), chunk=block_rows, interpret=interpret)
    return out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def affine_chunk_scan(a, b, h0, *, chunk: int = 256,
                      interpret: bool = False):
    """h_t = a_t * h_{t-1} + b_t — the affine-monoid engine instance.

    The carry pair is the affine element ((∏a so far), h_last); each
    chunk's trajectory is the b-leaf of carry ∘ chunk-scan, i.e.
    ``cum_a * h_in + cum_b`` exactly as the dedicated SSM kernel
    computed it.  Returns (h (T, D), h_final (1, D))."""
    init = (jnp.ones_like(h0), h0)
    (h,), (h_final,) = chunked_scan(
        (a, b), init, _affine_combine, exclusive=False, traj=(1,),
        final=(1,), chunk=chunk, interpret=interpret)
    return h, h_final


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def affine_chunk_summary(a, b, *, chunk: int = 256,
                         interpret: bool = False):
    """Whole-sequence affine summary (a_total, b_total) in ONE pass —
    the carry's a-leaf chains the chunk products, so no second
    ``prod`` traversal of ``a`` is needed."""
    D = a.shape[1]
    init = (jnp.ones((1, D), a.dtype), jnp.zeros((1, D), a.dtype))
    _, (a_tot, b_tot) = chunked_scan(
        (a, b), init, _affine_combine, exclusive=False, traj=(),
        final=(0, 1), chunk=chunk, interpret=interpret)
    return a_tot, b_tot


# ---------------------------------------------------------------------------
# Fused round-combine kernels (the PallasExecutor ⊕ hooks)
# ---------------------------------------------------------------------------


def _combine_kernel(op, a_ref, b_ref, o_ref):
    o_ref[...] = op(a_ref[...], b_ref[...])


def _masked_combine_kernel(op, a_ref, b_ref, k_ref, o_ref):
    keep = k_ref[0, 0] != 0
    o_ref[...] = jnp.where(keep, op(a_ref[...], b_ref[...]), b_ref[...])


def _exchange_kernel(op, r_ref, w_ref, s_ref, o_ref):
    # butterfly round: the side bit picks the combine order; the two
    # orders, the select and the store are ONE grid pass (the XLA
    # baseline is two combine launches plus a select sweep)
    low = s_ref[0, 0] != 0
    r, w = r_ref[...], w_ref[...]
    o_ref[...] = jnp.where(low, op(r, w), op(w, r))


def _scan_reduce_kernel(op, commutative, r_ref, w_ref, p_ref, s_ref,
                        w_out, p_out):
    # fused exscan+allreduce round: both registers (window total T and
    # exclusive prefix P) update in one traversal of the three inputs
    low = s_ref[0, 0] != 0
    r, w, p = r_ref[...], w_ref[...], p_ref[...]
    if commutative:
        w_out[...] = op(r, w)
    else:
        w_out[...] = jnp.where(low, op(r, w), op(w, r))
    p_out[...] = jnp.where(low, op(r, p), p)


def _affine_combine_kernel(al, bl, ah, bh, oa, ob):
    ca, cb = _affine_combine((al[...], bl[...]), (ah[...], bh[...]))
    oa[...] = ca
    ob[...] = cb


def _affine_masked_kernel(al, bl, ah, bh, k_ref, oa, ob):
    keep = k_ref[0, 0] != 0
    a_hi, b_hi = ah[...], bh[...]
    ca, cb = _affine_combine((al[...], bl[...]), (a_hi, b_hi))
    oa[...] = jnp.where(keep, ca, a_hi)
    ob[...] = jnp.where(keep, cb, b_hi)


def _affine_exchange_kernel(ar, br, aw, bw, s_ref, oa, ob):
    low = s_ref[0, 0] != 0
    recv = (ar[...], br[...])
    w = (aw[...], bw[...])
    la, lb = _affine_combine(recv, w)
    ha, hb = _affine_combine(w, recv)
    oa[...] = jnp.where(low, la, ha)
    ob[...] = jnp.where(low, lb, hb)


def _affine_scan_reduce_kernel(ar, br, aw, bw, ap, bp, s_ref,
                               oaw, obw, oap, obp):
    low = s_ref[0, 0] != 0
    recv = (ar[...], br[...])
    w = (aw[...], bw[...])
    p = (ap[...], bp[...])
    la, lb = _affine_combine(recv, w)
    ha, hb = _affine_combine(w, recv)
    oaw[...] = jnp.where(low, la, ha)
    obw[...] = jnp.where(low, lb, hb)
    pa, pb = _affine_combine(recv, p)
    oap[...] = jnp.where(low, pa, p[0])
    obp[...] = jnp.where(low, pb, p[1])


def _pad_tile(flat, pad_value, block_rows):
    """(n,) flat → identity-padded (rows, LANE) tile + block height."""
    n = flat.size
    lane_pad = (-n) % LANE
    if lane_pad:
        flat = jnp.pad(flat, (0, lane_pad), constant_values=pad_value)
    tiled = flat.reshape(-1, LANE)
    rows = tiled.shape[0]
    br = min(block_rows, rows)
    row_pad = (-rows) % br
    if row_pad:
        tiled = jnp.pad(tiled, ((0, row_pad), (0, 0)),
                        constant_values=pad_value)
    return tiled, br


def _round_call(kernel, ins, pad_values, n_out, *, scalar=None,
                block_rows=256, interpret=False):
    """Launch ONE round kernel over same-size flat operands.

    ``ins`` are 1-D same-dtype buffers (a whole dtype group of payload
    leaves, pre-concatenated); each is identity-padded to the (rows,
    LANE) tiling.  ``scalar`` (receive mask / butterfly side bit) rides
    in SMEM.  Returns ``n_out`` flat buffers truncated to input size.
    """
    n = ins[0].size
    tiles = []
    br = 1
    for v, pv in zip(ins, pad_values):
        t, br = _pad_tile(v, pv, block_rows)
        tiles.append(t)
    rows = tiles[0].shape[0]
    tile_spec = pl.BlockSpec((br, LANE), lambda i: (i, 0))
    in_specs = [tile_spec] * len(tiles)
    operands = list(tiles)
    if scalar is not None:
        operands.append(jnp.reshape(jnp.asarray(scalar, jnp.int32),
                                    (1, 1)))
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
    outs = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=in_specs,
        out_specs=[tile_spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct(tiles[0].shape, tiles[0].dtype)
                   for _ in range(n_out)],
        interpret=interpret,
    )(*operands)
    return [o.reshape(-1)[:n] for o in outs]


@functools.partial(
    jax.jit,
    static_argnames=("op", "block_rows", "interpret", "pad_value"))
def block_combine(a, b, op, *, keep=None, block_rows: int = 256,
                  interpret: bool = False, pad_value=None):
    """a ⊕ b over arbitrary-shape arrays through (block_rows, LANE)
    VMEM tiles — one launch, one HBM pass.  With ``keep`` (a traced
    bool) the receive-mask select fuses into the same pass:
    where(keep, a ⊕ b, b).  Padding uses the monoid identity of ``op``
    (override with ``pad_value``), so max/min never see pad garbage."""
    shape = a.shape
    pv = pad_value if pad_value is not None else _op_identity(op, a.dtype)
    ins = [a.reshape(-1), b.reshape(-1)]
    if keep is None:
        out, = _round_call(functools.partial(_combine_kernel, op), ins,
                           (pv, pv), 1, block_rows=block_rows,
                           interpret=interpret)
    else:
        out, = _round_call(functools.partial(_masked_combine_kernel, op),
                           ins, (pv, pv), 1, scalar=keep,
                           block_rows=block_rows, interpret=interpret)
    return out.reshape(shape)


# --- tree-level entry points: k payload leaves, one pallas_call ----------


def _flat_pair(tree):
    """The affine payload shape the kernels serve: a flat (a, b) pair
    of same-shape/dtype arrays.  Returns (a, b) or None."""
    if isinstance(tree, (tuple, list)) and len(tree) == 2:
        a, b = tree
        if (hasattr(a, "shape") and hasattr(b, "shape")
                and a.shape == b.shape
                and getattr(a, "dtype", None) == getattr(b, "dtype",
                                                         None)):
            return a, b
    return None


def _dtype_groups(leaves):
    groups: dict = {}
    for i, leaf in enumerate(leaves):
        groups.setdefault(jnp.dtype(leaf.dtype), []).append(i)
    return groups


def _batched_elementwise(kernel_fn, m, trees, n_out, *, scalar,
                         block_rows, interpret):
    """Run one elementwise round kernel over every leaf of ``trees``
    (same structure each), batched so all leaves of one dtype share a
    single ``pallas_call`` — k fused-layout slots cost one HBM
    traversal, not k."""
    leaves0, treedef = jax.tree.flatten(trees[0])
    flat_trees = [leaves0] + [treedef.flatten_up_to(t)
                              for t in trees[1:]]
    n_leaves = len(leaves0)
    out_leaves = [[None] * n_leaves for _ in range(n_out)]
    for dtype, idxs in _dtype_groups(leaves0).items():
        pv = leaf_identity(m.name, dtype)
        sizes = [leaves0[i].size for i in idxs]
        ins = [jnp.concatenate([ft[i].reshape(-1) for i in idxs])
               if len(idxs) > 1 else ft[idxs[0]].reshape(-1)
               for ft in flat_trees]
        outs = _round_call(kernel_fn, ins, (pv,) * len(ins), n_out,
                           scalar=scalar, block_rows=block_rows,
                           interpret=interpret)
        for k, flat in enumerate(outs):
            off = 0
            for i, sz in zip(idxs, sizes):
                out_leaves[k][i] = flat[off:off + sz].reshape(
                    leaves0[i].shape)
                off += sz
    return tuple(jax.tree.unflatten(treedef, ol) for ol in out_leaves)


def _pair_ins(*pairs):
    return [x.reshape(-1) for pair in pairs for x in pair]


def _pair_pads(n_pairs):
    return (1, 0) * n_pairs  # affine identity: a-leaves 1, b-leaves 0


def _pair_out(tree_like, flats):
    a, b = _flat_pair(tree_like)
    out = (flats[0].reshape(a.shape), flats[1].reshape(b.shape))
    return type(tree_like)(out) if isinstance(tree_like, list) else out


def tree_combine(m, lo, hi, *, keep=None, block_rows=256,
                 interpret=False):
    """Engine ⊕ over payload trees: where(keep, lo ⊕ hi, hi) (plain ⊕
    when ``keep`` is None) in one batched pass.  Returns None when the
    monoid/payload shape is not engine-served (caller falls back)."""
    if m.leaf_op is not None:
        op = m.leaf_op
        if keep is None:
            kern = functools.partial(_combine_kernel, op)
        else:
            kern = functools.partial(_masked_combine_kernel, op)
        out, = _batched_elementwise(kern, m, (lo, hi), 1, scalar=keep,
                                    block_rows=block_rows,
                                    interpret=interpret)
        return out
    if m.name == "affine":
        plo, phi = _flat_pair(lo), _flat_pair(hi)
        if plo is None or phi is None:
            return None
        kern = (_affine_combine_kernel if keep is None
                else _affine_masked_kernel)
        flats = _round_call(kern, _pair_ins(plo, phi), _pair_pads(2), 2,
                            scalar=keep, block_rows=block_rows,
                            interpret=interpret)
        return _pair_out(hi, flats)
    return None


def tree_exchange(m, recv, w, low_side, *, block_rows=256,
                  interpret=False):
    """Non-commutative butterfly round: both combine orders, the side
    select and the store in ONE pass (XLA baseline: 2 launches + a
    select sweep).  Returns the new W, or None if not engine-served."""
    if m.leaf_op is not None:
        kern = functools.partial(_exchange_kernel, m.leaf_op)
        out, = _batched_elementwise(kern, m, (recv, w), 1,
                                    scalar=low_side,
                                    block_rows=block_rows,
                                    interpret=interpret)
        return out
    if m.name == "affine":
        pr, pw = _flat_pair(recv), _flat_pair(w)
        if pr is None or pw is None:
            return None
        flats = _round_call(_affine_exchange_kernel, _pair_ins(pr, pw),
                            _pair_pads(2), 2, scalar=low_side,
                            block_rows=block_rows, interpret=interpret)
        return _pair_out(w, flats)
    return None


def tree_scan_reduce(m, recv, w, prefix, low_side, *, block_rows=256,
                     interpret=False):
    """Fused exscan+allreduce round: the (P, T) register pair updates
    in ONE batched pass (XLA baseline: 2 launches commutative, 3
    launches + 2 select sweeps otherwise).  Returns (w, prefix) or
    None if not engine-served."""
    if m.leaf_op is not None:
        kern = functools.partial(_scan_reduce_kernel, m.leaf_op,
                                 m.commutative)
        w2, p2 = _batched_elementwise(kern, m, (recv, w, prefix), 2,
                                      scalar=low_side,
                                      block_rows=block_rows,
                                      interpret=interpret)
        return w2, p2
    if m.name == "affine":
        pr, pw, pp = (_flat_pair(recv), _flat_pair(w),
                      _flat_pair(prefix))
        if pr is None or pw is None or pp is None:
            return None
        flats = _round_call(_affine_scan_reduce_kernel,
                            _pair_ins(pr, pw, pp), _pair_pads(3), 4,
                            scalar=low_side, block_rows=block_rows,
                            interpret=interpret)
        return _pair_out(w, flats[:2]), _pair_out(prefix, flats[2:])
    return None
