"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel module pairs with a pure-jnp oracle in ref.py; ops.py holds
the public, shape-flexible jit'd wrappers (interpret=True off-TPU).
"""

from repro.kernels import ops, ref
