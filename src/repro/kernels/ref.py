"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested
against (``assert_allclose`` over shape/dtype sweeps, interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def exscan_ref(x: jax.Array, axis: int = 0) -> jax.Array:
    """Exclusive prefix sum along ``axis`` (row 0 gets zeros)."""
    incl = jnp.cumsum(x, axis=axis)
    excl = incl - x
    return excl


def ssm_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array | None = None):
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (T, D).  h0: (D,) initial state (zeros if None).
    Returns (h, h_final) where h[t] is the state AFTER absorbing step t.
    """
    T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((D,), b.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_final, hs = lax.scan(step, h0.astype(b.dtype), (a, b))
    return hs, h_final


def moe_routing_ref(assignment: jax.Array, num_experts: int):
    """Per-(token, slot) position within its expert + per-expert counts.

    assignment: (T, K) int32 expert ids in [0, num_experts).
    Position ordering is row-major over (token, slot): slot j of token t
    precedes slot j' of token t' iff t*K + j < t'*K + j'.

    Returns:
      positions: (T, K) int32 — index of this entry within its expert's
        buffer (exclusive count of earlier same-expert entries).
      counts: (num_experts,) int32 — total entries per expert.
    """
    T, K = assignment.shape
    flat = assignment.reshape(-1)  # (T*K,) in arrival order
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # (TK, E)
    incl = jnp.cumsum(onehot, axis=0)
    excl = incl - onehot
    positions = jnp.take_along_axis(excl, flat[:, None], axis=1)[:, 0]
    counts = onehot.sum(axis=0)
    return positions.reshape(T, K), counts
