"""Fused MoE routing-offset Pallas TPU kernel.

Given per-(token, slot) expert assignments, computes each entry's write
position inside its expert's buffer (the exclusive count of earlier
same-expert entries) plus per-expert totals — the quantities whose
*cross-device* prefix is then taken with the paper's 123-doubling exscan
to build all-to-all dispatch offsets (models/moe.py).

TPU adaptation: a histogram-scan.  Sequential grid over token blocks,
running per-expert counters in VMEM scratch; within a block the one-hot
expansion (block_tokens*K, E) is scanned with a vectorized cumsum on the
VPU.  One pass, no atomics (the GPU idiom) needed — grid order gives
determinism for free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _routing_kernel(assign_ref, pos_ref, counts_ref, carry_ref, *, num_experts):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    assign = assign_ref[...]  # (bt, K) int32
    bt, k = assign.shape
    flat = assign.reshape(bt * k)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bt * k, num_experts), 1)
    onehot = (flat[:, None] == iota).astype(jnp.int32)  # (bt*K, E)
    incl = jnp.cumsum(onehot, axis=0)
    excl = incl - onehot
    carry = carry_ref[...]  # (1, E)
    pos_flat = jnp.sum((excl + carry) * onehot, axis=1)  # gather own column
    pos_ref[...] = pos_flat.reshape(bt, k)
    new_counts = carry + incl[-1:, :]
    carry_ref[...] = new_counts

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        counts_ref[...] = new_counts


@functools.partial(
    jax.jit, static_argnames=("num_experts", "block_tokens", "interpret")
)
def moe_routing(
    assignment: jax.Array,
    *,
    num_experts: int,
    block_tokens: int = 256,
    interpret: bool = False,
):
    """Positions within expert buffers + per-expert counts.

    Args:
      assignment: (T, K) int32 expert ids, T % block_tokens == 0.

    Returns:
      positions: (T, K) int32; counts: (1, num_experts) int32.
    """
    T, K = assignment.shape
    assert T % block_tokens == 0, (T, block_tokens)
    grid = (T // block_tokens,)
    kernel = functools.partial(_routing_kernel, num_experts=num_experts)
    positions, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_tokens, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_tokens, K), lambda i: (i, 0)),
            pl.BlockSpec((1, num_experts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, K), jnp.int32),
            jax.ShapeDtypeStruct((1, num_experts), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, num_experts), jnp.int32)],
        interpret=interpret,
    )(assignment)
    return positions, counts
