"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary shapes/dtypes by lane-padding to TPU-friendly tiles,
choose block sizes from a VMEM budget, and fall back to the pure-jnp
reference on CPU (`interpret=True` is used automatically when no TPU is
present so the kernels still execute — and are tested — everywhere).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blelloch_exscan as _bl
from repro.kernels import moe_routing as _moe
from repro.kernels import ssm_chunk_scan as _ssm

LANE = 128
_VMEM_BUDGET = 4 * 1024 * 1024  # conservative half-ish of 16 MiB VMEM


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block_rows(d: int, itemsize: int, max_rows: int) -> int:
    """Largest power-of-two row count whose (rows, d) tile fits VMEM."""
    rows = max_rows
    while rows > 8 and rows * d * itemsize * 3 > _VMEM_BUDGET:
        rows //= 2
    return max(rows, 8)


def exscan(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Exclusive prefix sum along axis 0 of an (n, d) or (n,) array."""
    if interpret is None:
        interpret = not _on_tpu()
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n, d = x.shape
    xp = _pad_to(_pad_to(x, LANE, 1), 8, 0)
    np_, dp = xp.shape
    rows = _pick_block_rows(dp, xp.dtype.itemsize, min(np_, 256))
    xp = _pad_to(xp, rows, 0)
    out = _bl.blelloch_exscan(xp, block_rows=rows, interpret=interpret)
    out = out[:n, :d]
    return out[:, 0] if squeeze else out


def ssm_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array | None = None,
    *,
    interpret: bool | None = None,
):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t, axis 0.

    a, b: (T, D); h0: (D,) or None.  Returns (h: (T, D), h_final: (D,)).
    Padding note: decay `a` must pad with ONES (identity), b with zeros.
    """
    if interpret is None:
        interpret = not _on_tpu()
    T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((D,), b.dtype)
    padD = (-D) % LANE
    padT = (-T) % 8
    ap = jnp.pad(a, ((0, padT), (0, padD)), constant_values=1.0)
    bp = jnp.pad(b, ((0, padT), (0, padD)))
    h0p = jnp.pad(h0[None, :], ((0, 0), (0, padD)))
    Tp, Dp = ap.shape
    chunk = _pick_block_rows(Dp, bp.dtype.itemsize, min(Tp, 256))
    padT2 = (-Tp) % chunk
    if padT2:
        ap = jnp.pad(ap, ((0, padT2), (0, 0)), constant_values=1.0)
        bp = jnp.pad(bp, ((0, padT2), (0, 0)))
    h, _ = _ssm.ssm_chunk_scan(ap, bp, h0p, chunk=chunk, interpret=interpret)
    h = h[:T, :D]
    return h, h[-1]


def ssm_chunk_summary(
    a: jax.Array, b: jax.Array, *, interpret: bool | None = None
):
    """Chunk summary (A_total, B_total) of a sequence slice: the AFFINE
    monoid element composed across devices by core.collectives.exscan."""
    if interpret is None:
        interpret = not _on_tpu()
    T, D = a.shape
    padD = (-D) % LANE
    ap = jnp.pad(a, ((0, 0), (0, padD)), constant_values=1.0)
    bp = jnp.pad(b, ((0, 0), (0, padD)))
    Tp = ap.shape[0]
    chunk = _pick_block_rows(ap.shape[1], bp.dtype.itemsize, min(Tp, 256))
    padT = (-Tp) % chunk
    if padT:
        ap = jnp.pad(ap, ((0, padT), (0, 0)), constant_values=1.0)
        bp = jnp.pad(bp, ((0, padT), (0, 0)))
    a_tot, b_tot = _ssm.ssm_chunk_summary(ap, bp, chunk=chunk, interpret=interpret)
    return a_tot[0, :D], b_tot[0, :D]


def moe_routing(
    assignment: jax.Array,
    num_experts: int,
    *,
    interpret: bool | None = None,
):
    """Write positions within expert buffers + per-expert counts.

    assignment: (T, K) int32.  Returns (positions (T,K) i32, counts (E,) i32).
    """
    if interpret is None:
        interpret = not _on_tpu()
    T, K = assignment.shape
    padE = (-num_experts) % LANE
    E = num_experts + padE
    if E == num_experts:
        E += LANE  # guarantee a sentinel column for token padding
    block = min(T, max(8, _VMEM_BUDGET // (8 * E * 4)))
    # round block down to a divisor-friendly power of two
    b = 8
    while b * 2 <= block:
        b *= 2
    block = b
    padT = (-T) % block
    ap = jnp.pad(assignment, ((0, padT), (0, 0)), constant_values=E - 1)
    pos, counts = _moe.moe_routing(
        ap, num_experts=E, block_tokens=block, interpret=interpret
    )
    return pos[:T], counts[0, :num_experts]
