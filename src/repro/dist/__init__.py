"""Multi-process distributed execution of scan schedules (DESIGN §11).

The rest of the repo plans, composes and verifies schedules inside one
process; this package makes a :class:`~repro.core.schedule.Schedule`
run across **real OS process boundaries**:

  * :mod:`repro.dist.transport` — rank-addressed message transports:
    an in-process :class:`LocalTransport` (threads; unit tests) and a
    :class:`SocketTransport` whose workers rendezvous through a
    coordinator address — ``jax.distributed.initialize``-style — and
    then exchange schedule payloads over direct loopback TCP peer
    connections, so the harness never needs real NICs.
  * :mod:`repro.dist.worker` — the per-rank message-passing executor
    (:class:`RankExecutor`): one schedule rank's side of the IR —
    sends/receives honouring each round's peer structure — plus the
    worker process main loop.
  * :mod:`repro.dist.launcher` — :class:`WorkerPool` spawns N worker
    subprocesses, scatters payloads, gathers stacked results, and the
    ``python -m repro.dist.launcher --nprocs 2 --smoke`` CLI.

The correctness contract is *bit-identity*: executing a schedule
through N processes must equal the single-process
:class:`~repro.core.schedule.SimulatorExecutor` on the same schedule,
bit for bit (both follow the IR with the same numpy ops in the same
order).  ``benchmarks/dist_bench.py --check`` gates it in CI.
"""

from repro.dist.launcher import WorkerPool, run_plan
from repro.dist.transport import (
    LocalTransport, SocketTransport, Transport, TransportError)
from repro.dist.worker import RankExecutor, run_ranks_threaded

__all__ = [
    "LocalTransport",
    "RankExecutor",
    "SocketTransport",
    "Transport",
    "TransportError",
    "WorkerPool",
    "run_plan",
    "run_ranks_threaded",
]
