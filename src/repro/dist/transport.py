"""Rank-addressed message transports for distributed schedule execution.

A :class:`Transport` carries one schedule rank's sends/receives:
``send(src, dst, payload)`` / ``recv(dst, src)`` address messages by
*global schedule rank*, and per-(src, dst) FIFO order is guaranteed —
exactly the ordering the round-structured IR needs (a rank never has
two in-flight messages to the same peer within a round, and rounds are
separated by the data dependency of using what was received).

Two implementations:

  * :class:`LocalTransport` — all ranks in one process (threads); the
    unit-test substrate for :class:`~repro.dist.worker.RankExecutor`.
  * :class:`SocketTransport` — each process owns a contiguous block of
    ranks; intra-process messages short-circuit through the mailbox
    while cross-process messages travel as length-prefixed pickle
    frames over loopback TCP peer connections.  One daemon reader
    thread per peer drains every incoming frame into the mailbox
    unconditionally, so a blocking ``sendall`` on a cyclic send
    pattern can never deadlock.

Rendezvous is ``jax.distributed.initialize``-style: every worker
connects to one coordinator address, reports its own listen port, and
receives the full peer address map plus the run configuration; workers
then build the all-pairs peer connections deterministically (connect
to lower process indices, accept from higher ones).  Everything rides
127.0.0.1, so the harness needs no real NICs.
"""

from __future__ import annotations

import pickle
import queue
import socket
import struct
import threading
import time


class TransportError(RuntimeError):
    """A transport-level failure (timeout, closed peer, bad frame)."""


_LEN = struct.Struct(">Q")


def send_msg(sock: socket.socket, obj) -> int:
    """Write one length-prefixed pickle frame; returns frame bytes."""
    blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(blob)) + blob)
    return _LEN.size + len(blob)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportError("peer closed the connection")
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket):
    """Read one length-prefixed pickle frame."""
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    return pickle.loads(_recv_exact(sock, n))


class _Mailbox:
    """Thread-safe per-(src, dst) FIFO queues, created lazily."""

    def __init__(self):
        self._lock = threading.Lock()
        self._queues: dict[tuple[int, int], queue.Queue] = {}

    def _q(self, src: int, dst: int) -> queue.Queue:
        key = (src, dst)
        with self._lock:
            q = self._queues.get(key)
            if q is None:
                q = self._queues[key] = queue.Queue()
            return q

    def put(self, src: int, dst: int, payload):
        self._q(src, dst).put(payload)

    def get(self, src: int, dst: int, timeout: float | None):
        try:
            return self._q(src, dst).get(timeout=timeout)
        except queue.Empty:
            raise TransportError(
                f"recv timed out waiting for rank {src} -> rank {dst} "
                f"(timeout={timeout}s)") from None


class Transport:
    """Base: rank-addressed messaging with byte/message accounting.

    ``stats()`` reports message and byte counters split into local
    (same-process, mailbox short-circuit) and cross-process traffic —
    ``dist_bench`` asserts the cross counters are nonzero to prove
    messages really left the process.
    """

    p: int

    def __init__(self, p: int, *, timeout: float = 120.0):
        self.p = int(p)
        self.timeout = timeout
        self._stat_lock = threading.Lock()
        self._local_msgs = 0
        self._local_bytes = 0
        self._cross_msgs = 0
        self._cross_bytes = 0

    def _count(self, nbytes: int, *, cross: bool):
        with self._stat_lock:
            if cross:
                self._cross_msgs += 1
                self._cross_bytes += nbytes
            else:
                self._local_msgs += 1
                self._local_bytes += nbytes

    def stats(self) -> dict:
        with self._stat_lock:
            return {
                "local_msgs": self._local_msgs,
                "local_bytes": self._local_bytes,
                "cross_msgs": self._cross_msgs,
                "cross_bytes": self._cross_bytes,
            }

    def send(self, src: int, dst: int, payload):
        raise NotImplementedError

    def recv(self, dst: int, src: int, timeout: float | None = None):
        raise NotImplementedError

    def close(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _payload_nbytes(payload) -> int:
    import jax
    import numpy as np

    return sum(np.asarray(leaf).nbytes
               for leaf in jax.tree.leaves(payload))


class LocalTransport(Transport):
    """All p ranks inside one process: pure mailbox, for thread-driven
    unit tests of the per-rank executor."""

    def __init__(self, p: int, *, timeout: float = 120.0):
        super().__init__(p, timeout=timeout)
        self._mail = _Mailbox()

    def send(self, src: int, dst: int, payload):
        self._count(_payload_nbytes(payload), cross=False)
        self._mail.put(src, dst, payload)

    def recv(self, dst: int, src: int, timeout: float | None = None):
        return self._mail.get(src, dst, timeout or self.timeout)


class SocketTransport(Transport):
    """One process's endpoint of the multi-process transport.

    Process k owns the contiguous global-rank block
    ``[k·ranks_per_proc, (k+1)·ranks_per_proc)``.  Sends to co-resident
    ranks short-circuit through the mailbox; sends to remote ranks
    frame ``(src, dst, payload)`` over the peer's TCP connection.  A
    daemon reader thread per peer demuxes every incoming frame into
    the mailbox, so receives simply block on the FIFO queue.
    """

    def __init__(self, proc: int, nprocs: int, ranks_per_proc: int,
                 peers: dict[int, socket.socket], *,
                 timeout: float = 120.0):
        super().__init__(nprocs * ranks_per_proc, timeout=timeout)
        self.proc = int(proc)
        self.nprocs = int(nprocs)
        self.ranks_per_proc = int(ranks_per_proc)
        self._mail = _Mailbox()
        self._peers = dict(peers)
        self._send_locks = {j: threading.Lock() for j in self._peers}
        self._closed = False
        self._readers = []
        for j, sock in self._peers.items():
            t = threading.Thread(target=self._reader, args=(j, sock),
                                 name=f"transport-reader-{j}",
                                 daemon=True)
            t.start()
            self._readers.append(t)

    def owner(self, rank: int) -> int:
        return rank // self.ranks_per_proc

    def local_ranks(self) -> list[int]:
        base = self.proc * self.ranks_per_proc
        return list(range(base, base + self.ranks_per_proc))

    def _reader(self, peer: int, sock: socket.socket):
        try:
            while True:
                src, dst, payload = recv_msg(sock)
                self._mail.put(src, dst, payload)
        except (TransportError, OSError):
            return  # peer closed / transport shut down

    def send(self, src: int, dst: int, payload):
        target = self.owner(dst)
        if target == self.proc:
            self._count(_payload_nbytes(payload), cross=False)
            self._mail.put(src, dst, payload)
            return
        sock = self._peers.get(target)
        if sock is None:
            raise TransportError(
                f"no peer connection to process {target} "
                f"(rank {dst})")
        with self._send_locks[target]:
            n = send_msg(sock, (src, dst, payload))
        self._count(n, cross=True)

    def recv(self, dst: int, src: int, timeout: float | None = None):
        if self.owner(dst) != self.proc:
            raise TransportError(
                f"process {self.proc} cannot recv for rank {dst} "
                f"(owned by process {self.owner(dst)})")
        return self._mail.get(src, dst, timeout or self.timeout)

    def close(self):
        if self._closed:
            return
        self._closed = True
        for sock in self._peers.values():
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            sock.close()


# ---------------------------------------------------------------------------
# Rendezvous (jax.distributed.initialize-style: one coordinator address)
# ---------------------------------------------------------------------------


def _connect_retry(addr: tuple[str, int],
                   deadline: float) -> socket.socket:
    last = None
    while time.monotonic() < deadline:
        try:
            return socket.create_connection(addr, timeout=5.0)
        except OSError as e:
            last = e
            time.sleep(0.05)
    raise TransportError(f"could not connect to {addr}: {last}")


def rendezvous_worker(coord_addr: tuple[str, int], proc: int,
                      nprocs: int, *, timeout: float = 60.0
                      ) -> tuple[socket.socket,
                                 dict[int, socket.socket], dict]:
    """One worker's side of the rendezvous.

    Connects to the coordinator, announces its own loopback listen
    port, receives the full peer port map plus the run config, then
    builds the all-pairs peer mesh: connect to every lower process
    index (identifying itself), accept from every higher one.
    Returns ``(coordinator_socket, peers, config)``.
    """
    deadline = time.monotonic() + timeout
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.bind(("127.0.0.1", 0))
    listener.listen(max(1, nprocs))
    my_port = listener.getsockname()[1]

    coord = _connect_retry(coord_addr, deadline)
    coord.settimeout(timeout)
    send_msg(coord, ("hello", proc, my_port))
    tag, ports, config = recv_msg(coord)
    if tag != "peers":
        raise TransportError(f"bad rendezvous reply {tag!r}")
    coord.settimeout(None)

    peers: dict[int, socket.socket] = {}
    for j in range(proc):
        s = _connect_retry(("127.0.0.1", ports[j]), deadline)
        send_msg(s, ("peer", proc))
        peers[j] = s
    listener.settimeout(max(1.0, deadline - time.monotonic()))
    for _ in range(proc + 1, nprocs):
        s, _ = listener.accept()
        tag, j = recv_msg(s)
        if tag != "peer":
            raise TransportError(f"bad peer handshake {tag!r}")
        peers[j] = s
    listener.close()
    for s in peers.values():
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return coord, peers, config


def rendezvous_coordinator(listener: socket.socket, nprocs: int,
                           config: dict, *, timeout: float = 60.0
                           ) -> dict[int, socket.socket]:
    """The coordinator's side: accept every worker's hello, then
    broadcast the peer port map plus ``config``.  Returns the
    per-process coordinator connections (the launcher's control
    channel)."""
    listener.settimeout(timeout)
    conns: dict[int, socket.socket] = {}
    ports: dict[int, int] = {}
    for _ in range(nprocs):
        conn, _ = listener.accept()
        tag, proc, port = recv_msg(conn)
        if tag != "hello" or proc in conns:
            raise TransportError(
                f"bad or duplicate hello from process {proc}")
        conns[proc] = conn
        ports[proc] = port
    for conn in conns.values():
        send_msg(conn, ("peers", ports, config))
    return conns
