"""Per-rank message-passing execution of a schedule + the worker loop.

:class:`RankExecutor` runs ONE rank's side of a
:class:`~repro.core.schedule.Schedule` against a
:class:`~repro.dist.transport.Transport`: each round's ppermute
becomes explicit ``send``/``recv`` calls honouring the IR's peer
structure (shift chains, butterfly exchanges, the pipelined segmented
ring, all-gathers through a group root).  The numpy op sequence and
combine orders mirror :class:`~repro.core.schedule.SimulatorExecutor`
step for step, so a multi-process execution is **bit-identical** to
the single-process simulator on the same schedule — the correctness
contract ``benchmarks/dist_bench.py --check`` gates.

Masked receives still consume their message (a discarded frame would
otherwise alias a later round's receive on the same (src, dst) FIFO);
only the *application* of the received payload is masked, exactly like
the SPMD executor's select-on-combine-output.

``worker_main()`` is the subprocess entry point
(``python -m repro.dist.worker``): rendezvous via the
``REPRO_DIST_*`` environment (coordinator address, process index,
world size), then a task loop — "run" executes this process's rank
block in one thread per rank, "pingpong" times a cross-process round
trip for the "dci" tier calibration, "shutdown" exits.
"""

from __future__ import annotations

import os
import threading
import time
import traceback

import numpy as np

from repro.dist import transport as transport_lib


def _np_tree(x):
    import jax

    return jax.tree.map(np.asarray, x)


def _tree_copy(x):
    import jax

    return jax.tree.map(lambda a: np.asarray(a).copy(), x)


class RankExecutor:
    """Execute one global rank's side of a schedule over a transport.

    ``stats`` (a :class:`~repro.core.schedule.CollectiveStats`), when
    given, receives the simulator's aggregate recording — callers pass
    it for exactly one rank (global rank 0) so the totals match the
    single-process measurement and the plan's predictions.
    """

    def __init__(self, transport: transport_lib.Transport):
        self.transport = transport

    # -- stats recording (simulator-compatible aggregates) ------------

    @staticmethod
    def _rec_round(stats, tree):
        if stats is not None:
            from repro.core.schedule import _nbytes

            stats.rounds += 1
            stats.bytes_per_round.append(_nbytes(tree))

    @staticmethod
    def _rec_op(stats, n: int = 1):
        if stats is not None:
            stats.op_applications += n

    @staticmethod
    def _rec_allgather(stats):
        if stats is not None:
            stats.allgathers += 1

    # -- execution -----------------------------------------------------

    def execute(self, sched, x, m, rank: int, *, stats=None):
        """Run ``sched`` for global ``rank`` on per-rank payload ``x``
        (no leading rank axis); returns this rank's output (a tuple
        for multi-output schedules)."""
        from repro.core import monoid as monoid_lib
        from repro.core import schedule as schedule_lib

        op = monoid_lib.NUMPY_OPS.get(m.name, m.op)
        ident_fn = monoid_lib.NUMPY_IDENTITY.get(m.name)
        if ident_fn is None:
            def ident_fn(t):
                return _np_tree(m.identity_like(t))

        if sched.layout is not None:
            packed = schedule_lib.pack_payloads(
                sched.layout, [_np_tree(xi) for xi in x], xp=np)
            out = self._execute(sched, packed, m, op, ident_fn, rank,
                                stats)
            return schedule_lib.unpack_fused_outputs(
                sched.layout, out, len(sched.outputs))
        return self._execute(sched, _np_tree(x), m, op, ident_fn,
                             rank, stats)

    def _execute(self, sched, x, m, op, ident_fn, rank, stats):
        from repro.core import schedule as schedule_lib

        w = _tree_copy(x) if sched.init == "x" else ident_fn(x)
        regs: dict = {}
        for run in schedule_lib._stage_runs(sched.steps):
            if isinstance(run, schedule_lib.RoundStep):  # control
                st = run
                if st.kind == "stage":
                    if st.reg:
                        regs[st.reg] = w
                    if st.src == "w":
                        x = w
                    if st.init == "identity":
                        w = ident_fn(x)
                    elif st.init == "x":
                        w = _tree_copy(x)
                    elif st.init != "w":
                        w = regs[st.init]
                else:  # merge
                    other = x if st.reg == "$x" else regs[st.reg]
                    self._rec_op(stats)
                    w = op(w, other)
                continue
            g, q = self._my_group(sched, run[0].axis, rank)
            if run[0].kind == "seg_shift":
                w = self._run_segmented(
                    run, x, op, ident_fn, g, q,
                    schedule_lib._run_seg_count(run, sched), stats)
            elif run[0].kind == "scan_reduce":
                w, prefix = self._run_scan_reduce(
                    run, x, w, m, op, ident_fn, g, q, stats)
                if run[-1].reg:
                    regs[run[-1].reg] = prefix
            elif run[0].kind == "block_exchange":
                w = self._run_block(run, x, m, op, ident_fn, g, q,
                                    stats)
            else:
                w = self._run_steps(run, x, w, m, op, ident_fn, g, q,
                                    stats)
        outs = tuple(w if o == "$w" else regs[o] for o in sched.outputs)
        return outs[0] if len(outs) == 1 else outs

    @staticmethod
    def _my_group(sched, axis_tag, rank):
        from repro.core.schedule import _axis_groups

        for g in _axis_groups(sched, axis_tag):
            if rank in g:
                return g, g.index(rank)
        raise ValueError(f"rank {rank} not in any group of axis "
                         f"{axis_tag!r} (p={sched.p})")

    def _run_steps(self, steps, x, w, m, op, ident_fn, g, q, stats):
        tr = self.transport
        pg = len(g)
        gathered = None
        for st in steps:
            if st.kind == "shift":
                if st.send == "x":
                    payload = x
                elif st.send == "w":
                    payload = w
                else:  # "w_op_x"
                    self._rec_op(stats)
                    payload = op(w, x)
                self._rec_round(stats, payload)
                if st.combine == "op":
                    self._rec_op(stats)
                if q + st.skip < pg:
                    tr.send(g[q], g[q + st.skip], payload)
                if q >= st.skip:
                    # always consume (the mask only gates application)
                    recv = tr.recv(g[q], g[q - st.skip])
                    ok = q >= st.bound if st.mask == "ge" else \
                        q > st.bound
                    if ok:
                        w = recv if st.combine == "copy" \
                            else op(recv, w)
            elif st.kind == "exchange":
                self._rec_round(stats, w)
                self._rec_op(stats, st.op_count(m.commutative))
                j = q ^ st.skip
                if j < pg:
                    tr.send(g[q], g[j], w)
                    recv = tr.recv(g[q], g[j])
                    # recv covers the lower ranks iff our side bit is
                    # set; commutative monoids use one order (simulator
                    # parity: op(old[j], old[q]))
                    w = op(recv, w) if (m.commutative or q & st.skip) \
                        else op(w, recv)
            elif st.kind == "allgather":
                self._rec_allgather(stats)
                gathered = self._allgather(x, g, q)
            elif st.kind == "fold":
                self._rec_op(stats, st.fold_count)
                acc = ident_fn(x)
                for t in range(q):
                    acc = op(acc, gathered[t])
                w = acc
            elif st.kind == "bcast":
                self._rec_allgather(stats)
                root = g[st.root]
                if g[q] == root:
                    for i in g:
                        if i != root:
                            self.transport.send(root, i, w)
                else:
                    w = tr.recv(g[q], root)
        return w

    def _allgather(self, x, g, q):
        """All ranks' inputs in group order, via the group root (rank
        g[0] collects, then redistributes the full list)."""
        tr = self.transport
        root = g[0]
        if g[q] == root:
            vals = [x] + [tr.recv(root, i) for i in g[1:]]
            for i in g[1:]:
                tr.send(root, i, vals)
            return vals
        tr.send(g[q], root, x)
        return tr.recv(g[q], root)

    def _run_scan_reduce(self, steps, x, w, m, op, ident_fn, g, q,
                         stats):
        tr = self.transport
        prefix = ident_fn(x)
        for st in steps:
            self._rec_round(stats, w)
            self._rec_op(stats, st.op_count(m.commutative))
            j = q ^ st.skip
            if j >= len(g):
                continue
            tr.send(g[q], g[j], w)
            recv = tr.recv(g[q], g[j])
            if q & st.skip:  # partner covers lower ranks
                prefix = op(recv, prefix)
                w = op(recv, w)
            else:
                w = op(recv, w) if m.commutative else op(w, recv)
        return w, prefix

    def _run_block(self, steps, x, m, op, ident_fn, g, q, stats):
        """One rank's side of the block-distributed exscan family
        (fold / vector-halving up / windowed mid exscan / doubling
        down / unfold) — combine orders mirror
        ``SimulatorExecutor._run_block`` bit for bit.  Ranks folded
        onto an odd partner idle through the core phases; the stats
        rank still records every step (aggregate accounting is
        schedule-wide, not per-rank)."""
        import jax

        from repro.core.schedule import _np_split, _np_unsplit

        tr = self.transport
        pg = len(g)
        st0 = steps[0]
        R = st0.seg
        t_eff = R.bit_length() - 1
        rho = st0.bound
        M = pg - rho
        reps = [2 * u + 1 if u < rho else u + rho for u in range(M)]
        sl = (lambda tree, a, n:
              jax.tree.map(lambda x_: x_[a:a + n], tree))
        Vs = jax.tree.map(lambda a: _np_split(a, R), x)
        # this rank's virtual id (None: a fold's idle even partner)
        if q < 2 * rho:
            u = q // 2 if q % 2 else None
        else:
            u = q - rho
        Y = jax.tree.map(np.copy, Vs) if u is not None else None
        lo = None
        O: dict = {}
        S: dict = {}
        T = P = None
        commutative = m.commutative
        for st in steps:
            self._rec_round(
                stats, jax.tree.map(lambda a: a[:st.rows], Vs))
            self._rec_op(stats, st.op_count(commutative))
            if st.phase == "fold":
                if q < 2 * rho:
                    if u is None:  # even partner: send V, then idle
                        tr.send(g[q], g[q + 1], Vs)
                    else:
                        lo = tr.recv(g[q], g[q - 1])
                        Y = op(lo, Y)
            elif st.phase == "up":
                if u is None:
                    continue
                k = st.t
                half = R >> (k + 1)
                bit = (u >> k) & 1
                kept = sl(Y, bit * half, half)
                sent = sl(Y, (1 - bit) * half, half)
                peer = g[reps[u ^ (1 << k)]]
                tr.send(g[q], peer, sent)
                recv = tr.recv(g[q], peer)
                O[k], S[k] = kept, recv
                Y = op(recv, kept) if (commutative or bit) \
                    else op(kept, recv)
            elif st.phase == "mid":
                if u is None:
                    continue
                if T is None:
                    T = Y
                    P = ident_fn(Y)
                d = st.skip << t_eff
                if u + d < M:
                    send = T if st.combine == "copy" else op(P, T)
                    tr.send(g[q], g[reps[u + d]], send)
                if u >= d:
                    recv = tr.recv(g[q], g[reps[u - d]])
                    P = recv if st.combine == "copy" else op(recv, P)
            elif st.phase == "down":
                if u is None:
                    continue
                j = st.t
                if P is None:  # single window: no mid phase ran
                    P = ident_fn(Y)
                bit = (u >> j) & 1
                peer = g[reps[u ^ (1 << j)]]
                send = P if bit else op(P, O[j])
                tr.send(g[q], peer, send)
                recv = tr.recv(g[q], peer)
                own = op(P, S[j]) if bit else P
                a_, b_ = (own, recv) if bit == 0 else (recv, own)
                P = jax.tree.map(
                    lambda x_, y_: np.concatenate([x_, y_], axis=0),
                    a_, b_)
            else:  # unfold
                if q < 2 * rho:
                    if u is None:  # receive the pre-adjust prefix
                        P = tr.recv(g[q], g[q + 1])
                    else:
                        tr.send(g[q], g[q - 1], P)
                        P = op(P, lo)
        return jax.tree.map(_np_unsplit, P,
                            jax.tree.map(np.asarray, x))

    def _run_segmented(self, steps, x, op, ident_fn, g, q, S, stats):
        import jax

        from repro.core.schedule import _np_set_seg, _np_split, \
            _np_unsplit

        tr = self.transport
        pg = len(g)
        Vs = jax.tree.map(lambda a: _np_split(a, S), x)
        seg_of = (lambda v, s: jax.tree.map(lambda a: a[s], v))
        R = ident_fn(Vs)
        cur = jax.tree.map(lambda a: a.copy(), seg_of(Vs, 0))
        ident = ident_fn(cur)
        for st in steps:
            self._rec_round(stats, cur)
            if st.prep:
                self._rec_op(stats)
            if q + 1 < pg:
                tr.send(g[q], g[q + 1], cur)
            s = st.t + 1 - q
            if q >= 1:
                recv = tr.recv(g[q], g[q - 1])
                base = recv if 0 <= s < S else ident
            else:
                base = ident
            sc = min(max(s, 0), S - 1)
            if q >= 1 and 0 <= s < S:
                R = jax.tree.map(
                    lambda acc, b: _np_set_seg(acc, sc, b), R, base)
            if st.prep:
                cur = op(base, seg_of(Vs, sc))
        return jax.tree.map(_np_unsplit, R, _np_tree(x))


def run_ranks_threaded(transport, sched, xs, m, *, ranks=None,
                       stats_rank=None, stats=None,
                       rank_seconds=None):
    """Run a block of ranks concurrently, one thread each (the worker
    process's local block, or every rank for LocalTransport tests).

    ``xs`` maps position to the per-rank payload of ``ranks[i]``
    (default: all p ranks).  ``stats`` is recorded by ``stats_rank``
    only (pass global rank 0 on the process that owns it, so totals
    mirror one simulator run).  ``rank_seconds``, when a list, is
    filled with each rank's execution walltime in ``ranks`` order —
    the per-rank timings the straggler detector consumes
    (:mod:`repro.core.autotune`).  Returns outputs in ``ranks`` order
    and re-raises the first per-rank failure.
    """
    ranks = list(range(sched.p)) if ranks is None else list(ranks)
    outs: list = [None] * len(ranks)
    errs: list = []
    if rank_seconds is not None:
        rank_seconds[:] = [0.0] * len(ranks)

    def go(idx, rank):
        try:
            t0 = time.perf_counter()
            ex = RankExecutor(transport)
            outs[idx] = ex.execute(
                sched, xs[idx], m, rank,
                stats=stats if rank == stats_rank else None)
            if rank_seconds is not None:
                rank_seconds[idx] = time.perf_counter() - t0
        except BaseException:  # noqa: BLE001 - re-raised on the caller
            errs.append((rank, traceback.format_exc()))

    threads = [threading.Thread(target=go, args=(i, r),
                                name=f"rank-{r}", daemon=True)
               for i, r in enumerate(ranks)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        rank, tb = errs[0]
        raise RuntimeError(f"rank {rank} failed:\n{tb}")
    return outs


# ---------------------------------------------------------------------------
# Worker process entry point
# ---------------------------------------------------------------------------


def _stats_dict(st) -> dict:
    return {"rounds": st.rounds,
            "op_applications": st.op_applications,
            "allgathers": st.allgathers,
            "bytes_per_round": list(st.bytes_per_round)}


def _handle_run(tr, task):
    from repro.core import monoid as monoid_lib
    from repro.core import schedule as schedule_lib

    sched = task["schedule"]
    m = monoid_lib.get(task["monoid"])
    xs = task["xs"]
    ranks = tr.local_ranks()
    stats = schedule_lib.CollectiveStats() if task.get("collect") \
        else None
    seconds = []
    rank_seconds = []
    outs = None
    for rep in range(int(task.get("repeats", 1))):
        t0 = time.perf_counter()
        per_rank: list = []
        outs = run_ranks_threaded(
            tr, sched, xs, m, ranks=ranks, stats_rank=0,
            stats=stats if rep == 0 else None,
            rank_seconds=per_rank)
        seconds.append(time.perf_counter() - t0)
        rank_seconds.append(per_rank)
    return {"outputs": outs, "seconds": seconds,
            "rank_seconds": rank_seconds,
            "stats": _stats_dict(stats) if stats else None,
            "transport": tr.stats()}


def _handle_pingpong(tr, task):
    """Time ``repeats`` payload round trips between this process's
    first rank and a peer process's first rank (the "dci" hop clock
    the cross-process calibration fits)."""
    me = tr.local_ranks()[0]
    peer = int(task["peer_proc"]) * tr.ranks_per_proc
    payload = np.zeros(max(1, int(task["nbytes"]) // 8),
                       dtype=np.int64)
    n = int(task.get("repeats", 10))
    if task.get("lead"):
        t0 = time.perf_counter()
        for _ in range(n):
            tr.send(me, peer, payload)
            tr.recv(me, peer)
        return {"seconds": time.perf_counter() - t0}
    for _ in range(n):
        got = tr.recv(me, peer)
        tr.send(me, peer, got)
    return {"seconds": None}


def worker_main() -> int:
    host, port = os.environ["REPRO_DIST_COORD"].rsplit(":", 1)
    proc = int(os.environ["REPRO_DIST_PROC"])
    nprocs = int(os.environ["REPRO_DIST_NPROCS"])
    coord, peers, config = transport_lib.rendezvous_worker(
        (host, int(port)), proc, nprocs,
        timeout=float(config_timeout := os.environ.get(
            "REPRO_DIST_TIMEOUT", "60")))
    tr = transport_lib.SocketTransport(
        proc, nprocs, int(config.get("ranks_per_proc", 1)), peers,
        timeout=float(config.get("timeout", config_timeout)))
    try:
        while True:
            tag, task = transport_lib.recv_msg(coord)
            if tag == "shutdown":
                return 0
            try:
                if tag == "run":
                    reply = _handle_run(tr, task)
                elif tag == "pingpong":
                    reply = _handle_pingpong(tr, task)
                else:
                    raise ValueError(f"unknown task {tag!r}")
                transport_lib.send_msg(coord, ("done", reply))
            except Exception:  # noqa: BLE001 - reported to launcher
                transport_lib.send_msg(
                    coord, ("error", traceback.format_exc()))
    finally:
        tr.close()
        coord.close()


if __name__ == "__main__":
    raise SystemExit(worker_main())
