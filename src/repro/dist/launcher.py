"""Spawn and drive N worker processes executing one schedule.

:class:`WorkerPool` is the coordinator: it binds a loopback rendezvous
socket, spawns ``nprocs`` subprocess workers (``python -m
repro.dist.worker`` with the coordinator address in the environment —
the ``jax.distributed.initialize`` shape), hands them the run
configuration, then scatters per-rank payloads / gathers stacked
results over the per-worker control connections.  Process k owns the
contiguous global-rank block ``[k·p_intra, (k+1)·p_intra)`` — the
row-major layout of a composed ``(inter_axis, intra_axis)`` schedule,
so intra-tier rounds stay inside one process while inter-tier rounds
cross process boundaries.

CLI smoke (the CI two-process gate)::

    PYTHONPATH=src python -m repro.dist.launcher --nprocs 2 --smoke

plans a hierarchical exscan over (proc=2, local=p_intra), executes it
across the worker pool, and verifies bit-identity against the
single-process :class:`~repro.core.schedule.SimulatorExecutor`.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import socket
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.dist import transport as transport_lib

_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


@dataclasses.dataclass
class DistResult:
    """One distributed run: stacked outputs + measurement."""

    outputs: object  # leading-rank-axis pytree (tuple if multi-output)
    seconds: list  # per repeat: max worker walltime
    stats: dict | None  # rank-0 CollectiveStats aggregate (collect=True)
    transport: dict  # summed transport counters (cross_* prove IPC)
    # per repeat: every rank's execution walltime in global-rank order
    # (the straggler detector's input — repro.core.autotune)
    rank_seconds: list = dataclasses.field(default_factory=list)


class WorkerPool:
    """N subprocess workers executing schedules across real OS
    process boundaries, each owning ``p_intra`` consecutive ranks."""

    def __init__(self, nprocs: int, p_intra: int = 1, *,
                 timeout: float = 120.0):
        if nprocs < 1 or p_intra < 1:
            raise ValueError(f"need nprocs >= 1 and p_intra >= 1, got "
                             f"{nprocs}/{p_intra}")
        self.nprocs = int(nprocs)
        self.p_intra = int(p_intra)
        self.timeout = timeout
        self._closed = False
        self._listener = socket.socket(socket.AF_INET,
                                       socket.SOCK_STREAM)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(nprocs)
        port = self._listener.getsockname()[1]
        self._logs = [tempfile.NamedTemporaryFile(
            mode="w+", prefix=f"repro-dist-w{k}-", suffix=".log",
            delete=False) for k in range(nprocs)]
        env = dict(os.environ)
        env["PYTHONPATH"] = _SRC_DIR + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        # first entry of the platform list the workers boot with —
        # keys calibrated profiles so backends never alias in the store
        self.platform = env["JAX_PLATFORMS"].split(",")[0].strip()
        env["REPRO_DIST_COORD"] = f"127.0.0.1:{port}"
        env["REPRO_DIST_NPROCS"] = str(nprocs)
        env["REPRO_DIST_TIMEOUT"] = str(timeout)
        self._procs = []
        for k in range(nprocs):
            wenv = dict(env, REPRO_DIST_PROC=str(k))
            self._procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.dist.worker"], env=wenv,
                stdout=self._logs[k], stderr=subprocess.STDOUT))
        try:
            # workers connect in arbitrary order: index the control
            # connections by process id so task k reaches process k
            self._conns = dict(sorted(
                transport_lib.rendezvous_coordinator(
                    self._listener, nprocs,
                    {"ranks_per_proc": self.p_intra,
                     "timeout": timeout},
                    timeout=timeout).items()))
        except Exception as e:
            raise RuntimeError(
                f"worker rendezvous failed: {e}\n"
                f"{self._drain_logs()}") from e

    @property
    def p(self) -> int:
        """Total schedule ranks the pool executes."""
        return self.nprocs * self.p_intra

    def _drain_logs(self) -> str:
        chunks = []
        for k, f in enumerate(self._logs):
            try:
                f.flush()
                with open(f.name) as rf:
                    text = rf.read().strip()
                if text:
                    chunks.append(f"--- worker {k} ---\n{text}")
            except OSError:
                pass
        return "\n".join(chunks)

    def _request(self, messages: list[tuple]) -> list[dict]:
        """Send one task per worker, await one reply per worker."""
        for conn, msg in zip(self._conns.values(), messages):
            transport_lib.send_msg(conn, msg)
        replies, errors = [], []
        for k, conn in self._conns.items():
            conn.settimeout(self.timeout)
            try:
                tag, body = transport_lib.recv_msg(conn)
            except (OSError, transport_lib.TransportError) as e:
                raise RuntimeError(
                    f"worker {k} died: {e}\n"
                    f"{self._drain_logs()}") from e
            if tag == "error":
                errors.append((k, body))
            else:
                replies.append(body)
        if errors:
            # every worker's reply was consumed above, so the control
            # connections stay usable after a failed task
            k, body = errors[0]
            raise RuntimeError(f"worker {k} failed:\n{body}")
        return replies

    def run(self, sched, x, monoid="add", *, collect: bool = True,
            repeats: int = 1) -> DistResult:
        """Execute ``sched`` on pytree ``x`` (leading rank axis of
        size ``self.p``) across the worker processes; returns stacked
        outputs exactly like the single-process simulator."""
        import jax

        if sched.p != self.p:
            raise ValueError(f"schedule p={sched.p} != pool "
                             f"p={self.p} ({self.nprocs}x{self.p_intra})")
        per_rank = [jax.tree.map(lambda a: np.asarray(a)[r], x)
                    for r in range(self.p)]
        msgs = []
        for k in range(self.nprocs):
            block = per_rank[k * self.p_intra:(k + 1) * self.p_intra]
            msgs.append(("run", {
                "schedule": sched, "monoid": monoid, "xs": block,
                "collect": collect and k == 0, "repeats": repeats}))
        replies = self._request(msgs)
        outs = [o for r in replies for o in r["outputs"]]
        n_out = len(sched.outputs)
        if n_out > 1:
            stacked = tuple(
                jax.tree.map(lambda *vs: np.stack(vs, axis=0),
                             *[o[j] for o in outs])
                for j in range(n_out))
        else:
            stacked = jax.tree.map(lambda *vs: np.stack(vs, axis=0),
                                   *outs)
        seconds = [max(r["seconds"][i] for r in replies)
                   for i in range(repeats)]
        # workers own contiguous rank blocks in process order, so
        # concatenating their per-rank timings yields global order
        rank_seconds = [
            [s for r in replies for s in r["rank_seconds"][i]]
            for i in range(repeats)
        ] if all(r.get("rank_seconds") for r in replies) else []
        tstats: dict = {}
        for r in replies:
            for key, v in r["transport"].items():
                tstats[key] = tstats.get(key, 0) + v
        return DistResult(outputs=stacked, seconds=seconds,
                          stats=replies[0]["stats"], transport=tstats,
                          rank_seconds=rank_seconds)

    def measure_hop(self, nbytes: int, *, repeats: int = 10) -> float:
        """Median-free one-way cross-process hop estimate: half the
        mean round-trip of ``repeats`` ping-pongs between process 0
        and process 1 at ``nbytes`` payload."""
        if self.nprocs < 2:
            raise ValueError("measure_hop needs >= 2 worker processes")
        msgs = [("pingpong", {"peer_proc": 1, "nbytes": nbytes,
                              "repeats": repeats, "lead": True}),
                ("pingpong", {"peer_proc": 0, "nbytes": nbytes,
                              "repeats": repeats, "lead": False})]
        msgs += [("pingpong", {"peer_proc": k, "nbytes": 0,
                               "repeats": 0, "lead": True})
                 for k in range(2, self.nprocs)]
        replies = self._request(msgs)
        return replies[0]["seconds"] / (2 * repeats)

    def close(self):
        if self._closed:
            return
        self._closed = True
        for conn in self._conns.values():
            try:
                transport_lib.send_msg(conn, ("shutdown", None))
            except OSError:
                pass
        deadline = time.monotonic() + 10.0
        for proc in self._procs:
            try:
                proc.wait(timeout=max(0.1,
                                      deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                proc.kill()
        for conn in self._conns.values():
            conn.close()
        self._listener.close()
        for f in self._logs:
            try:
                f.close()
                os.unlink(f.name)
            except OSError:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def run_plan(pool: WorkerPool, pl, x, *, collect: bool = True,
             repeats: int = 1) -> DistResult:
    """Execute a resolved :class:`~repro.core.scan_api.ScanPlan`
    through ``pool`` (the plan's spec names the monoid)."""
    from repro.core import monoid as monoid_lib

    name = monoid_lib.get(pl.spec.monoid).name
    return pool.run(pl.schedule(), x, monoid=name, collect=collect,
                    repeats=repeats)


# ---------------------------------------------------------------------------
# CLI: the two-process smoke the CI job runs
# ---------------------------------------------------------------------------


def _smoke_payload(p: int, nbytes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 30,
                        size=(p, max(1, nbytes // 8))).astype(np.int64)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Run a hierarchical exscan across N worker "
                    "processes and verify it against the simulator.")
    ap.add_argument("--nprocs", type=int, default=2,
                    help="worker processes (the inter/'dci' tier size)")
    ap.add_argument("--p-intra", type=int, default=4,
                    help="ranks per process (the intra/'ici' tier size)")
    ap.add_argument("--m", type=int, default=1_048_576,
                    help="per-rank payload bytes")
    ap.add_argument("--monoid", default="add")
    ap.add_argument("--smoke", action="store_true",
                    help="exit nonzero unless the multi-process result "
                         "is bit-identical to the simulator")
    ap.add_argument("--timeout", type=float, default=120.0)
    args = ap.parse_args(argv)

    from repro.core import monoid as monoid_lib
    from repro.core import scan_api
    from repro.core import schedule as schedule_lib

    spec = scan_api.ScanSpec(kind="exclusive", monoid=args.monoid)
    pl = scan_api.plan_hierarchical(spec, p_inter=args.nprocs,
                                    p_intra=args.p_intra,
                                    nbytes=args.m)
    sched = pl.schedule()
    inner, _, outer = pl.sub_plans if len(pl.sub_plans) == 3 \
        else (pl.sub_plans[0], None, pl.sub_plans[-1])
    print(f"hierarchical plan p={pl.p} "
          f"({args.nprocs} procs x {args.p_intra} ranks), "
          f"m={args.m}B:")
    print(f"  intra ('{inner.spec.axes[-1]}' tier): "
          f"{inner.algorithm} S={inner.segments} "
          f"rounds={inner.rounds}")
    print(f"  inter ('{outer.spec.axes[-1]}' tier): "
          f"{outer.algorithm} S={outer.segments} "
          f"rounds={outer.rounds}")
    x = _smoke_payload(pl.p, args.m)
    m = monoid_lib.get(args.monoid)
    with WorkerPool(args.nprocs, args.p_intra,
                    timeout=args.timeout) as pool:
        res = pool.run(sched, x, monoid=m.name)
    with schedule_lib.collect_stats() as st:
        want = schedule_lib.SimulatorExecutor().execute(sched, x, m)
    import jax

    identical = all(
        np.array_equal(g, w) for g, w in
        zip(jax.tree.leaves(res.outputs), jax.tree.leaves(want)))
    rounds_ok = res.stats["rounds"] == st.rounds == pl.rounds
    print(f"  executed: seconds={res.seconds[0]:.3f} "
          f"rounds={res.stats['rounds']} (plan {pl.rounds}) "
          f"cross_bytes={res.transport['cross_bytes']}")
    print(f"  bit-identical to simulator: {identical}")
    if args.smoke and not (
            identical and rounds_ok
            and res.transport["cross_msgs"] > 0):
        print("SMOKE FAIL")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
