import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the
device count at first init): the dry-run — and only the dry-run — sees
512 placeholder CPU devices so ``jax.make_mesh`` can build the
production meshes (16x16 single pod, 2x16x16 multi-pod).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
        --json out.json

Per cell it prints ``compiled.memory_analysis()`` (proves the program
fits HBM) and ``compiled.cost_analysis()`` FLOPs/bytes, plus the parsed
collective wire bytes — the inputs to EXPERIMENTS.md §Roofline.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import scan_api  # noqa: E402
from repro.core import schedule as schedule_lib  # noqa: E402
from repro.core.scan_api import ScanSpec  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def _cost_analysis(compiled) -> dict:
    """compiled.cost_analysis(), normalized: older jax returns [dict]."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _verify_scan_plans(cfg, mesh) -> list:
    """Resolve the cell's scan spec per mesh axis and execute each
    plan's schedule IR in the numpy simulator executor against the host
    reference (no devices), so plan/measurement drift fails the cell
    before the compile does.

    Covers the payload regimes and monoid families the cell's call
    sites re-target the spec to: the MoE-dispatch-sized small "add"
    payload (doubling schedules), a 1 MiB context-carry-sized one
    (segmented ring on bandwidth-bound axes) under both "add" and the
    non-commutative "affine" carry monoid, and the non-segmentable
    "matmul" path — plus the composed forms the consumers actually
    issue: the multi-axis batch×model scan (ONE axis-annotated
    schedule since the composition refactor), the fused
    exscan+allreduce ("scan_total") that MoE dispatch runs, and a
    fused k-scan bundle (compression offsets).
    """
    checks = []
    small = 4 * max(cfg.n_experts, 16)  # int32 expert counts
    cases = (("add", small), ("add", 1 << 20), ("affine", 1 << 20),
             ("matmul", small))
    with scan_api.use_cost_model(mesh_lib.axis_cost_model):
        for axis in mesh.axis_names:
            for mono, nbytes in cases:
                pl = scan_api.plan(
                    cfg.scan_spec.over(axis, monoid=mono),
                    p=mesh.shape[axis], nbytes=nbytes)
                res = schedule_lib.verify_plan(pl)
                checks.append({"axis": axis, "monoid": mono,
                               "nbytes": nbytes, **res})
                if not res["ok"]:
                    raise RuntimeError(
                        f"scan plan/schedule drift on axis {axis!r} "
                        f"({mono}): {res}")
        # composed multi-axis (what MoE dispatch runs over batch axes ×
        # model) and its fused scan_total form — one schedule each
        maxes = tuple(mesh.axis_names)
        msizes = tuple(int(mesh.shape[a]) for a in maxes)
        for kind in ("exclusive", "scan_total"):
            pl = scan_api.plan(
                cfg.scan_spec.over(maxes, kind=kind, monoid="add",
                                   algorithm="auto", segments=None),
                p=msizes, nbytes=small)
            res = schedule_lib.verify_plan(pl)
            checks.append({"axis": maxes, "monoid": "add", "kind": kind,
                           "nbytes": small, **res})
            if not res["ok"]:
                raise RuntimeError(
                    f"composed {kind} plan/schedule drift over "
                    f"{maxes}: {res}")
        # fused k-scan bundle (compression offsets: k tiny same-axis
        # exscans riding one schedule's rounds)
        axis = mesh.axis_names[-1]
        fp = scan_api.plan_fused(
            [cfg.scan_spec.over(axis, kind="exclusive", monoid="add",
                                algorithm="auto", segments=None)] * 4,
            int(mesh.shape[axis]), [16] * 4)
        res = fp.verify()
        checks.append({"axis": axis, "monoid": "add", "kind": "fused",
                       "nbytes": 16, "algorithm": "fused[4]",
                       "segments": 1, **res})
        if not res["ok"]:
            raise RuntimeError(
                f"fused scan plan/schedule drift on axis {axis!r}: "
                f"{res}")
    return checks


def _probe(cfg, shape, mesh, repeats: int):
    """Compile an UNROLLED reduced-depth twin of the cell and return
    (flops, bytes, CollectiveStats).  XLA's cost_analysis counts a
    ``while`` (lax.scan) body once regardless of trip count, so the full
    cell's per-device cost is reconstructed from two unrolled probes:
        cost(R) = probe(1) + (R - 1) * (probe(2) - probe(1)),
    exact for a uniform scanned stack (embed/head live in probe(1))."""
    unit = len(cfg.pattern())
    cfg_p = dataclasses.replace(cfg, n_layers=unit * repeats,
                                unroll_stack=True)
    with scan_api.use_cost_model(mesh_lib.axis_cost_model):
        compiled = steps_lib.lower_cell(cfg_p, shape, mesh).compile()
    cost = _cost_analysis(compiled)
    coll = rl.parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _extrapolate(p1, p2, repeats: int):
    f1, b1, c1 = p1
    f2, b2, c2 = p2
    r = repeats - 1
    flops = f1 + r * (f2 - f1)
    bytes_ = b1 + r * (b2 - b1)
    ops = sorted(set(c1.op_counts) | set(c2.op_counts))
    counts = {o: c1.op_counts.get(o, 0)
              + r * (c2.op_counts.get(o, 0) - c1.op_counts.get(o, 0))
              for o in ops}
    byts = {o: c1.op_bytes.get(o, 0.0)
            + r * (c2.op_bytes.get(o, 0.0) - c1.op_bytes.get(o, 0.0))
            for o in ops}
    return flops, bytes_, rl.CollectiveStats(counts, byts)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, strategy: str = "tp",
             probes: bool = True, profile_dir: str | None = None,
             **cfg_overrides) -> dict:
    cfg = configs.get(arch, sharding_strategy=strategy, **cfg_overrides)
    shape = steps_lib.SHAPES[shape_name]
    ok, reason = steps_lib.applicable(cfg, shape)
    cell = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy,
    }
    if not ok:
        cell["status"] = "skipped"
        cell["reason"] = reason
        if verbose:
            print(f"[SKIP] {arch} x {shape_name}: {reason}")
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    # install the calibrated cost profile for this mesh (defaults when
    # none is persisted) and record the pricing provenance per cell
    profile = mesh_lib.use_calibrated_profile(mesh,
                                              directory=profile_dir)
    cell["cost_profile"] = profile.provenance(
        mesh_lib.mesh_fingerprint(mesh))
    if verbose:
        print(f"  cost profile: {profile.source} "
              f"fingerprint={profile.fingerprint()}")
    cell["scan_plan_checks"] = _verify_scan_plans(cfg, mesh)
    t0 = time.time()
    # "auto" scan specs price each mesh axis by its interconnect tier
    # (DCI for "pod" on the multi-pod mesh) while this cell traces
    with scan_api.use_cost_model(mesh_lib.axis_cost_model):
        lowered = steps_lib.lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # cost probes (scan-body extrapolation — see _probe docstring);
    # the multi-pod pass skips them (roofline table is single-pod only)
    t0 = time.time()
    if probes:
        p1 = _probe(cfg, shape, mesh, 1)
        p2 = _probe(cfg, shape, mesh, 2)
        flops, bytes_hbm, coll = _extrapolate(p1, p2, cfg.n_repeats)
    else:
        cost = _cost_analysis(compiled)
        flops = float(cost.get("flops", 0.0))
        bytes_hbm = float(cost.get("bytes accessed", 0.0))
        coll = rl.parse_collectives(compiled.as_text())
    t_probe = time.time() - t0

    training = shape.kind == "train"
    seq_for_flops = shape.seq
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    model_flops = cfg.model_flops_per_token(seq_for_flops, training) * tokens
    roof = rl.Roofline(
        flops=flops, bytes_hbm=bytes_hbm, collective=coll,
        compute_s=flops / rl.PEAK_FLOPS,
        memory_s=bytes_hbm / rl.HBM_BW,
        collective_s=coll.total_bytes / rl.LINK_BW,
        model_flops=model_flops, n_devices=n_dev)

    cell.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        probe_s=round(t_probe, 1),
        flops_per_device=roof.flops,
        bytes_per_device=roof.bytes_hbm,
        collective_bytes=roof.collective.total_bytes,
        collective_ops=roof.collective.op_counts,
        collective_op_bytes=roof.collective.op_bytes,
        compute_s=roof.compute_s,
        memory_s=roof.memory_s,
        collective_s=roof.collective_s,
        dominant=roof.dominant,
        model_flops=model_flops,
        useful_flops_fraction=roof.useful_flops_fraction,
        mfu_bound=roof.mfu_bound,
        memory_analysis={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(
                mem, "peak_memory_in_bytes",
                getattr(mem, "temp_size_in_bytes", None)),
        },
    )
    if verbose:
        print(f"[OK] {arch} x {shape_name} @ {cell['mesh']} "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        plans = {(c["axis"], c["monoid"], c["nbytes"]):
                 f"{c['algorithm']}/S{c['segments']}"
                 for c in cell["scan_plan_checks"]}
        print(f"  scan plans verified (simulator): {plans}")
        print(f"  memory_analysis: {cell['memory_analysis']}")
        print(f"  cost: {roof.flops:.3e} FLOP/dev, "
              f"{roof.bytes_hbm:.3e} B/dev, "
              f"{roof.collective.total_bytes:.3e} wire B "
              f"{dict(roof.collective.op_counts)}")
        print(f"  roofline: compute {roof.compute_s*1e3:.2f} ms | "
              f"memory {roof.memory_s*1e3:.2f} ms | "
              f"collective {roof.collective_s*1e3:.2f} ms "
              f"-> {roof.dominant}-bound; "
              f"useful/HLO flops {roof.useful_flops_fraction:.2f}; "
              f"MFU bound {roof.mfu_bound:.2f}")
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(steps_lib.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--strategy", default="tp",
                    choices=["tp", "fsdp_sp", "decode_ws"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    ap.add_argument("--no-probes", action="store_true",
                    help="skip cost probes (compile-only pass)")
    ap.add_argument("--exscan", default=None,
                    choices=["auto", "123", "1doubling", "two_op",
                             "native", "ring"])
    ap.add_argument("--profile-dir", default=None,
                    help="calibrated cost-profile store (default: "
                         "tune/profiles or $REPRO_PROFILE_DIR)")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        "dry-run must see 512 placeholder devices")

    cells = []
    if args.all:
        targets = [(a, s) for a in configs.ARCHITECTURES
                   for s in steps_lib.SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        targets = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for multi_pod in meshes:
        for arch, shape in targets:
            try:
                cells.append(run_cell(
                    arch, shape, multi_pod, strategy=args.strategy,
                    probes=not args.no_probes,
                    profile_dir=args.profile_dir,
                    **(({"remat": False} if args.no_remat else {})
                       | ({"remat_policy": args.remat_policy}
                          if args.remat_policy != "nothing" else {})
                       | ({"scan": ScanSpec(kind="exclusive",
                                            algorithm=args.exscan)}
                          if args.exscan else {}))))
            except Exception as e:  # noqa: BLE001
                failures += 1
                traceback.print_exc()
                cells.append({"arch": arch, "shape": shape,
                              "mesh": "2x16x16" if multi_pod else "16x16",
                              "status": "FAILED", "error": str(e)[:500]})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(cells, f, indent=1)
        print(f"wrote {args.json}")
    print(f"\n{sum(1 for c in cells if c['status'] == 'ok')} ok, "
          f"{sum(1 for c in cells if c['status'] == 'skipped')} skipped, "
          f"{failures} failed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
