"""End-to-end training driver with checkpoint/restart fault tolerance.

Usage (CPU example — the quickstart trains a ~100M model):
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Production features exercised here end-to-end:
  * deterministic resumable data pipeline (seeded by step),
  * async sharded checkpointing with atomic commit,
  * automatic resume from the latest committed checkpoint,
  * straggler/step-time telemetry with EWMA watchdog,
  * planner-driven exscan for the MoE dispatch collective
    (``--exscan auto`` cost-model selection by default; explicit
    algorithms remain selectable for A/B runs).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.core import scan_api
from repro.core.scan_api import ScanSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim import adamw_init


class StragglerWatchdog:
    """EWMA step-time tracker; flags steps slower than ``k`` x EWMA.

    On a real cluster the flag feeds the controller's drop-and-rebalance
    policy (DESIGN.md §10); here it provides the telemetry + hook."""

    def __init__(self, alpha: float = 0.1, k: float = 3.0):
        self.alpha = alpha
        self.k = k
        self.ewma = None
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.k * self.ewma
        if slow:
            self.flagged.append(step)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--exscan", default="auto",
                    choices=["auto", "123", "1doubling", "two_op",
                             "native", "ring"])
    ap.add_argument("--profile-dir", default=None,
                    help="calibrated cost-profile store (default: "
                         "tune/profiles or $REPRO_PROFILE_DIR; see "
                         "python -m repro.core.tune)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--autotune", action="store_true",
                    help="online cost-profile refits: probe the "
                         "planned exscan schedule at --autotune-every "
                         "cadence, stream the timings into NNLS refits "
                         "and install recalibrated profiles past the "
                         "drift gate (repro.core.autotune)")
    ap.add_argument("--autotune-every", type=int, default=10,
                    help="steps between autotune probes")
    args = ap.parse_args(argv)

    get = configs.get_smoke if args.smoke else configs.get
    cfg = get(args.arch, scan=ScanSpec(kind="exclusive",
                                       algorithm=args.exscan))
    mesh = mesh_lib.make_host_mesh(args.data_mesh, args.model_mesh)
    # planner pricing provenance: prefer a profile calibrated on this
    # mesh (core/tune.py) over the hand-guessed defaults, and say which
    profile = mesh_lib.use_calibrated_profile(
        mesh, directory=args.profile_dir)
    prov = profile.provenance(mesh_lib.mesh_fingerprint(mesh))
    print(f"[planner] cost profile: {prov['source']} "
          f"fingerprint={prov['fingerprint']} "
          f"mesh={prov['mesh_fingerprint']}"
          + (f" fit_residuals={prov['fit_residuals']}"
             if prov["fit_residuals"] else ""))
    model = Model(cfg, mesh)

    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start_step = 0

    store = None
    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir)
        if args.resume == "auto":
            latest = store.latest_step()
            if latest is not None:
                state = store.restore(latest, {"params": params, "opt": opt})
                params, opt = state["params"], state["opt"]
                start_step = latest
                print(f"[resume] restored step {latest}")

    step_fn = jax.jit(make_train_step(
        cfg, mesh, lr_peak=args.lr, warmup=max(1, args.steps // 20),
        total_steps=args.steps), donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    rng = np.random.default_rng(1234)
    watchdog = StragglerWatchdog()
    tuner = None
    if args.autotune:
        from repro.core.autotune import AutoTuner

        # the training scans run inside the jitted step, so the online
        # loop times the *planned* schedule out-of-band (tuner.probe)
        # at probe cadence; installs reprice every future plan() call
        tuner = AutoTuner(profile, mesh_fingerprint="train-online")
        probe_axes = mesh_lib.batch_axes(mesh)
        probe_spec = cfg.scan.over(
            probe_axes[-1] if probe_axes else "data", monoid="add")
        probe_p = max(2, mesh_lib.data_degree(mesh))
        probe_bytes = 8 * max(1, getattr(cfg, "n_experts", 8) or 8)
    losses = []
    # "auto" scan specs price each mesh axis by its interconnect tier
    with scan_api.use_cost_model(mesh_lib.axis_cost_model), \
            jax.set_mesh(mesh):
        for step in range(start_step, args.steps):
            batch = dict(data.batch(step))
            batch.pop("positions", None)
            batch.pop("segments", None)
            if cfg.frontend == "vision":
                batch["prefix"] = jnp.asarray(rng.standard_normal(
                    (args.batch, cfg.n_prefix, cfg.d_model)),
                    jnp.dtype(cfg.dtype))
            if cfg.frontend == "audio":
                batch = {
                    "embeds": jnp.asarray(rng.standard_normal(
                        (args.batch, args.seq, cfg.d_model)),
                        jnp.dtype(cfg.dtype)),
                    "labels": jnp.asarray(batch["labels"]),
                }
            t0 = time.time()
            params, opt, metrics = step_fn(
                params, opt, batch, jnp.int32(step))
            loss = float(metrics["loss"])
            dt = time.time() - t0
            slow = watchdog.observe(step, dt)
            losses.append(loss)
            if step % args.log_every == 0 or slow:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms{'  [STRAGGLER]' if slow else ''}")
            if tuner is not None and step % args.autotune_every == 0:
                tuner.probe(probe_spec, probe_p, probe_bytes)
                res = tuner.maybe_refit()
                if res.installed:
                    prov = res.profile.provenance()
                    print(f"[autotune] step {step}: installed refit "
                          f"fingerprint={prov['fingerprint']} "
                          f"drift={dict(res.drift)} "
                          f"plans_dropped={res.plans_dropped}")
            if store and args.ckpt_every and \
                    (step + 1) % args.ckpt_every == 0:
                store.save(step + 1, {"params": params, "opt": opt},
                           blocking=False)
    if store:
        store.wait()
        store.save(args.steps, {"params": params, "opt": opt})
    if tuner is not None:
        print(f"[autotune] refits={tuner.refits} "
              f"installs={tuner.installs} "
              f"plans_dropped={tuner.plans_dropped} "
              f"reservoirs={tuner.reservoir_sizes()}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    train()
