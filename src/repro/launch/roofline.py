"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (see EXPERIMENTS.md
§Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ_ops bytes_on_wire(op) / link_bw

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the
partitioned per-device module).  Collective bytes are NOT in
cost_analysis: we parse the optimized HLO text and apply standard
ring-algorithm wire-byte accounting per op with its replica-group size.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per direction), 3D-torus with 1-hop neighbours.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]' -> bytes; tuples handled by caller via findall."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class CollectiveStats:
    op_counts: dict
    op_bytes: dict  # wire bytes per op kind

    @property
    def total_bytes(self) -> float:
        return sum(self.op_bytes.values())

    @property
    def total_count(self) -> int:
        return sum(self.op_counts.values())


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# every attribute form through which an op invokes a sub-computation
# (conditional branches, reduce/sort/fusion bodies, async wrappers,
# while conditions) — each runs once per execution of the referencing
# op; while BODIES additionally multiply by the loop trip count
_CALLED_RE = re.compile(
    r"\b(?:calls|to_apply|condition|true_computation|"
    r"false_computation|called_computation)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"\bbranch_computations=\{([^}]*)\}")
_COLLECTIVE_LINE_RE = re.compile(
    r"(?:ROOT )?%?[\w.\-]+ = (\(?[^)]*?\)?) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")


def _split_computations(hlo_text: str):
    """(computations, entry): computation name -> its op lines.  HLO
    text defines computations at column 0 with indented op lines."""
    comps: dict[str, list] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        if raw.startswith(" "):
            if cur is not None:
                comps[cur].append(raw.strip())
            continue
        m = _COMP_HEADER_RE.match(raw)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        else:
            cur = None
    return comps, entry


def _parse_collective_lines(lines):
    counts: dict[str, int] = {}
    bytes_: dict[str, float] = {}
    called: list[tuple[str, int]] = []  # (computation, multiplier)
    for line in lines:
        # while BODIES execute known_trip_count times; every other
        # sub-computation reference (conditions via _CALLED_RE,
        # conditional branches, fusion/reduce bodies, async wrappers)
        # runs once per invocation — none may be dropped
        if " while(" in line:
            wm = _WHILE_BODY_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                # unknown trip counts count the body once (legacy)
                called.append((wm.group(1),
                               int(tm.group(1)) if tm else 1))
        for name in _CALLED_RE.findall(line):
            called.append((name, 1))
        bm = _BRANCHES_RE.search(line)
        if bm:
            for tok in bm.group(1).split(","):
                tok = tok.strip().lstrip("%")
                if tok:
                    called.append((tok, 1))
        m = _COLLECTIVE_LINE_RE.match(line)
        if not m:
            continue
        out_shapes, op, phase = m.groups()
        if phase == "-done":
            continue  # counted at -start
        out_bytes = sum(_shape_bytes(s)
                        for s in re.findall(r"\w+\[[\d,]*\]", out_shapes))
        # group size: explicit lists or iota [n,g] form
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if g <= 1 and op != "collective-permute":
            continue  # degenerate
        frac = (g - 1) / g if g > 1 else 1.0
        if op == "all-reduce":
            wire = 2.0 * out_bytes * frac
        elif op == "all-gather":
            wire = out_bytes * frac
        elif op == "reduce-scatter":
            wire = out_bytes * g * frac  # out is the scattered piece
        elif op == "all-to-all":
            wire = out_bytes * frac
        else:  # collective-permute: one send per device
            wire = out_bytes
        counts[op] = counts.get(op, 0) + 1
        bytes_[op] = bytes_.get(op, 0.0) + wire
    return counts, bytes_, called


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Dynamic collective counts/bytes of an optimized HLO module.

    Loop-aware since the rolled round-table executors: the module is
    walked computation-by-computation from ENTRY through every
    sub-computation reference (while bodies/conditions, conditional
    branches, fusion/reduce bodies, async wrappers), and a collective
    inside a ``while`` body (e.g. the segmented ring's single
    ``collective-permute`` trace site) is multiplied by the loop's
    ``known_trip_count`` backend config — so the STATIC parse still
    equals the dynamic round count the planner predicts: one permute
    × (p−2+S) trips, not one op.  Unknown trip counts fall back to
    counting the body once (the pre-rolled behaviour)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:  # not a full module dump: parse lines flat
        counts, bytes_, _ = _parse_collective_lines(
            [ln.strip() for ln in hlo_text.splitlines()])
        return CollectiveStats(counts, bytes_)
    memo: dict[str, tuple] = {}

    def totals(name: str) -> tuple:
        if name in memo:
            return memo[name]
        memo[name] = ({}, {})  # cycle guard (HLO has none, but safe)
        counts, bytes_, called = _parse_collective_lines(
            comps.get(name, []))
        for sub, mult in called:
            sub_c, sub_b = totals(sub)
            for k, v in sub_c.items():
                counts[k] = counts.get(k, 0) + mult * v
            for k, v in sub_b.items():
                bytes_[k] = bytes_.get(k, 0.0) + mult * v
        memo[name] = (counts, bytes_)
        return memo[name]

    counts, bytes_ = totals(entry)
    return CollectiveStats(counts, bytes_)


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    bytes_hbm: float  # per device
    collective: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float  # 6·N_active·tokens (whole step, all devices)
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """MFU if the step ran exactly at the dominant roofline term."""
        denom = self.bound_s * self.n_devices * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0


def analyze(compiled, *, model_flops: float, n_devices: int) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(
        flops=flops,
        bytes_hbm=bytes_hbm,
        collective=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_hbm / HBM_BW,
        collective_s=coll.total_bytes / LINK_BW,
        model_flops=model_flops,
        n_devices=n_devices,
    )
