"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (see EXPERIMENTS.md
§Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ_ops bytes_on_wire(op) / link_bw

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the
partitioned per-device module).  Collective bytes are NOT in
cost_analysis: we parse the optimized HLO text and apply standard
ring-algorithm wire-byte accounting per op with its replica-group size.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per direction), 3D-torus with 1-hop neighbours.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]' -> bytes; tuples handled by caller via findall."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class CollectiveStats:
    op_counts: dict
    op_bytes: dict  # wire bytes per op kind

    @property
    def total_bytes(self) -> float:
        return sum(self.op_bytes.values())

    @property
    def total_count(self) -> int:
        return sum(self.op_counts.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    bytes_: dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\(?[^)]*?\)?) "
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        out_shapes, op, phase = m.groups()
        if phase == "-done":
            continue  # counted at -start
        out_bytes = sum(_shape_bytes(s)
                        for s in re.findall(r"\w+\[[\d,]*\]", out_shapes))
        # group size: explicit lists or iota [n,g] form
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if g <= 1 and op != "collective-permute":
            continue  # degenerate
        frac = (g - 1) / g if g > 1 else 1.0
        if op == "all-reduce":
            wire = 2.0 * out_bytes * frac
        elif op == "all-gather":
            wire = out_bytes * frac
        elif op == "reduce-scatter":
            wire = out_bytes * g * frac  # out is the scattered piece
        elif op == "all-to-all":
            wire = out_bytes * frac
        else:  # collective-permute: one send per device
            wire = out_bytes
        counts[op] = counts.get(op, 0) + 1
        bytes_[op] = bytes_.get(op, 0.0) + wire
    return CollectiveStats(counts, bytes_)


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    bytes_hbm: float  # per device
    collective: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float  # 6·N_active·tokens (whole step, all devices)
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """MFU if the step ran exactly at the dominant roofline term."""
        denom = self.bound_s * self.n_devices * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0


def analyze(compiled, *, model_flops: float, n_devices: int) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(
        flops=flops,
        bytes_hbm=bytes_hbm,
        collective=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_hbm / HBM_BW,
        collective_s=coll.total_bytes / LINK_BW,
        model_flops=model_flops,
        n_devices=n_devices,
    )
