"""Batched serving driver: continuous-batching decode loop.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Implements the production serving shape: one prefill (writes the KV /
state cache) followed by batched single-token decode steps, with greedy
sampling and per-request completion tracking.  The same ``serve_step``
is what the decode_* dry-run cells lower at the 512-chip meshes.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models.model import Model
from repro.serve.metrics import percentile


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args(argv)
    for name in ("batch", "prompt_len", "gen", "data_mesh", "model_mesh"):
        if getattr(args, name) < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1, "
                     f"got {getattr(args, name)}")

    get = configs.get_smoke if args.smoke else configs.get
    cfg = get(args.arch)
    if cfg.encoder_only:
        raise SystemExit("encoder-only arch has no decode loop")
    mesh = mesh_lib.make_host_mesh(args.data_mesh, args.model_mesh)
    model = Model(cfg, mesh)
    params = model.init_params(jax.random.PRNGKey(0))

    B, P, G = args.batch, args.prompt_len, args.gen
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, P)), jnp.int32)

    prefill = jax.jit(lambda p, c, t: model.serve_step(
        p, c, t, 0, last_only=True))
    decode = jax.jit(model.decode_step)

    with jax.set_mesh(mesh):
        cache = model.init_cache(B, P + G)
        t0 = time.time()
        logits, cache = prefill(params, cache, prompts)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        generated = [next_tok]
        step_s = []
        for i in range(G - 1):
            t0 = time.time()
            logits, cache = decode(params, cache, next_tok[:, None], P + i)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            jax.block_until_ready(next_tok)
            step_s.append(time.time() - t0)
            generated.append(next_tok)
        t_decode = sum(step_s)

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    print(f"prefill {P} tokens x {B} reqs: {t_prefill*1e3:.1f} ms")
    if G == 1:
        # the prompt's last-token argmax IS the only generated token —
        # there are no decode steps, so no decode rate exists to report
        print("decode: 0 steps (--gen 1 generates the prefill "
              "token only)")
    else:
        tok_s = B * (G - 1) / t_decode if t_decode > 0 else float("inf")
        print(f"decode {G-1} steps x {B} reqs: {t_decode*1e3:.1f} ms "
              f"({tok_s:.1f} tok/s)")
        print(f"decode step latency: p50 {percentile(step_s, 50)*1e3:.2f} "
              f"ms, p99 {percentile(step_s, 99)*1e3:.2f} ms")
    print(f"first request tokens: {out[0][:16]}")
    return out


if __name__ == "__main__":
    serve()
