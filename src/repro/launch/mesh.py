"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") —
the "pod" axis carries data parallelism across pods (its collectives
traverse DCI, which is why it is a separate, outermost axis).

``make_production_mesh`` is a function (never a module-level constant)
so importing this module does not touch jax device state.
"""

from __future__ import annotations

import jax

from repro.core.scan_api import CostModel

# α-β-γ parameters per interconnect tier (see DESIGN.md §7): "pod"
# collectives traverse DCI (higher launch latency, lower bandwidth)
# while intra-pod axes ride ICI.
ICI_COST = CostModel(alpha=1e-6, beta=1.0 / 50e9, gamma=2.0 / 819e9)
DCI_COST = CostModel(alpha=10e-6, beta=1.0 / 12.5e9, gamma=2.0 / 819e9)


def axis_cost_model(axis_name) -> CostModel:
    """Per-axis cost tier: DCI for the cross-pod axis, ICI otherwise.

    A stable module-level function, so it can be installed as the
    ambient planner cost model (``scan_api.use_cost_model(
    axis_cost_model)`` — train.py and dryrun.py do) and multi-axis
    plans price each sub-axis by its own interconnect.
    """
    axes = (axis_name,) if isinstance(axis_name, str) else \
        tuple(axis_name or ())
    return DCI_COST if "pod" in axes else ICI_COST


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_degree(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
