"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") —
the "pod" axis carries data parallelism across pods (its collectives
traverse DCI, which is why it is a separate, outermost axis).

``make_production_mesh`` is a function (never a module-level constant)
so importing this module does not touch jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_degree(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
