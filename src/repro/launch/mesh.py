"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") —
the "pod" axis carries data parallelism across pods (its collectives
traverse DCI, which is why it is a separate, outermost axis).

``make_production_mesh`` is a function (never a module-level constant)
so importing this module does not touch jax device state.
"""

from __future__ import annotations

import jax

from repro.core.scan_api import CostModel, CostProfile

# Hand-guessed default α-β-γ parameters per interconnect tier (see
# DESIGN.md §7): "pod" collectives traverse DCI (higher launch latency,
# lower bandwidth) while intra-pod axes ride ICI.  These are the
# ``source="default"`` fallback — ``resolve_profile`` prefers a
# calibrated profile measured on the actual mesh (core/tune.py).
ICI_COST = CostModel(alpha=1e-6, beta=1.0 / 50e9, gamma=2.0 / 819e9)
DCI_COST = CostModel(alpha=10e-6, beta=1.0 / 12.5e9, gamma=2.0 / 819e9)

DEFAULT_PROFILE = CostProfile(
    tiers=(("dci", DCI_COST), ("ici", ICI_COST)),
    source="default", axis_tiers=(("pod", "dci"),),
    default_tier="ici")

_active_profile: CostProfile | None = None


def install_profile(profile: CostProfile | None) -> CostProfile | None:
    """Install ``profile`` as the pricing source ``axis_cost_model``
    resolves (None restores the defaults).  Returns the previously
    installed profile.  Because the plan cache keys on resolved
    pricing constants, installing a recalibrated profile invalidates
    every stale plan without an explicit cache flush."""
    global _active_profile
    prev = _active_profile
    _active_profile = profile
    return prev


def current_profile() -> CostProfile:
    """The installed (calibrated) profile, or the default one."""
    return _active_profile or DEFAULT_PROFILE


def axis_cost_model(axis_name) -> CostModel:
    """Per-axis pricing kernel: the cross-pod axis rides the "dci"
    tier, everything else "ici" — resolved from the *installed*
    profile (calibrated when one is installed, hand-guessed defaults
    otherwise).

    A stable module-level function, so it can be installed as the
    ambient planner cost model (``scan_api.use_cost_model(
    axis_cost_model)`` — train.py and dryrun.py do) and multi-axis
    plans price each sub-axis by its own interconnect.
    """
    return current_profile().for_axis(axis_name)


def mesh_fingerprint(mesh, *, processes: int | None = None,
                     local_devices: int | None = None) -> str:
    """Identity of a mesh for the calibrated-profile store: platform,
    device kind, the axis-name/size grid, and — for multi-process
    runtimes — the process topology.

    A profile fitted across N processes prices real inter-process
    hops; resolving it for a single-process mesh (or vice versa)
    would poison planning, so the fingerprint folds in the process
    count and per-process device shape whenever more than one process
    participates.  Single-process fingerprints are unchanged
    (``processes`` defaults to ``jax.process_count()``), so existing
    stored profiles stay resolvable."""
    dev = mesh.devices.flat[0]
    kind = getattr(dev, "device_kind", "unknown")
    grid = "x".join(f"{a}{mesh.shape[a]}" for a in mesh.axis_names)
    base = f"{getattr(dev, 'platform', 'unknown')}-{kind}-{grid}"
    if processes is None:
        processes = jax.process_count()
    if int(processes) > 1:
        if local_devices is None:
            local_devices = jax.local_device_count()
        base += f"-procs{int(processes)}x{int(local_devices)}"
    return base


def resolve_profile(mesh=None, directory: str | None = None,
                    fingerprint: str | None = None) -> CostProfile:
    """The best available profile for ``mesh``: a calibrated profile
    persisted under the mesh's fingerprint, else one from the
    device-free simulated calibration flow (``python -m
    repro.core.tune --simulate``), else :data:`DEFAULT_PROFILE`."""
    from repro.core import tune  # lazy: tune lazily imports this module

    fp = fingerprint or (mesh_fingerprint(mesh) if mesh is not None
                         else None)
    if fp is not None:
        prof = tune.load_profile(fp, directory)
        if prof is not None:
            return prof
    prof = tune.load_profile("simulated-default", directory)
    return prof if prof is not None else DEFAULT_PROFILE


def use_calibrated_profile(mesh=None,
                           directory: str | None = None) -> CostProfile:
    """Resolve and install the calibrated profile for ``mesh`` (falls
    back to defaults); returns the installed profile so callers can
    log its provenance."""
    prof = resolve_profile(mesh, directory)
    install_profile(prof if prof is not DEFAULT_PROFILE else None)
    return prof


def fake_device_env(n_devices: int, env=None) -> dict:
    """Environment for a subprocess that must see ``n_devices`` fake
    CPU devices (jax fixes the count at first init, so a fresh
    process is the only way to change it).

    Strips ANY inherited device-count flag first: XLA honours the
    LAST ``--xla_force_host_platform_device_count`` occurrence, so an
    ambient count (CI env, the dry-run's 512) would silently override
    the requested one.  Shared by ``tests/helpers.run_with_devices``
    and ``benchmarks/exec_bench.py``."""
    import os

    out = dict(os.environ if env is None else env)
    inherited = [f for f in out.get("XLA_FLAGS", "").split()
                 if not f.startswith(
                     "--xla_force_host_platform_device_count=")]
    out["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={n_devices}"]
        + inherited)
    return out


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_degree(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
