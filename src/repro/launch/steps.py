"""Step functions + abstract input specs for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every input of the cell's step function;
``make_step``/``shardings`` build the jit-able callable and its
in/out shardings.  The dry-run lowers ``jax.jit(step, in_shardings=...)
.lower(*specs).compile()`` — nothing here ever touches real data.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.models import params as PD
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_lr
from repro.sharding import rules as rules_lib


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq: int
    batch: int
    long_context: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           long_context=True),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; else the recorded reason."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.long_context and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("pure full-attention arch: 500k context needs "
                       "sub-quadratic attention (DESIGN.md shape skips)")
    return True, ""


def kv_dup(cfg: ModelConfig, mesh) -> int:
    """KV-head duplication factor for the decode cache.

    We duplicate kv heads to the smallest count that (a) the TP degree
    divides (so the cache heads dim shards) and (b) divides n_heads (so
    GQA grouping stays exact).  If no such count exists (e.g. 24 q
    heads, kv=2, tp=16) we return 1 and the cache falls back to
    sequence-over-model sharding — see cache_logical_axes."""
    tp = mesh.shape["model"]
    kv, h = cfg.n_kv_heads, cfg.n_heads
    for dup in range(1, h // kv + 1):
        kvd = kv * dup
        if kvd % tp == 0 and h % kvd == 0:
            return dup
    return 1


def kv_shardable(cfg: ModelConfig, mesh) -> bool:
    tp = mesh.shape["model"]
    kvd = cfg.n_kv_heads * kv_dup(cfg, mesh)
    return kvd % tp == 0


# --------------------------- abstract inputs ---------------------------


def _batch_specs(cfg: ModelConfig, B: int, S: int):
    """S is the TOTAL backbone sequence; vlm frontends consume the first
    n_prefix positions with stub patch embeddings (DESIGN.md §6)."""
    dt = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.frontend == "audio":
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return out
    s_tok = S - (cfg.n_prefix if cfg.frontend == "vision" else 0)
    out["tokens"] = jax.ShapeDtypeStruct((B, s_tok), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((B, s_tok), jnp.int32)
    if cfg.frontend == "vision":
        out["prefix"] = jax.ShapeDtypeStruct((B, cfg.n_prefix, cfg.d_model),
                                             dt)
    return out


def _batch_shardings(cfg: ModelConfig, batch_specs, mesh, rules, B):
    bt = mesh_lib.batch_axes(mesh)
    b_entry = bt if (bt and B % mesh_lib.data_degree(mesh) == 0) else None

    def shard(s):
        spec = P(b_entry, *([None] * (len(s.shape) - 1)))
        return NamedSharding(mesh, spec)

    return jax.tree.map(shard, batch_specs)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(abstract_args, arg_shardings, donate_argnums) for the cell."""
    model = Model(cfg, mesh)
    rules = rules_lib.rules_for(cfg)
    params = model.abstract_params()
    p_shard = model.param_shardings(rules)

    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        opt_shard = type(opt)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(
                lambda s, sh: sh, opt.mu, p_shard),
            nu=jax.tree.map(lambda s, sh: sh, opt.nu, p_shard),
        )
        batch = _batch_specs(cfg, shape.batch, shape.seq)
        b_shard = _batch_shardings(cfg, batch, mesh, rules, shape.batch)
        step_ct = jax.ShapeDtypeStruct((), jnp.int32)
        args = (params, opt, batch, step_ct)
        shardings = (p_shard, opt_shard, b_shard, NamedSharding(mesh, P()))
        return args, shardings, (0, 1)

    # serving cells
    if cfg.encoder_only:  # prefill == one full encode pass, no cache
        bt = mesh_lib.batch_axes(mesh)
        b_entry = bt if (bt and shape.batch %
                         mesh_lib.data_degree(mesh) == 0) else None
        dt = jnp.dtype(cfg.dtype)
        embeds = jax.ShapeDtypeStruct(
            (shape.batch, shape.seq, cfg.d_model), dt)
        sh = NamedSharding(mesh, P(b_entry, None, None))
        return (params, embeds), (p_shard, sh), ()

    dup = kv_dup(cfg, mesh)
    seq_sharded = shape.long_context
    if shape.kind == "prefill":
        S_in, cache_len_known = shape.seq, 0
        cache_max = shape.seq
    else:
        S_in, cache_len_known = 1, None
        cache_max = shape.seq
    cache = model.abstract_cache(shape.batch, cache_max, dup)
    cache_axes = model.cache_logical_axes(
        seq_sharded, kv_shardable(cfg, mesh))
    cache_shard = jax.tree.map(
        lambda log, s: rules.shard(log, mesh, s.shape),
        cache_axes, cache,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    bt = mesh_lib.batch_axes(mesh)
    b_ok = shape.batch % mesh_lib.data_degree(mesh) == 0
    b_entry = bt if (bt and b_ok) else None
    dt = jnp.dtype(cfg.dtype)
    vlm_prefill = cfg.frontend == "vision" and shape.kind == "prefill"
    s_tok = S_in - (cfg.n_prefix if vlm_prefill else 0)
    tokens = jax.ShapeDtypeStruct((shape.batch, s_tok), jnp.int32)
    tok_shard = NamedSharding(mesh, P(b_entry, None))
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    args = [params, cache, tokens, cache_len]
    shardings = [p_shard, cache_shard, tok_shard, NamedSharding(mesh, P())]
    if vlm_prefill:
        args.append(jax.ShapeDtypeStruct(
            (shape.batch, cfg.n_prefix, cfg.d_model), dt))
        shardings.append(NamedSharding(mesh, P(b_entry, None, None)))
    return tuple(args), tuple(shardings), (1,)


# --------------------------- step functions ---------------------------


def make_train_step(cfg: ModelConfig, mesh, *, lr_peak: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000):
    model = Model(cfg, mesh)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_lr(step, peak=lr_peak, warmup=warmup, total=total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, out_metrics

    return train_step


def make_serve_step(cfg: ModelConfig, mesh, shape: ShapeSpec):
    model = Model(cfg, mesh)
    last_only = shape.kind == "prefill"

    if cfg.encoder_only:
        def encode_step(params, embeds):
            logits, _ = model.forward(params, None, embeds)
            return logits

        return encode_step

    if cfg.frontend == "vision" and shape.kind == "prefill":
        def serve_step(params, cache, tokens, cache_len, prefix):
            return model.serve_step(params, cache, tokens, cache_len,
                                    prefix_embeds=prefix,
                                    last_only=last_only)
    else:
        def serve_step(params, cache, tokens, cache_len):
            return model.serve_step(params, cache, tokens, cache_len,
                                    last_only=last_only)

    return serve_step


def make_step(cfg: ModelConfig, mesh, shape: ShapeSpec):
    if shape.kind == "train":
        return make_train_step(cfg, mesh)
    return make_serve_step(cfg, mesh, shape)


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Lower (but don't compile) one cell. Returns the Lowered object."""
    step = make_step(cfg, mesh, shape)
    args, shardings, donate = input_specs(cfg, shape, mesh)
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=donate)
    with jax.set_mesh(mesh):
        return jitted.lower(*args)
