"""Sharded checkpointing with atomic commit, async save, elastic restore.

Layout:
    <dir>/step_000100/
        manifest.json          # pytree structure, shapes, dtypes
        shard_00000.npz        # this host's leaves (flat index -> array)
        COMMITTED              # written last: marks the checkpoint usable

Fault-tolerance contract:
  * save is all-or-nothing (COMMITTED marker written after fsync of all
    shards) — a crash mid-save leaves the previous checkpoint intact;
  * ``latest_step`` ignores uncommitted directories;
  * restore works with a different host count than save (elastic): the
    manifest records which flat leaves live in which shard, and every
    host reads what it needs;
  * an optional background thread makes saves asynchronous (off the
    training critical path), with ``wait()`` joining before the next
    save or exit.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def tree_paths(tree):
    paths = [
        jax.tree_util.keystr(kp)
        for kp, _ in jax.tree_util.tree_leaves_with_path(tree)
    ]
    return paths


class CheckpointStore:
    def __init__(self, directory: str, host_id: int = 0, n_hosts: int = 1):
        self.dir = directory
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------- save -----------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree, blocking: bool = True):
        """Save ``tree`` (host-local copies of its shard of leaves)."""
        self.wait()
        leaves, _ = _flatten(tree)
        paths = tree_paths(tree)
        arrays = [np.asarray(l) for l in leaves]

        def work():
            d = self._step_dir(step)
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            if self.host_id == 0:
                shutil.rmtree(d, ignore_errors=True)
                manifest = {
                    "step": step,
                    "n_hosts": self.n_hosts,
                    "leaves": [
                        {
                            "path": p,
                            "shape": list(a.shape),
                            "dtype": str(a.dtype),
                            "shard": i % self.n_hosts,
                        }
                        for i, (p, a) in enumerate(zip(paths, arrays))
                    ],
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
            # every host writes the leaves it owns (round-robin by index)
            mine = {
                str(i): a
                for i, a in enumerate(arrays)
                if i % self.n_hosts == self.host_id
            }
            np.savez(os.path.join(tmp, f"shard_{self.host_id:05d}.npz"),
                     **mine)
            # single-host: commit immediately; multi-host: host 0 calls
            # commit() after the cross-host barrier (all shards written)
            if self.n_hosts == 1:
                self.commit(step)

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def commit(self, step: int):
        """Atomically publish a checkpoint once every host has written
        its shard (call from host 0 after a barrier)."""
        d = self._step_dir(step)
        tmp = d + ".tmp"
        expected = {f"shard_{h:05d}.npz" for h in range(self.n_hosts)}
        present = set(os.listdir(tmp))
        missing = expected - present
        if missing:
            raise RuntimeError(f"commit({step}): missing shards {missing}")
        os.replace(tmp, d)
        with open(os.path.join(d, "COMMITTED"), "w") as f:
            f.write("ok")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ----------------------------- load -----------------------------

    def latest_step(self) -> int | None:
        best = None
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                os.path.join(self.dir, name, "COMMITTED")
            ):
                s = int(m.group(1))
                best = s if best is None or s > best else best
        return best

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (shapes must match);
        works regardless of the saving host count (elastic restart)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shards: dict[int, np.lib.npyio.NpzFile] = {}
        leaves, treedef = _flatten(like)
        out = []
        for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
            sh = meta["shard"]
            if sh not in shards:
                shards[sh] = np.load(
                    os.path.join(d, f"shard_{sh:05d}.npz"))
            arr = shards[sh][str(i)]
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"leaf {meta['path']}: checkpoint shape {arr.shape} "
                    f"!= expected {want_shape}")
            out.append(arr)
        return treedef.unflatten(out)
