"""Deterministic synthetic data pipeline with sequence packing.

Production shape: per-host shards, deterministic by (seed, step, host),
so restart-from-checkpoint replays identically (fault tolerance) and
elastic re-sharding (different host count) keeps the global stream
stable.

Packing: variable-length documents are packed into fixed (B, S) windows;
document offsets AND document ordinals (the segment-id base) are both
exclusive prefix sums over the same document stream, computed in one
pass with ``scan_api.host_fused_exscan`` — the numpy twin of the device
collective's ``fused_scan`` (a multi-host deployment would hand the
same shapes to ``scan_api.fused_scan`` under a mesh for global
cross-host offsets riding one set of rounds).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scan_api import host_fused_exscan


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    pad_id: int = 0


class SyntheticLM:
    """Markov-ish synthetic token stream: enough structure that CE
    decreases under training, fully deterministic."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = cfg.global_batch // n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.host_id))

    def docs_for_step(self, step: int) -> list[np.ndarray]:
        """Variable-length documents for this host at this step."""
        cfg = self.cfg
        rng = self._rng(step)
        need = self.local_batch * cfg.seq_len
        docs = []
        total = 0
        while total < need * 2:
            n = int(rng.integers(cfg.mean_doc_len // 4,
                                 cfg.mean_doc_len * 2))
            # structured: random walk over vocab with momentum — learnable
            start = int(rng.integers(1, cfg.vocab))
            stride = int(rng.integers(1, 17))
            doc = (start + stride * np.arange(n)) % (cfg.vocab - 1) + 1
            noise = rng.integers(0, cfg.vocab, n)
            mask = rng.random(n) < 0.05
            doc = np.where(mask, noise, doc)
            docs.append(doc.astype(np.int32))
            total += n
        return docs

    def pack(self, docs: list[np.ndarray]):
        """Pack docs into (local_batch, seq_len) with position reset.

        Offsets of each document in the flat stream are the exclusive
        prefix sums of document lengths, and the segment-id base of
        each document is the exclusive prefix count of documents seen
        (the running ordinal) — two exscans over the same stream,
        computed in ONE fused pass (scan_api.host_fused_exscan, the
        host twin of fused_scan; under elastic re-sharding both would
        ride the same cross-host rounds).
        """
        cfg = self.cfg
        lengths = np.array([len(d) for d in docs], np.int64)
        offsets, ordinals = host_fused_exscan(
            [lengths, np.ones_like(lengths)])
        need = self.local_batch * cfg.seq_len
        flat = np.zeros(need, np.int32)
        pos = np.zeros(need, np.int32)
        seg = np.zeros(need, np.int32)
        for d, o, ordinal in zip(docs, offsets, ordinals):
            o = int(o)
            if o >= need:
                break
            n = min(len(d), need - o)
            flat[o : o + n] = d[:n]
            pos[o : o + n] = np.arange(n)
            seg[o : o + n] = int(ordinal) + 1
        shape = (self.local_batch, cfg.seq_len)
        return {
            "tokens": flat.reshape(shape),
            "positions": pos.reshape(shape),
            "segments": seg.reshape(shape),
            "labels": flat.reshape(shape),
        }

    def batch(self, step: int):
        return self.pack(self.docs_for_step(step))


def synthetic_batch(cfg_model, batch: int, seq: int, seed: int = 0):
    """One-shot batch for examples/tests (matches Model.loss's schema)."""
    rng = np.random.default_rng(seed)
    out = {}
    if cfg_model.frontend == "audio":
        out["embeds"] = rng.standard_normal(
            (batch, seq, cfg_model.d_model)).astype(np.float32)
        out["labels"] = rng.integers(
            0, cfg_model.vocab, (batch, seq)).astype(np.int32)
        return out
    dc = DataConfig(vocab=cfg_model.vocab, seq_len=seq, global_batch=batch,
                    seed=seed)
    b = SyntheticLM(dc).batch(0)
    out["tokens"] = b["tokens"]
    out["labels"] = b["labels"]
    if cfg_model.frontend == "vision":
        out["prefix"] = rng.standard_normal(
            (batch, cfg_model.n_prefix, cfg_model.d_model)
        ).astype(np.float32)
    return out
