"""Top-k gradient compression with error feedback (distributed-optim).

Data-parallel gradient sync exchanging only the top-k magnitude entries
per device (EF-SGD style): the residual is carried in an error-feedback
buffer so the compression is unbiased over time.  Buffers are
fixed-size (k_max) for static shapes; each device may use fewer slots
(threshold crossing) and the *compact* layout offsets — where rank r's
entries start in the concatenated global value array — are the
exclusive prefix sums of per-rank counts, computed with the paper's
exscan.  One offset exscan is needed PER LEAF GROUP; they are k
concurrent scalar scans over the same axis, so they route through
``scan_api.fused_scan``: the planner packs them into one payload and
all k ride a single schedule's rounds (α·q once, not k·α·q — the
paper's latency argument applied across payloads).  The algorithm is
planner-selected (``ScanSpec``-driven like every other exscan site;
the legacy ``algorithm=`` kwarg remains as a compatibility alias).

Used inside shard_map over the data axes when
``TrainConfig.grad_compression_fraction`` is set (launch/train.py path
keeps dense psum by default — compression is opt-in, as accuracy trade
offs are workload-specific).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.scan_api import ScanSpec, fused_scan

# Per-rank slot counts are a tiny int vector — the paper's small-m
# regime, where "auto" picks the round-optimal schedule for the p at
# hand (123-doubling at the paper's scales, two-⊕ at tiny power-of-2 p).
OFFSETS_SPEC = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")


def leaf_slot_counts(sizes, k_fraction: float) -> list[int]:
    """Per-leaf compact slot counts: the top-k budget each rank
    contributes to leaf group i is ``max(1, int(sizes[i] *
    k_fraction))``.  Shared by :func:`sparse_gradient_sync` (the slot
    math and the offset exscans below) and the serve subsystem's
    compression request generator (``repro.serve.workloads``), so the
    traffic the scan service benches is byte-for-byte the traffic this
    module issues."""
    return [max(1, int(int(n) * k_fraction)) for n in sizes]


def _topk_sparsify(g: jax.Array, k: int):
    """Returns (values, indices, dense_contribution) of the k largest-
    magnitude entries of flat g."""
    flat = g.reshape(-1)
    vals, idx = lax.top_k(jnp.abs(flat), k)
    picked = flat[idx]
    dense = jnp.zeros_like(flat).at[idx].set(picked)
    return picked, idx.astype(jnp.int32), dense.reshape(g.shape)


def sparse_gradient_sync(
    grads,
    err,
    axis_name: str,
    *,
    k_fraction: float = 0.01,
    spec: ScanSpec | None = None,
    algorithm: str | None = None,
):
    """One EF-top-k gradient exchange. Call INSIDE shard_map.

    Args:
      grads: pytree of per-device (unreduced) gradients.
      err: matching error-feedback pytree (zeros at step 0).
      axis_name: data-parallel axis.

    Returns (synced_grads, new_err, stats) where stats carries the
    compact-layout offsets ((p,)-int per leaf group) from the exscan.
    """
    p = lax.axis_size(axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        n = g.size
        (k,) = leaf_slot_counts([n], k_fraction)
        vals, idx, mine = _topk_sparsify(g, k)
        new_e = g - mine
        # exchange fixed-size segments
        vals_all = lax.all_gather(vals, axis_name)  # (p, k)
        idx_all = lax.all_gather(idx, axis_name)
        dense = jnp.zeros((n,), jnp.float32)
        dense = dense.at[idx_all.reshape(-1)].add(vals_all.reshape(-1))
        return (dense / p).reshape(g.shape), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = tree.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    synced = tree.unflatten([o[0] for o in out])
    new_err = tree.unflatten([o[1] for o in out])

    # compact layout: this rank's write offset for each leaf = exscan
    # of its per-rank slot count (all k here; variable under
    # thresholding, where each leaf group's count is computed
    # independently).  The k per-leaf scans go through fused_scan,
    # which packs them back into one payload riding a single
    # schedule's rounds — same wire cost as the old hand-packed
    # (k,)-vector scan, but each offset is now its own planned scan.
    ospec = (spec if spec is not None else OFFSETS_SPEC)
    if algorithm is not None:  # legacy string path
        ospec = ospec.over(axis_name, algorithm=algorithm)
    ospec = ospec.over(axis_name, kind="exclusive", monoid="add")
    counts = [jnp.int32(c) for c in leaf_slot_counts(
        [g.size for g in flat_g], k_fraction)]
    offs = fused_scan([(c, ospec) for c in counts])
    offsets = jnp.stack(offs)
    return synced, new_err, {"compact_offsets": offsets}


def init_error_feedback(grads):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
