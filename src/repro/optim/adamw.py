"""AdamW, pure-JAX, pytree-native, sharding-transparent.

Optimizer state leaves inherit the parameter sharding (first/second
moments are elementwise), so FSDP-sharded params get FSDP-sharded
optimizer states for free — the ZeRO property falls out of GSPMD.

Moments are kept in float32 regardless of param dtype (mixed-precision
training: bf16 params, f32 master statistics).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment  (f32)
    nu: Any  # second moment (f32)


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """One AdamW step.  ``lr`` may be a scalar or traced value."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1.0 - b1) * g32
        nu = b2 * nu + (1.0 - b2) * (g32 * g32)
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps)
        delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)


def cosine_lr(step, *, peak: float, warmup: int, total: int,
              floor_frac: float = 0.1):
    """Linear warmup + cosine decay to ``floor_frac * peak``."""
    t = step.astype(jnp.float32)
    warm = peak * t / jnp.maximum(1.0, warmup)
    prog = jnp.clip((t - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                  (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
