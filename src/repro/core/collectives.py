"""SPMD prefix-scan collectives: implementations behind ``scan_api``.

Each simultaneous send-receive communication round of the paper becomes
one ``lax.ppermute`` along a named mesh axis (every device sends and
receives at most one message per round — the paper's one-ported model).
Edge ranks, which in the MPI formulation conditionally skip
sends/receives, are handled uniformly in SPMD via the monoid identity
and masked combines; the masks are exactly the paper's loop conditions
(``0 < f``, ``t < p``).

The preferred entry point is the planner API::

    from repro.core.scan_api import ScanSpec, scan, plan

    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    y = scan(x, spec.over("data"))        # planner picks the algorithm
    plan(spec, p=256, nbytes=64)          # inspect the choice first

Every implementation below registers itself with
``@register_algorithm(...)``, carrying its theoretical round/⊕/byte
costs from :mod:`repro.core.oracle` so plans predict ``collect_stats``
measurements exactly.  Registered exclusive-scan algorithms:

  * ``"123"``        — the paper's new 123-doubling algorithm
                       (Algorithm 1): q = ceil(log2(p-1)+log2(4/3))
                       rounds, q-1 result-path ⊕.
  * ``"1doubling"``  — shift + straight doubling: 1+ceil(log2(p-1))
                       rounds, ceil(log2(p-1)) ⊕.
  * ``"two_op"``     — two-⊕ doubling: ceil(log2 p) rounds,
                       2*ceil(log2 p)-1 ⊕.
  * ``"native"``     — all-gather + local fold (what a library would do
                       without the paper; XLA-native collective).
  * ``"ring"``       — p-1 neighbour rounds (the pipelined/fixed-degree
                       baseline the paper cites for large m; see
                       DESIGN.md §7).

The legacy string API is kept as thin compatibility wrappers over
``scan_api``: ``exscan(x, axis, m, algorithm)``,
``inclusive_scan(x, axis, m)`` and ``allreduce(x, axis, m)``.

All functions must be called inside ``shard_map`` (or any context where
``axis_name`` is bound).  Inputs may be arbitrary pytrees; the monoid
operates on the whole tree.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import monoid as monoid_lib
from repro.core import oracle
from repro.core import scan_api
from repro.core.scan_api import ScanSpec, register_algorithm, scan


# ---------------------------------------------------------------------------
# Trace-time instrumentation: counts ppermute rounds and ⊕ applications so
# tests and benchmarks can assert the paper's costs on the actual
# implementation (not just the numpy oracle).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveStats:
    rounds: int = 0  # ppermute calls (communication rounds)
    op_applications: int = 0  # ⊕ applications per device (SPMD)
    allgathers: int = 0
    bytes_per_round: list = dataclasses.field(default_factory=list)


_tls = threading.local()


@contextlib.contextmanager
def collect_stats():
    """Context manager capturing round/op counts of scans traced inside."""
    stats = CollectiveStats()
    prev = getattr(_tls, "stats", None)
    _tls.stats = stats
    try:
        yield stats
    finally:
        _tls.stats = prev


def _stats() -> CollectiveStats | None:
    return getattr(_tls, "stats", None)


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _record_round(tree):
    s = _stats()
    if s is not None:
        s.rounds += 1
        s.bytes_per_round.append(_nbytes(tree))


def _record_op(n: int = 1):
    """Count n ⊕ *executions* (a traced-once loop body records its trip
    count, so stats mean executions, not trace sites)."""
    s = _stats()
    if s is not None:
        s.op_applications += n


def _record_allgather():
    s = _stats()
    if s is not None:
        s.allgathers += 1


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _axis_size(axis_name) -> int:
    return lax.axis_size(axis_name)


def _shift_up(tree, axis_name: str, skip: int, p: int):
    """One communication round: rank r sends to r+skip (where r+skip < p).

    Non-receiving ranks get zero-fill from ppermute; callers mask.
    """
    perm = [(r, r + skip) for r in range(p - skip)]
    _record_round(tree)
    return jax.tree.map(lambda x: lax.ppermute(x, axis_name, perm), tree)


def _masked_combine(m: monoid_lib.Monoid, recv, w, mask):
    """W <- recv ⊕ W where mask, else W (recv covers lower ranks)."""
    combined = m.op(recv, w)
    _record_op()
    return jax.tree.map(
        lambda c, x: jnp.where(mask, c, x), combined, w
    )


def _fixup_identity(m: monoid_lib.Monoid, recv, has_src):
    """Replace zero-fill from ppermute with the monoid identity."""
    ident = m.identity_like(recv)
    return jax.tree.map(
        lambda t, i: jnp.where(has_src, t, i), recv, ident
    )


def _doubling_phase(w, axis_name: str, m: monoid_lib.Monoid, r, p: int,
                    skips, strict: bool = True):
    """The doubling loop shared by 123-doubling, 1-doubling and the
    Hillis-Steele inclusive scan: for each skip s, W ← W_{r-s} ⊕ W on
    ranks where the window still reaches below 0 (mask ``r > s``, or
    ``r >= s`` for the inclusive scan where W covers the rank itself).
    """
    for s in skips:
        recv = _shift_up(w, axis_name, s, p)
        has = r > s if strict else r >= s
        w = _masked_combine(m, _fixup_identity(m, recv, has), w, has)
    return w


# ---------------------------------------------------------------------------
# Predicted-cost functions for the registry (see scan_api.ScanAlgorithm:
# these must match collect_stats() measurements of the traced programs —
# tests/test_scan_api.py asserts this for every p in 2..17).
# ---------------------------------------------------------------------------


def _ops_123(p: int) -> int:
    # round 1 records a send-side prep + a combine, each later round one
    # combine: 2 + (rounds - 2) = rounds (p >= 3).
    return 0 if p <= 2 else oracle.q_123(p)


def _ops_1doubling(p: int) -> int:
    return max(0, oracle.rounds_1doubling(p) - 1)


def _ops_two_op(p: int) -> int:
    return 2 * max(0, oracle.rounds_two_op(p) - 1)


def _rounds_inclusive(p: int) -> int:
    return 0 if p <= 1 else math.ceil(math.log2(p))


def _rounds_butterfly(p: int) -> int:
    return 0 if p <= 1 else math.ceil(math.log2(p))


def _ops_butterfly(p: int) -> int:
    if p <= 1:
        return 0
    if p & (p - 1):  # non-power-of-two: inclusive scan + broadcast
        return _rounds_inclusive(p)
    return 2 * _rounds_butterfly(p)


def _ag_butterfly(p: int) -> int:
    return 1 if p > 1 and (p & (p - 1)) else 0


# ---------------------------------------------------------------------------
# The paper's algorithms
# ---------------------------------------------------------------------------


@register_algorithm(
    "123", kind="exclusive", rounds=oracle.q_123, ops=_ops_123)
def exscan_123(x, axis_name: str, m: monoid_lib.Monoid):
    """Algorithm 1 (123-doubling) as q ppermute rounds.

    Skip schedule s_0=1, s_1=2, s_k=3*2^(k-2).  Masks mirror the paper's
    conditions: round-0 receive iff r>=1, round-1 combine iff r>=2,
    round-k combine iff r - s_k > 0 (rank complete once its window
    bottoms out at 0 — the paper's ``while 0 < f``).
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)

    # Round 0 (skip 1): W = V_{r-1}; rank 0 holds the identity.
    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)
    if p == 2:
        return w

    # Round 1 (skip 2): send W ⊕ V (rank 0's W is the identity, so it
    # sends plain V exactly as in Algorithm 1); combine T ⊕ W iff r >= 2.
    prep = m.op(w, x)
    _record_op()
    recv = _shift_up(prep, axis_name, 2, p)
    w = _masked_combine(m, _fixup_identity(m, recv, r >= 2), w, r >= 2)

    # Rounds k >= 2 (skip 3*2^(k-2)): plain doubling on W.
    return _doubling_phase(w, axis_name, m, r, p, oracle.skips_123(p)[2:])


@register_algorithm(
    "1doubling", kind="exclusive", rounds=oracle.rounds_1doubling,
    ops=_ops_1doubling)
def exscan_1doubling(x, axis_name: str, m: monoid_lib.Monoid):
    """Shift + straight doubling: 1 + ceil(log2(p-1)) rounds."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)

    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)
    return _doubling_phase(w, axis_name, m, r, p,
                           oracle.skips_1doubling(p)[1:])


@register_algorithm(
    "two_op", kind="exclusive", rounds=oracle.rounds_two_op,
    ops=_ops_two_op)
def exscan_two_op(x, axis_name: str, m: monoid_lib.Monoid):
    """Two-⊕ doubling: ceil(log2 p) rounds, two ⊕ per round after the first."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)

    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)

    k = 1
    while (1 << k) < p:
        s = 1 << k
        prep = m.op(w, x)  # W ⊕ V  (rank 0: identity ⊕ V = V)
        _record_op()
        recv = _shift_up(prep, axis_name, s, p)
        w = _masked_combine(m, _fixup_identity(m, recv, r >= s), w, r >= s)
        k += 1
    return w


@register_algorithm(
    "native", kind="exclusive", rounds=lambda p: 0,
    ops=lambda p: max(0, p - 1),
    allgathers=lambda p: 0 if p <= 1 else 1,
    latency_hops=lambda p: max(0, p - 1),  # ring all-gather on tori
    wire_bytes=lambda p, m: p * m if p > 1 else 0)
def exscan_native(x, axis_name: str, m: monoid_lib.Monoid):
    """Baseline: all-gather everyone's V, fold locally below own rank.

    One all-gather "round" but p·m bytes on the wire and p-1 local ⊕ —
    the standard library fallback the paper improves upon for small m.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)
    _record_allgather()
    gathered = jax.tree.map(
        lambda t: lax.all_gather(t, axis_name, axis=0), x
    )
    ident = m.identity_like(x)

    def body(i, acc):
        vi = jax.tree.map(lambda g: g[i], gathered)
        take = i < r
        combined = m.op(acc, vi)
        return jax.tree.map(
            lambda c, a: jnp.where(take, c, a), combined, acc
        )

    _record_op(p - 1)  # the fori_loop body executes p-1 times
    return lax.fori_loop(0, p - 1, body, ident)


@register_algorithm(
    "ring", kind="exclusive", rounds=lambda p: max(0, p - 1),
    ops=lambda p: max(0, p - 2),
    # serial_bytes prices the PIPELINED ring of the paper's large-m
    # citation (segments overlap the p-1 neighbour rounds -> ~2m on the
    # bandwidth critical path).  The SPMD program below is an
    # UNPIPELINED stand-in — full m bytes per round, (p-1)·m serialized
    # (= wire_bytes) — so treat "auto" picking ring as "a pipelined
    # fixed-degree algorithm belongs here"; see DESIGN.md §7 and the
    # ROADMAP item on payload-segmented rings.
    serial_bytes=lambda p, m: 2 * m if p > 1 else 0)
def exscan_ring(x, axis_name: str, m: monoid_lib.Monoid):
    """p-1 neighbour rounds; latency-poor but each round is 1 hop.

    Included as the pipelined/fixed-degree comparison point the paper
    cites for large m.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)
    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)
    acc = w  # running exclusive prefix
    carry = w  # value to forward (V_{r-1} partial chain)
    for step in range(1, p - 1):
        # Forward the chain: each round, rank r receives V_{r-step-1}'s
        # running partial and folds it in if still needed.
        recv = _shift_up(carry, axis_name, 1, p)
        recv = _fixup_identity(m, recv, r >= step + 1)
        acc = _masked_combine(m, recv, acc, r >= step + 1)
        carry = recv
    return acc


@register_algorithm(
    "hillis_steele", kind="inclusive", rounds=_rounds_inclusive,
    ops=_rounds_inclusive)
def _inclusive_hillis_steele(x, axis_name: str, m: monoid_lib.Monoid):
    """Hillis-Steele inclusive scan: ceil(log2 p) rounds, one ⊕ each."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    return _doubling_phase(x, axis_name, m, r, p,
                           oracle.skips_two_op(p), strict=False)


@register_algorithm(
    "butterfly", kind="allreduce", rounds=_rounds_butterfly,
    ops=_ops_butterfly, allgathers=_ag_butterfly)
def _allreduce_butterfly(x, axis_name: str, m: monoid_lib.Monoid):
    """Recursive-doubling (butterfly) all-reduce under an arbitrary monoid.

    ceil(log2 p) rounds.  For non-commutative monoids the butterfly
    exchange pattern preserves rank order within each combine (lower
    block always on the left).
    """
    p = _axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    w = x
    # For non-power-of-two p fall back to inclusive scan + broadcast of the
    # last rank's value (2*ceil(log2 p) rounds worst case, still log).
    if p & (p - 1):
        incl = _inclusive_hillis_steele(x, axis_name, m)
        # broadcast rank p-1's inclusive value to everyone
        _record_allgather()
        return jax.tree.map(
            lambda t: lax.all_gather(t, axis_name, axis=0)[p - 1], incl
        )
    k = 0
    while (1 << k) < p:
        s = 1 << k
        perm = [(i, i ^ s) for i in range(p)]
        _record_round(w)
        recv = jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm), w)
        low_side = (r & s) != 0  # partner is the lower block
        combined_lo = m.op(recv, w)  # partner low, self high
        combined_hi = m.op(w, recv)  # self low, partner high
        _record_op(2)
        w = jax.tree.map(
            lambda lo, hi: jnp.where(low_side, lo, hi),
            combined_lo,
            combined_hi,
        )
        k += 1
    return w


# ---------------------------------------------------------------------------
# Legacy string API — thin wrappers over scan_api (kept for
# backward compatibility; new code should build a ScanSpec and call
# scan_api.scan / scan_api.plan directly).
# ---------------------------------------------------------------------------

ALGORITHMS = scan_api.algorithms("exclusive")


def exscan(x, axis_name, m="add", algorithm: str = "123"):
    """Exclusive prefix scan along one or more named mesh axes.

    Compatibility wrapper: equivalent to
    ``scan(x, ScanSpec(kind="exclusive", monoid=m, algorithm=algorithm,
    axis_name=axis_name))``.

    Args:
      x: pytree of arrays (the per-rank input vector V_r).
      axis_name: a mesh axis name, or a tuple of axis names ordered
        major→minor (e.g. ``("pod", "data")``); ranks are taken in
        row-major order over the tuple, matching
        ``lax.axis_index(axes)`` ordering.
      m: a Monoid or registry name.
      algorithm: one of ``ALGORITHMS``, or ``"auto"`` for cost-model
        selection.

    Returns:
      The exclusive prefix ⊕_{i<r} V_i; rank 0 gets the identity.
    """
    return scan(x, ScanSpec(kind="exclusive", monoid=monoid_lib.get(m),
                            algorithm=algorithm, axis_name=axis_name))


def inclusive_scan(x, axis_name: str, m="add"):
    """Hillis-Steele inclusive scan: ceil(log2 p) rounds, one ⊕ each."""
    return scan(x, ScanSpec(kind="inclusive", monoid=monoid_lib.get(m),
                            algorithm="hillis_steele",
                            axis_name=axis_name))


def allreduce(x, axis_name: str, m="add"):
    """Butterfly all-reduce under an arbitrary monoid (rank-ordered)."""
    return scan(x, ScanSpec(kind="allreduce", monoid=monoid_lib.get(m),
                            algorithm="butterfly", axis_name=axis_name))


# ---------------------------------------------------------------------------
# Theory helpers re-exported for benchmarks
# ---------------------------------------------------------------------------

q_123 = oracle.q_123
rounds_1doubling = oracle.rounds_1doubling
rounds_two_op = oracle.rounds_two_op


def expected_rounds(algorithm: str, p: int) -> int:
    """ppermute rounds of an exclusive algorithm, from the registry.

    Legacy exception: ``"native"`` reports 1 (its single all-gather)
    rather than the registry's 0 ppermutes, preserving the historical
    convention of this helper.
    """
    if algorithm == "native":
        return 1  # one all-gather (but p·m bytes), zero ppermutes
    return scan_api.get_algorithm("exclusive", algorithm).rounds(p)
