"""SPMD prefix-scan collectives: algorithm registry behind ``scan_api``.

Since the schedule-IR redesign, every algorithm here is a *schedule
builder* (:mod:`repro.core.schedule`): it returns the explicit
round-by-round program — peer offsets, SPMD masks, combine directions —
that the SPMD ``ppermute`` executor traces under ``shard_map``, the
pure-numpy simulator runs at any p without devices, and the Pallas
executor lowers through the on-chip block-combine kernel.  The planner
counts its predicted rounds/⊕/all-gathers off the same IR, so
``ScanPlan`` predictions equal ``collect_stats()`` measurements by
construction.

The preferred entry point is the planner API::

    from repro.core.scan_api import ScanSpec, scan, plan

    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto")
    y = scan(x, spec.over("data"))        # planner picks the algorithm
    pl = plan(spec, p=256, nbytes=64)     # inspect the choice first
    print(pl.schedule().describe())       # round-by-round, no tracing

Registered exclusive-scan algorithms:

  * ``"123"``        — the paper's new 123-doubling algorithm
                       (Algorithm 1): q = ceil(log2(p-1)+log2(4/3))
                       rounds, q-1 result-path ⊕.
  * ``"1doubling"``  — shift + straight doubling: 1+ceil(log2(p-1))
                       rounds, ceil(log2(p-1)) ⊕.
  * ``"two_op"``     — two-⊕ doubling: ceil(log2 p) rounds,
                       2*ceil(log2 p)-1 ⊕.
  * ``"native"``     — all-gather + local fold (what a library would do
                       without the paper; XLA-native collective).
  * ``"ring"``       — the pipelined segmented neighbour ring the paper
                       cites for large m: p−2+S rounds of one m/S-byte
                       segment each (S=1: the plain p−1-round ring);
                       the planner picks S from the α/β trade-off.

The legacy string API (``exscan``/``inclusive_scan``/``allreduce``) is
kept as deprecated wrappers over ``scan_api`` — they emit
``DeprecationWarning`` pointing at :class:`ScanSpec`.

All execution must happen inside ``shard_map`` (or any context where
``axis_name`` is bound).  Inputs may be arbitrary pytrees; the monoid
operates on the whole tree.
"""

from __future__ import annotations

import warnings

from repro.core import monoid as monoid_lib
from repro.core import oracle
from repro.core import scan_api
from repro.core import schedule as schedule_lib
from repro.core.scan_api import ScanSpec, register_algorithm, scan

# Trace/execution-time instrumentation lives with the executors in
# core/schedule.py; re-exported here because this module has always
# been its public home (``collectives.collect_stats()``).
CollectiveStats = schedule_lib.CollectiveStats
collect_stats = schedule_lib.collect_stats
_record_op = schedule_lib._record_op
_record_round = schedule_lib._record_round
_record_allgather = schedule_lib._record_allgather


# ---------------------------------------------------------------------------
# Algorithm registry: schedule builders + their kinds.  The builders —
# and the executors that run them — live in core/schedule.py; this
# module binds them to the planner.
# ---------------------------------------------------------------------------

register_algorithm("123", kind="exclusive")(schedule_lib.build_123)
register_algorithm("1doubling",
                   kind="exclusive")(schedule_lib.build_1doubling)
register_algorithm("two_op", kind="exclusive")(schedule_lib.build_two_op)
register_algorithm("native", kind="exclusive")(schedule_lib.build_native)
register_algorithm("ring", kind="exclusive",
                   segmentable=True)(schedule_lib.build_ring)
# Block-distributed exscan family (mid-m band): vector-halving /
# quartering (Träff-2026 exclusive-scan variants) and the full
# reduce-scatter-depth exscan (Rabenseifner-style halving/doubling:
# ~2·(p−1)/p·m wire bytes in 2⌈log₂p⌉ rounds).  They split payload
# leaves into row blocks, so the monoid must be segmentable.
register_algorithm("halving", kind="exclusive",
                   requires_segmentable=True)(schedule_lib.build_halving)
register_algorithm(
    "quartering", kind="exclusive",
    requires_segmentable=True)(schedule_lib.build_quartering)
register_algorithm(
    "reduce_scatter", kind="exclusive",
    requires_segmentable=True)(schedule_lib.build_reduce_scatter)
register_algorithm("hillis_steele",
                   kind="inclusive")(schedule_lib.build_hillis_steele)
register_algorithm("butterfly",
                   kind="allreduce")(schedule_lib.build_butterfly)

# "scan_total": exclusive scan + allreduce of the same payload fused
# into ONE schedule (outputs (prefix, total)).  Every exclusive
# algorithm registers a with_total variant under its own name, so
# pinned specs keep comparing like for like; "fused_doubling" is the
# round-optimal fused butterfly (both results in ⌈log₂p⌉ rounds at
# power-of-two p) that "auto" picks in the small-m regime.


def _total_variant(base_build):
    def build(p, segments=None):
        sched = (base_build(p, segments) if segments is not None
                 else base_build(p))
        return schedule_lib.with_total(sched)

    return build


register_algorithm("123", kind="scan_total")(
    _total_variant(schedule_lib.build_123))
register_algorithm("1doubling", kind="scan_total")(
    _total_variant(schedule_lib.build_1doubling))
register_algorithm("two_op", kind="scan_total")(
    _total_variant(schedule_lib.build_two_op))
register_algorithm("native", kind="scan_total")(
    _total_variant(schedule_lib.build_native))
register_algorithm("ring", kind="scan_total", segmentable=True)(
    _total_variant(schedule_lib.build_ring))
register_algorithm("halving", kind="scan_total",
                   requires_segmentable=True)(
    _total_variant(schedule_lib.build_halving))
register_algorithm("quartering", kind="scan_total",
                   requires_segmentable=True)(
    _total_variant(schedule_lib.build_quartering))
register_algorithm("reduce_scatter", kind="scan_total",
                   requires_segmentable=True)(
    _total_variant(schedule_lib.build_reduce_scatter))
register_algorithm("fused_doubling",
                   kind="scan_total")(schedule_lib.build_scan_total)


# ---------------------------------------------------------------------------
# Legacy string API — deprecated wrappers over scan_api (kept for
# backward compatibility; new code should build a ScanSpec and call
# scan_api.scan / scan_api.plan directly).
# ---------------------------------------------------------------------------

ALGORITHMS = scan_api.algorithms("exclusive")


def _deprecated(name: str):
    warnings.warn(
        f"collectives.{name}() is deprecated; build a "
        f"scan_api.ScanSpec and call scan_api.scan(x, spec) instead",
        DeprecationWarning, stacklevel=3)


def exscan(x, axis_name, m="add", algorithm: str = "123"):
    """DEPRECATED: exclusive prefix scan along named mesh axes.

    Equivalent to ``scan(x, ScanSpec(kind="exclusive", monoid=m,
    algorithm=algorithm, axis_name=axis_name))`` — build the
    :class:`ScanSpec` yourself; this wrapper emits a
    ``DeprecationWarning``.

    Args:
      x: pytree of arrays (the per-rank input vector V_r).
      axis_name: a mesh axis name, or a tuple of axis names ordered
        major→minor (e.g. ``("pod", "data")``); ranks are taken in
        row-major order over the tuple, matching
        ``lax.axis_index(axes)`` ordering.
      m: a Monoid or registry name.
      algorithm: one of ``ALGORITHMS``, or ``"auto"`` for cost-model
        selection.

    Returns:
      The exclusive prefix ⊕_{i<r} V_i; rank 0 gets the identity.
    """
    _deprecated("exscan")
    return scan(x, ScanSpec(kind="exclusive", monoid=monoid_lib.get(m),
                            algorithm=algorithm, axis_name=axis_name))


def inclusive_scan(x, axis_name: str, m="add"):
    """DEPRECATED: Hillis-Steele inclusive scan (use a ScanSpec)."""
    _deprecated("inclusive_scan")
    return scan(x, ScanSpec(kind="inclusive", monoid=monoid_lib.get(m),
                            algorithm="hillis_steele",
                            axis_name=axis_name))


def allreduce(x, axis_name: str, m="add"):
    """DEPRECATED: butterfly all-reduce (use a ScanSpec)."""
    _deprecated("allreduce")
    return scan(x, ScanSpec(kind="allreduce", monoid=monoid_lib.get(m),
                            algorithm="butterfly", axis_name=axis_name))


# ---------------------------------------------------------------------------
# Theory helpers re-exported for benchmarks
# ---------------------------------------------------------------------------

q_123 = oracle.q_123
rounds_1doubling = oracle.rounds_1doubling
rounds_two_op = oracle.rounds_two_op
rounds_halving = oracle.rounds_halving
rounds_quartering = oracle.rounds_quartering
rounds_reduce_scatter = oracle.rounds_reduce_scatter


def expected_rounds(algorithm: str, p: int, *,
                    kind: str = "exclusive", segments: int = 1) -> int:
    """ppermute rounds of a registered algorithm, derived from its
    schedule builder — NOT a hand-maintained table, so it can never
    disagree with the IR the executors run (a drift test pins it to
    the closed-form oracle counts as well).

    Legacy exception: exclusive ``"native"`` reports 1 (its single
    all-gather) rather than the schedule's 0 ppermutes, preserving the
    historical convention of this helper.
    """
    if kind == "exclusive" and algorithm == "native":
        return 1  # one all-gather (but p·m bytes), zero ppermutes
    return scan_api.get_algorithm(kind, algorithm).schedule(
        p, segments).rounds


def expected_ops(algorithm: str, p: int, *, kind: str = "exclusive",
                 segments: int = 1, commutative: bool = False) -> int:
    """⊕ executions per device of a registered algorithm, derived
    from its schedule (``Schedule.op_count``), honouring the
    commutative-monoid elision in butterfly/scan_reduce rounds."""
    return scan_api.get_algorithm(kind, algorithm).schedule(
        p, segments).op_count(commutative)
