"""SPMD exclusive/inclusive prefix-scan collectives for TPU meshes.

This is the paper's contribution adapted to JAX: each simultaneous
send-receive communication round becomes one ``lax.ppermute`` along a
named mesh axis (every device sends and receives at most one message per
round — the paper's one-ported model).  Edge ranks, which in the MPI
formulation conditionally skip sends/receives, are handled uniformly in
SPMD via the monoid identity and masked combines; the masks are exactly
the paper's loop conditions (``0 < f``, ``t < p``).

Algorithms (selectable, all returning the exclusive prefix under a
:class:`repro.core.monoid.Monoid`; rank 0 receives the identity):

  * ``"123"``        — the paper's new 123-doubling algorithm
                       (Algorithm 1): q = ceil(log2(p-1)+log2(4/3))
                       rounds, q-1 result-path ⊕.
  * ``"1doubling"``  — shift + straight doubling: 1+ceil(log2(p-1))
                       rounds, ceil(log2(p-1)) ⊕.
  * ``"two_op"``     — two-⊕ doubling: ceil(log2 p) rounds,
                       2*ceil(log2 p)-1 ⊕.
  * ``"native"``     — all-gather + local fold (what a library would do
                       without the paper; XLA-native collective).
  * ``"ring"``       — p-1 neighbour rounds (bandwidth-optimal pipelined
                       baseline for large m; see DESIGN.md).

All functions must be called inside ``shard_map`` (or any context where
``axis_name`` is bound).  Inputs may be arbitrary pytrees; the monoid
operates on the whole tree.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import monoid as monoid_lib
from repro.core import oracle


# ---------------------------------------------------------------------------
# Trace-time instrumentation: counts ppermute rounds and ⊕ applications so
# tests and benchmarks can assert the paper's costs on the actual
# implementation (not just the numpy oracle).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveStats:
    rounds: int = 0  # ppermute calls (communication rounds)
    op_applications: int = 0  # ⊕ applications per device (SPMD)
    allgathers: int = 0
    bytes_per_round: list = dataclasses.field(default_factory=list)


_tls = threading.local()


@contextlib.contextmanager
def collect_stats():
    """Context manager capturing round/op counts of scans traced inside."""
    stats = CollectiveStats()
    prev = getattr(_tls, "stats", None)
    _tls.stats = stats
    try:
        yield stats
    finally:
        _tls.stats = prev


def _stats() -> CollectiveStats | None:
    return getattr(_tls, "stats", None)


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _record_round(tree):
    s = _stats()
    if s is not None:
        s.rounds += 1
        s.bytes_per_round.append(_nbytes(tree))


def _record_op():
    s = _stats()
    if s is not None:
        s.op_applications += 1


def _record_allgather():
    s = _stats()
    if s is not None:
        s.allgathers += 1


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _axis_size(axis_name) -> int:
    return lax.axis_size(axis_name)


def _shift_up(tree, axis_name: str, skip: int, p: int):
    """One communication round: rank r sends to r+skip (where r+skip < p).

    Non-receiving ranks get zero-fill from ppermute; callers mask.
    """
    perm = [(r, r + skip) for r in range(p - skip)]
    _record_round(tree)
    return jax.tree.map(lambda x: lax.ppermute(x, axis_name, perm), tree)


def _masked_combine(m: monoid_lib.Monoid, recv, w, mask):
    """W <- recv ⊕ W where mask, else W (recv covers lower ranks)."""
    combined = m.op(recv, w)
    _record_op()
    return jax.tree.map(
        lambda c, x: jnp.where(mask, c, x), combined, w
    )


def _fixup_identity(m: monoid_lib.Monoid, recv, has_src):
    """Replace zero-fill from ppermute with the monoid identity."""
    ident = m.identity_like(recv)
    return jax.tree.map(
        lambda t, i: jnp.where(has_src, t, i), recv, ident
    )


# ---------------------------------------------------------------------------
# The paper's algorithms
# ---------------------------------------------------------------------------


def exscan_123(x, axis_name: str, m: monoid_lib.Monoid):
    """Algorithm 1 (123-doubling) as q ppermute rounds.

    Skip schedule s_0=1, s_1=2, s_k=3*2^(k-2).  Masks mirror the paper's
    conditions: round-0 receive iff r>=1, round-1 combine iff r>=2,
    round-k combine iff r - s_k > 0 (rank complete once its window
    bottoms out at 0 — the paper's ``while 0 < f``).
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)

    # Round 0 (skip 1): W = V_{r-1}; rank 0 holds the identity.
    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)
    if p == 2:
        return w

    # Round 1 (skip 2): send W ⊕ V (rank 0's W is the identity, so it
    # sends plain V exactly as in Algorithm 1); combine T ⊕ W iff r >= 2.
    prep = m.op(w, x)
    _record_op()
    recv = _shift_up(prep, axis_name, 2, p)
    w = _masked_combine(m, _fixup_identity(m, recv, r >= 2), w, r >= 2)

    # Rounds k >= 2 (skip 3*2^(k-2)): plain doubling on W.
    k = 2
    while True:
        s = 3 * (1 << (k - 2))
        if s >= p - 1:
            break
        recv = _shift_up(w, axis_name, s, p)
        w = _masked_combine(m, _fixup_identity(m, recv, r > s), w, r > s)
        k += 1
    return w


def exscan_1doubling(x, axis_name: str, m: monoid_lib.Monoid):
    """Shift + straight doubling: 1 + ceil(log2(p-1)) rounds."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)

    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)

    k = 1
    while True:
        s = 1 << (k - 1)
        if s >= p - 1:
            break
        recv = _shift_up(w, axis_name, s, p)
        w = _masked_combine(m, _fixup_identity(m, recv, r > s), w, r > s)
        k += 1
    return w


def exscan_two_op(x, axis_name: str, m: monoid_lib.Monoid):
    """Two-⊕ doubling: ceil(log2 p) rounds, two ⊕ per round after the first."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)

    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)

    k = 1
    while (1 << k) < p:
        s = 1 << k
        prep = m.op(w, x)  # W ⊕ V  (rank 0: identity ⊕ V = V)
        _record_op()
        recv = _shift_up(prep, axis_name, s, p)
        w = _masked_combine(m, _fixup_identity(m, recv, r >= s), w, r >= s)
        k += 1
    return w


def exscan_native(x, axis_name: str, m: monoid_lib.Monoid):
    """Baseline: all-gather everyone's V, fold locally below own rank.

    One all-gather "round" but p·m bytes on the wire and p-1 local ⊕ —
    the standard library fallback the paper improves upon for small m.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)
    _record_allgather()
    gathered = jax.tree.map(
        lambda t: lax.all_gather(t, axis_name, axis=0), x
    )
    ident = m.identity_like(x)

    def body(i, acc):
        vi = jax.tree.map(lambda g: g[i], gathered)
        take = i < r
        combined = m.op(acc, vi)
        return jax.tree.map(
            lambda c, a: jnp.where(take, c, a), combined, acc
        )

    return lax.fori_loop(0, p - 1, body, ident)


def exscan_ring(x, axis_name: str, m: monoid_lib.Monoid):
    """p-1 neighbour rounds; latency-poor but each round is 1 hop.

    Included as the pipelined/fixed-degree comparison point the paper
    cites for large m.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if p == 1:
        return m.identity_like(x)
    recv = _shift_up(x, axis_name, 1, p)
    w = _fixup_identity(m, recv, r >= 1)
    acc = w  # running exclusive prefix
    carry = w  # value to forward (V_{r-1} partial chain)
    for step in range(1, p - 1):
        # Forward the chain: each round, rank r receives V_{r-step-1}'s
        # running partial and folds it in if still needed.
        recv = _shift_up(carry, axis_name, 1, p)
        recv = _fixup_identity(m, recv, r >= step + 1)
        acc = _masked_combine(m, recv, acc, r >= step + 1)
        carry = recv
    return acc


_ALGORITHMS = {
    "123": exscan_123,
    "1doubling": exscan_1doubling,
    "two_op": exscan_two_op,
    "native": exscan_native,
    "ring": exscan_ring,
}

ALGORITHMS = tuple(_ALGORITHMS)


def exscan(x, axis_name, m="add", algorithm: str = "123"):
    """Exclusive prefix scan along one or more named mesh axes.

    Args:
      x: pytree of arrays (the per-rank input vector V_r).
      axis_name: a mesh axis name, or a tuple of axis names ordered
        major→minor (e.g. ``("pod", "data")``); ranks are taken in
        row-major order over the tuple, matching
        ``lax.axis_index(axes)`` ordering.
      m: a Monoid or registry name.
      algorithm: one of ``ALGORITHMS``.

    Returns:
      The exclusive prefix ⊕_{i<r} V_i; rank 0 gets the identity.
    """
    m = monoid_lib.get(m)
    if algorithm not in _ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; known: {sorted(_ALGORITHMS)}"
        )
    fn = _ALGORITHMS[algorithm]
    if isinstance(axis_name, (tuple, list)):
        axes = tuple(axis_name)
        if len(axes) == 1:
            return fn(x, axes[0], m)
        # Two-level composition: exscan within the minor axis, plus the
        # exclusive prefix over major-axis *totals* (see DESIGN.md §5).
        minor = axes[-1]
        inner = fn(x, minor, m)
        total = allreduce(x, minor, m)  # ⊕ of the whole minor group
        outer = exscan(total, axes[:-1], m, algorithm)
        combined = m.op(outer, inner)
        _record_op()
        return combined
    return fn(x, axis_name, m)


def inclusive_scan(x, axis_name: str, m="add"):
    """Hillis-Steele inclusive scan: ceil(log2 p) rounds, one ⊕ each."""
    m = monoid_lib.get(m)
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    w = x
    k = 0
    while (1 << k) < p:
        s = 1 << k
        recv = _shift_up(w, axis_name, s, p)
        w = _masked_combine(m, _fixup_identity(m, recv, r >= s), w, r >= s)
        k += 1
    return w


def allreduce(x, axis_name: str, m="add"):
    """Recursive-doubling (butterfly) all-reduce under an arbitrary monoid.

    ceil(log2 p) rounds.  For non-commutative monoids the butterfly
    exchange pattern preserves rank order within each combine (lower
    block always on the left).
    """
    m = monoid_lib.get(m)
    p = _axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    w = x
    # For non-power-of-two p fall back to inclusive scan + broadcast of the
    # last rank's value (2*ceil(log2 p) rounds worst case, still log).
    if p & (p - 1):
        incl = inclusive_scan(x, axis_name, m)
        # broadcast rank p-1's inclusive value to everyone
        _record_allgather()
        return jax.tree.map(
            lambda t: lax.all_gather(t, axis_name, axis=0)[p - 1], incl
        )
    k = 0
    while (1 << k) < p:
        s = 1 << k
        partner = r ^ s
        perm = [(i, i ^ s) for i in range(p)]
        _record_round(w)
        recv = jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm), w)
        low_side = (r & s) != 0  # partner is the lower block
        combined_lo = m.op(recv, w)  # partner low, self high
        combined_hi = m.op(w, recv)  # self low, partner high
        _record_op()
        _record_op()
        w = jax.tree.map(
            lambda lo, hi: jnp.where(low_side, lo, hi),
            combined_lo,
            combined_hi,
        )
        k += 1
    return w


# ---------------------------------------------------------------------------
# Theory helpers re-exported for benchmarks
# ---------------------------------------------------------------------------

q_123 = oracle.q_123
rounds_1doubling = oracle.rounds_1doubling
rounds_two_op = oracle.rounds_two_op


def expected_rounds(algorithm: str, p: int) -> int:
    if algorithm == "123":
        return oracle.q_123(p)
    if algorithm == "1doubling":
        return oracle.rounds_1doubling(p)
    if algorithm == "two_op":
        return oracle.rounds_two_op(p)
    if algorithm == "ring":
        return max(0, p - 1)
    if algorithm == "native":
        return 1  # one all-gather (but p·m bytes)
    raise ValueError(algorithm)
