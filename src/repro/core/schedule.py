"""Executable schedule IR for scan plans (DESIGN.md §7).

A :class:`Schedule` is the explicit, inspectable program of a scan
algorithm: a sequence of :class:`RoundStep`s — peer offsets for the
``ppermute`` of each simultaneous send-receive round, SPMD receive
masks, the ⊕ combine direction, identity fixups — over per-rank payload
:class:`Segment`s.  Registered algorithms *build* schedules
(``build_123`` …), the planner derives its predicted round/⊕/all-gather
counts by counting the IR, and three executors run the same schedule:

  * :class:`SPMDExecutor` — one ``lax.ppermute`` per round inside
    ``shard_map`` (what ``scan_api.scan`` runs on a mesh);
  * :class:`SimulatorExecutor` — pure-numpy, rank-by-rank lockstep
    execution at any p with no devices (dry-run plan verification,
    benchmark drift checks, property tests);
  * :class:`PallasExecutor` — the SPMD executor with the per-round ⊕
    combine hook lowered through the on-chip Pallas block-combine
    kernel (``kernels/blelloch_exscan.block_combine``).

Because the planner's counts and the executors consume the *same* IR,
``ScanPlan`` predictions equal ``collect_stats()`` measurements by
construction — the IR is the single source of truth for what runs.

Payload segmentation is a schedule transform: :func:`segment` turns the
p−1-round neighbour ring into the paper's pipelined fixed-degree
algorithm — each leaf is flattened and split into S contiguous element
blocks and the per-segment running prefixes streamed through p−2+S
neighbour rounds, so each round carries m/S bytes
(~(1 + (p−2)/S)·m serialized instead of (p−1)·m).

Byte prediction note: the plan's ``bytes_on_wire`` for a segmented
schedule is ``rounds · ceil(m/S)``; the traced program zero-pads each
flattened leaf up to a multiple of S, so prediction and measurement
agree exactly when S divides every leaf's element count (the planner
only considers power-of-two S, which also keeps the padding bounded).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import monoid as monoid_lib
from repro.core import oracle


# ---------------------------------------------------------------------------
# Trace/execution-time instrumentation.  Both the SPMD executor (at trace
# time) and the numpy simulator (at execution time) record rounds, ⊕
# applications and all-gathers here, so tests and benchmarks can assert
# the planner's predicted costs on the program that actually runs.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveStats:
    rounds: int = 0  # ppermute calls (communication rounds)
    op_applications: int = 0  # ⊕ applications per device (SPMD lockstep)
    allgathers: int = 0
    bytes_per_round: list = dataclasses.field(default_factory=list)


_tls = threading.local()


@contextlib.contextmanager
def collect_stats():
    """Context manager capturing round/op counts of scans traced (SPMD)
    or executed (simulator) inside."""
    stats = CollectiveStats()
    prev = getattr(_tls, "stats", None)
    _tls.stats = stats
    try:
        yield stats
    finally:
        _tls.stats = prev


def _stats() -> CollectiveStats | None:
    return getattr(_tls, "stats", None)


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _record_round(tree):
    s = _stats()
    if s is not None:
        s.rounds += 1
        s.bytes_per_round.append(_nbytes(tree))


def _record_op(n: int = 1):
    """Count n ⊕ *executions* (a traced-once loop body records its trip
    count, so stats mean executions, not trace sites)."""
    s = _stats()
    if s is not None:
        s.op_applications += n


def _record_allgather():
    s = _stats()
    if s is not None:
        s.allgathers += 1


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    """One of S contiguous blocks of the flattened per-rank payload.

    Each leaf is flattened and zero-padded to a multiple of ``count``;
    block ``index`` holds elements [index·k, (index+1)·k) with
    k = ceil(size/count).  ⊕ must combine aligned element blocks
    independently for this to be sound (``Monoid.segmentable``)."""

    index: int
    count: int


@dataclasses.dataclass(frozen=True)
class RoundStep:
    """One round of a schedule.

    kind:
      "shift"     — ppermute r → r+skip; masked receive; combine.
      "seg_shift" — pipelined-ring round ``t``: neighbour ppermute of
                    one payload segment; rank r stores received segment
                    s = t+1−r (when 0 ≤ s < S) as its result and, if
                    ``prep``, forwards recv ⊕ V[s] next round (1 ⊕).
      "exchange"  — butterfly ppermute r ↔ r^skip; two order-preserving
                    combines selected by the rank's side bit.
      "allgather" — XLA-native all-gather of the input V.
      "fold"      — local left-fold of the gathered values below own
                    rank (``fold_count`` ⊕ executions).
      "bcast"     — broadcast rank ``root``'s value (via all-gather).

    send (shift only): "x" the input V, "w" the accumulator,
      "w_op_x" the prepared W ⊕ V (counts one ⊕).
    mask/bound (shift only): receive participation — "ge": r ≥ bound,
      "gt": r > bound.  Non-participants keep W (identity fixup).
    combine (shift only): "copy" W ← recv, or "op" W ← recv ⊕ W (the
      recv side always covers lower ranks — non-commutative safe).
    """

    kind: str
    skip: int = 0
    send: str = "w"
    mask: str = "ge"
    bound: int = 0
    combine: str = "none"
    t: int = -1  # seg_shift round index
    prep: bool = False  # seg_shift: forward-prep ⊕ this round
    fold_count: int = 0  # fold: ⊕ executions
    root: int = 0  # bcast source rank

    @property
    def is_round(self) -> bool:
        """Does this step cost one ppermute communication round?"""
        return self.kind in ("shift", "seg_shift", "exchange")

    @property
    def ops(self) -> int:
        """⊕ executions per device (SPMD lockstep) for this step."""
        n = 0
        if self.kind == "shift":
            n += 1 if self.send == "w_op_x" else 0
            n += 1 if self.combine == "op" else 0
        elif self.kind == "seg_shift":
            n += 1 if self.prep else 0
        elif self.kind == "exchange":
            n += 2
        elif self.kind == "fold":
            n += self.fold_count
        return n

    def describe(self) -> str:
        if self.kind == "shift":
            send = {"x": "V", "w": "W", "w_op_x": "W⊕V"}[self.send]
            cmp_ = {"ge": ">=", "gt": ">"}[self.mask]
            comb = "W←recv" if self.combine == "copy" else "W←recv⊕W"
            return (f"shift +{self.skip:<4d} send={send:<4s} "
                    f"recv r{cmp_}{self.bound}  {comb}")
        if self.kind == "seg_shift":
            tail = "; send←recv⊕V[s]" if self.prep else "  (drain)"
            return f"ring  t={self.t:<3d} seg s=t+1−r  W[s]←recv{tail}"
        if self.kind == "exchange":
            return f"xchg  r↔r^{self.skip}  W←ordered(recv,W)"
        if self.kind == "allgather":
            return "all-gather V"
        if self.kind == "fold":
            return f"local fold of {self.fold_count + 1} gathered values"
        if self.kind == "bcast":
            return f"broadcast rank {self.root} (all-gather)"
        return self.kind


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An executable scan program: init state + ordered RoundSteps."""

    algorithm: str
    kind: str  # "exclusive" | "inclusive" | "allreduce"
    p: int
    init: str = "identity"  # initial accumulator W: "identity" | "x"
    segments: tuple[Segment, ...] = (Segment(0, 1),)
    steps: tuple[RoundStep, ...] = ()

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def rounds(self) -> int:
        return sum(1 for s in self.steps if s.is_round)

    @property
    def op_applications(self) -> int:
        return sum(s.ops for s in self.steps)

    @property
    def allgathers(self) -> int:
        return sum(1 for s in self.steps
                   if s.kind in ("allgather", "bcast"))

    def describe(self) -> str:
        """Round-by-round human-readable listing (no tracing needed)."""
        head = (f"{self.kind} [{self.algorithm}] p={self.p} "
                f"S={self.n_segments} rounds={self.rounds} "
                f"⊕={self.op_applications} "
                f"allgathers={self.allgathers} (W₀={self.init})")
        lines = [head]
        rnd = 0
        for st in self.steps:
            tag = f"r{rnd}" if st.is_round else "--"
            rnd += 1 if st.is_round else 0
            lines.append(f"  {tag:>4s}: {st.describe()}")
        return "\n".join(lines)


def _segs(S: int) -> tuple[Segment, ...]:
    return tuple(Segment(i, S) for i in range(S))


# ---------------------------------------------------------------------------
# Builders: one per registered algorithm.  The planner counts rounds/⊕/
# all-gathers off these schedules, so by construction plans predict what
# the executors measure.
# ---------------------------------------------------------------------------


def build_123(p: int) -> Schedule:
    """Algorithm 1 (123-doubling): skip schedule 1, 2, 3·2^(k−2);
    q = ⌈log₂(p−1)+log₂(4/3)⌉ rounds, q−1 result-path ⊕."""
    steps: list[RoundStep] = []
    if p >= 2:
        steps.append(RoundStep("shift", skip=1, send="x", mask="ge",
                               bound=1, combine="copy"))
    if p >= 3:
        # Round 1 (skip 2): send W ⊕ V (rank 0's W is the identity, so
        # it sends plain V exactly as in the paper); combine iff r >= 2.
        steps.append(RoundStep("shift", skip=2, send="w_op_x", mask="ge",
                               bound=2, combine="op"))
        for s in oracle.skips_123(p)[2:]:
            # rank complete once its window bottoms out (paper: 0 < f)
            steps.append(RoundStep("shift", skip=s, send="w", mask="gt",
                                   bound=s, combine="op"))
    return Schedule("123", "exclusive", p, steps=tuple(steps))


def build_1doubling(p: int) -> Schedule:
    """Shift + straight doubling: 1 + ⌈log₂(p−1)⌉ rounds."""
    steps: list[RoundStep] = []
    if p >= 2:
        steps.append(RoundStep("shift", skip=1, send="x", mask="ge",
                               bound=1, combine="copy"))
        for s in oracle.skips_1doubling(p)[1:]:
            steps.append(RoundStep("shift", skip=s, send="w", mask="gt",
                                   bound=s, combine="op"))
    return Schedule("1doubling", "exclusive", p, steps=tuple(steps))


def build_two_op(p: int) -> Schedule:
    """Two-⊕ doubling: ⌈log₂ p⌉ rounds, two ⊕ per round after the first."""
    steps: list[RoundStep] = []
    if p >= 2:
        steps.append(RoundStep("shift", skip=1, send="x", mask="ge",
                               bound=1, combine="copy"))
        k = 1
        while (1 << k) < p:
            s = 1 << k
            steps.append(RoundStep("shift", skip=s, send="w_op_x",
                                   mask="ge", bound=s, combine="op"))
            k += 1
    return Schedule("two_op", "exclusive", p, steps=tuple(steps))


def build_native(p: int) -> Schedule:
    """Library baseline: all-gather everyone's V, fold locally below own
    rank — zero ppermutes but p·m wire bytes and p−1 local ⊕."""
    steps: tuple[RoundStep, ...] = ()
    if p >= 2:
        steps = (RoundStep("allgather"),
                 RoundStep("fold", fold_count=p - 1))
    return Schedule("native", "exclusive", p, steps=steps)


def build_ring(p: int, segments: int = 1) -> Schedule:
    """Pipelined segmented neighbour ring: p−2+S rounds of one
    m/S-byte segment each (S=1: the plain p−1-round ring).

    Round t: rank r receives segment s = t+1−r (its exclusive prefix
    for that block, complete on arrival) and forwards recv ⊕ V[s] —
    one ⊕ per non-final round, p−3+S total."""
    S = max(1, int(segments))
    if p <= 1:
        return Schedule("ring", "exclusive", p, segments=_segs(S))
    n = p - 2 + S
    steps = tuple(RoundStep("seg_shift", skip=1, t=t, prep=(t < n - 1))
                  for t in range(n))
    return Schedule("ring", "exclusive", p, segments=_segs(S),
                    steps=steps)


def build_hillis_steele(p: int) -> Schedule:
    """Hillis-Steele inclusive scan: ⌈log₂ p⌉ rounds, one ⊕ each."""
    steps = tuple(RoundStep("shift", skip=s, send="w", mask="ge",
                            bound=s, combine="op")
                  for s in oracle.skips_two_op(p))
    return Schedule("hillis_steele", "inclusive", p, init="x",
                    steps=steps)


def build_butterfly(p: int) -> Schedule:
    """Recursive-doubling all-reduce: ⌈log₂ p⌉ exchange rounds for
    power-of-two p; otherwise inclusive scan + broadcast of the last
    rank (order-preserving for non-commutative monoids)."""
    if p <= 1:
        return Schedule("butterfly", "allreduce", p, init="x")
    if p & (p - 1):  # non-power-of-two
        incl = build_hillis_steele(p)
        steps = incl.steps + (RoundStep("bcast", root=p - 1),)
        return Schedule("butterfly", "allreduce", p, init="x",
                        steps=steps)
    steps = []
    k = 0
    while (1 << k) < p:
        steps.append(RoundStep("exchange", skip=1 << k))
        k += 1
    return Schedule("butterfly", "allreduce", p, init="x",
                    steps=tuple(steps))


def segment(schedule: Schedule, S: int) -> Schedule:
    """The segmentation transform: split the payload into S row-blocks
    and stream them through p−2+S neighbour rounds.

    Only schedules made of neighbour rounds (the ring) pipeline this
    way; doubling schedules have data dependencies across non-neighbour
    peers and raise (including their trivially-empty p <= 1 forms)."""
    if schedule.algorithm != "ring" or not all(
            s.kind == "seg_shift" for s in schedule.steps):
        raise ValueError(
            f"only neighbour-ring schedules are segmentable, "
            f"not {schedule.algorithm!r}")
    return build_ring(schedule.p, S)


# ---------------------------------------------------------------------------
# Payload segmentation helpers: each leaf is flattened and split into S
# contiguous element blocks (sound for monoids whose ⊕ combines aligned
# element positions independently — ``Monoid.segmentable``).
# ---------------------------------------------------------------------------


def _jnp_split(a, S: int):
    """Any shape -> (S, ceil(size/S)), flattened and zero-padded."""
    a = jnp.asarray(a).reshape(-1)
    n = a.shape[0]
    k = -(-n // S)
    pad = S * k - n
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,), a.dtype)])
    return a.reshape(S, k)


def _jnp_unsplit(seg, like):
    n = like.size
    return seg.reshape(-1)[:n].reshape(like.shape)


def _np_split(a, S: int):
    a = np.asarray(a).reshape(-1)
    n = a.shape[0]
    k = -(-n // S)
    pad = S * k - n
    if pad:
        a = np.concatenate([a, np.zeros((pad,), a.dtype)])
    return a.reshape(S, k)


def _np_unsplit(seg, like):
    like = np.asarray(like)
    return np.asarray(seg).reshape(-1)[:like.size].reshape(like.shape)


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


class Executor:
    """One interface, three backends: ``execute(schedule, x, monoid)``.

    ``combine`` is the RoundStep ⊕ hook — subclasses may lower it onto
    different compute substrates (the Pallas executor runs it through
    the on-chip block-combine kernel)."""

    def combine(self, m: monoid_lib.Monoid, lo, hi):
        """⊕ with ``lo`` covering the lower ranks."""
        return m.op(lo, hi)

    def execute(self, schedule: Schedule, x, m: monoid_lib.Monoid):
        raise NotImplementedError


def _shift_up(tree, axis_name, skip: int, p: int):
    """One communication round: rank r sends to r+skip (r+skip < p).

    Non-receiving ranks get zero-fill from ppermute; callers mask."""
    perm = [(r, r + skip) for r in range(p - skip)]
    _record_round(tree)
    return jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm), tree)


def _fixup_identity(m: monoid_lib.Monoid, recv, has_src):
    """Replace zero-fill from ppermute with the monoid identity."""
    ident = m.identity_like(recv)
    return jax.tree.map(
        lambda t, i: jnp.where(has_src, t, i), recv, ident)


class SPMDExecutor(Executor):
    """Executes a schedule as the SPMD ppermute program of its rounds.

    Must run where ``axis_name`` is bound (inside ``shard_map``).  MPI
    rank conditionals become the schedule's receive masks: a rank with
    no source "receives" the monoid identity, making the combine a
    no-op (DESIGN.md §2)."""

    def __init__(self, axis_name):
        self.axis_name = axis_name

    def execute(self, sched: Schedule, x, m: monoid_lib.Monoid):
        axis = self.axis_name
        p = sched.p
        r = lax.axis_index(axis)
        if any(st.kind == "seg_shift" for st in sched.steps):
            return self._execute_segmented(sched, x, m, axis, p, r)
        w = x if sched.init == "x" else m.identity_like(x)
        gathered = None
        for st in sched.steps:
            if st.kind == "shift":
                if st.send == "x":
                    src = x
                elif st.send == "w":
                    src = w
                else:  # "w_op_x": rank 0's W is identity -> sends V
                    src = self.combine(m, w, x)
                    _record_op()
                recv = _shift_up(src, axis, st.skip, p)
                has = (r >= st.bound) if st.mask == "ge" else \
                    (r > st.bound)
                recv = _fixup_identity(m, recv, has)
                if st.combine == "op":
                    combined = self.combine(m, recv, w)
                    _record_op()
                    w = jax.tree.map(
                        lambda c, v: jnp.where(has, c, v), combined, w)
                else:  # "copy"
                    w = jax.tree.map(
                        lambda c, v: jnp.where(has, c, v), recv, w)
            elif st.kind == "exchange":
                perm = [(i, i ^ st.skip) for i in range(p)]
                _record_round(w)
                recv = jax.tree.map(
                    lambda t: lax.ppermute(t, axis, perm), w)
                low_side = (r & st.skip) != 0  # partner is lower block
                lo = self.combine(m, recv, w)
                hi = self.combine(m, w, recv)
                _record_op(2)
                w = jax.tree.map(
                    lambda a, b: jnp.where(low_side, a, b), lo, hi)
            elif st.kind == "allgather":
                _record_allgather()
                gathered = jax.tree.map(
                    lambda t: lax.all_gather(t, axis, axis=0), x)
            elif st.kind == "fold":
                ident = m.identity_like(x)

                def body(i, acc):
                    vi = jax.tree.map(lambda g: g[i], gathered)
                    take = i < r
                    combined = self.combine(m, acc, vi)
                    return jax.tree.map(
                        lambda c, a: jnp.where(take, c, a), combined,
                        acc)

                _record_op(st.fold_count)  # body executes fold_count×
                w = lax.fori_loop(0, st.fold_count, body, ident)
            elif st.kind == "bcast":
                _record_allgather()
                w = jax.tree.map(
                    lambda t: lax.all_gather(t, axis, axis=0)[st.root],
                    w)
        return w

    def _execute_segmented(self, sched, x, m, axis, p, r):
        """The pipelined ring: stream S leaf row-blocks through
        neighbour rounds; per-rank segment indices are dynamic
        (rank r handles segment t+1−r in round t)."""
        S = sched.n_segments
        V = jax.tree.map(lambda a: _jnp_split(a, S), x)
        R = m.identity_like(V)
        cur = jax.tree.map(lambda a: a[0], V)  # rank 0 sends V[0] first
        for st in sched.steps:
            s_recv = st.t + 1 - r
            valid = (r >= 1) & (s_recv >= 0) & (s_recv < S)
            sc = jnp.clip(s_recv, 0, S - 1)
            recv = _shift_up(cur, axis, 1, p)
            recv = _fixup_identity(m, recv, valid)
            # store: R[s] <- recv where the receive is in-window
            old = jax.tree.map(
                lambda t: lax.dynamic_slice_in_dim(t, sc, 1, 0), R)
            upd = jax.tree.map(
                lambda o, c: jnp.where(valid, c[None], o), old, recv)
            R = jax.tree.map(
                lambda t, u: lax.dynamic_update_slice_in_dim(
                    t, u, sc, 0), R, upd)
            if st.prep:
                # forward Q = recv ⊕ V[s] next round (rank 0: identity
                # fixup makes this plain V[t+1], its next raw segment)
                v_s = jax.tree.map(
                    lambda t: lax.dynamic_slice_in_dim(t, sc, 1, 0)[0],
                    V)
                cur = self.combine(m, recv, v_s)
                _record_op()
        return jax.tree.map(_jnp_unsplit, R, x)


class PallasExecutor(SPMDExecutor):
    """SPMD executor whose RoundStep ⊕ hook runs on-chip: elementwise
    monoids (``Monoid.leaf_op``) are tiled through VMEM by the Pallas
    block-combine kernel; structured monoids fall back to the plain op.

    Note: ``shard_map`` has no replication rule for ``pallas_call`` —
    wrap the call site with ``check_vma=False`` (``check_rep=False`` on
    older jax)."""

    def __init__(self, axis_name, *, interpret: bool | None = None,
                 block_rows: int = 256):
        super().__init__(axis_name)
        self.interpret = interpret
        self.block_rows = block_rows

    def combine(self, m: monoid_lib.Monoid, lo, hi):
        if m.leaf_op is None:
            return super().combine(m, lo, hi)
        from repro.kernels.blelloch_exscan import block_combine

        interpret = self.interpret
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return jax.tree.map(
            lambda a, b: block_combine(
                a, b, m.leaf_op, block_rows=self.block_rows,
                interpret=interpret), lo, hi)


class SimulatorExecutor(Executor):
    """Pure-numpy rank-by-rank execution of a schedule at any p — no
    devices, no tracing.  Leaves carry a leading rank axis of size p.

    Records the same aggregate stats as the SPMD executor into the
    ambient :func:`collect_stats` context, so plan-vs-execution drift is
    checkable host-side (dry-run, benchmark ``--check`` modes)."""

    def execute(self, sched: Schedule, x, m: monoid_lib.Monoid):
        p = sched.p
        op = monoid_lib.NUMPY_OPS.get(m.name, m.op)
        ident_fn = monoid_lib.NUMPY_IDENTITY.get(m.name)
        if ident_fn is None:
            def ident_fn(t):
                return jax.tree.map(np.asarray, m.identity_like(t))

        V = [jax.tree.map(lambda a: np.asarray(a)[q], x)
             for q in range(p)]
        if p == 0:
            return x
        if any(st.kind == "seg_shift" for st in sched.steps):
            return self._execute_segmented(sched, V, op, ident_fn, x)
        if sched.init == "x":
            W = [jax.tree.map(np.copy, v) for v in V]
        else:
            W = [ident_fn(v) for v in V]
        gathered = None
        for st in sched.steps:
            if st.kind == "shift":
                if st.send == "x":
                    payload = V
                elif st.send == "w":
                    payload = W
                else:
                    payload = [op(W[q], V[q]) for q in range(p)]
                    _record_op()
                _record_round(payload[0])
                ok = (lambda q: q >= st.bound) if st.mask == "ge" else \
                    (lambda q: q > st.bound)
                nw = list(W)
                for q in range(st.skip, p):
                    if ok(q):
                        recv = payload[q - st.skip]
                        nw[q] = recv if st.combine == "copy" else \
                            op(recv, W[q])
                if st.combine == "op":
                    _record_op()
                W = nw
            elif st.kind == "exchange":
                _record_round(W[0])
                _record_op(2)
                W = [op(W[q ^ st.skip], W[q]) if q & st.skip
                     else op(W[q], W[q ^ st.skip]) for q in range(p)]
            elif st.kind == "allgather":
                _record_allgather()
                gathered = V
            elif st.kind == "fold":
                _record_op(st.fold_count)
                nw = []
                for q in range(p):
                    acc = ident_fn(V[q])
                    for i in range(q):
                        acc = op(acc, gathered[i])
                    nw.append(acc)
                W = nw
            elif st.kind == "bcast":
                _record_allgather()
                W = [W[st.root] for _ in range(p)]
        return jax.tree.map(lambda *ws: np.stack(ws, axis=0), *W)

    def _execute_segmented(self, sched, V, op, ident_fn, x_like):
        p = len(V)
        S = sched.n_segments
        Vs = [jax.tree.map(lambda a: _np_split(a, S), v) for v in V]
        R = [ident_fn(v) for v in Vs]
        cur = [jax.tree.map(lambda a: a[0].copy(), v) for v in Vs]
        seg_of = (lambda v, s: jax.tree.map(lambda a: a[s], v))
        for st in sched.steps:
            _record_round(cur[0])
            recv = [None] + cur[:-1]  # neighbour shift r-1 -> r
            if st.prep:
                _record_op()
            ncur = list(cur)
            for q in range(p):
                s = st.t + 1 - q
                valid = q >= 1 and 0 <= s < S
                sc = min(max(s, 0), S - 1)
                base = recv[q] if valid else ident_fn(seg_of(Vs[q], sc))
                if valid:
                    R[q] = jax.tree.map(
                        lambda acc, b: _np_set_seg(acc, sc, b),
                        R[q], base)
                if st.prep:
                    ncur[q] = op(base, seg_of(Vs[q], sc))
            cur = ncur
        out = [jax.tree.map(_np_unsplit, R[q],
                            jax.tree.map(np.asarray, V[q]))
               for q in range(p)]
        return jax.tree.map(lambda *ws: np.stack(ws, axis=0), *out)


def _np_set_seg(acc, s: int, value):
    acc = np.asarray(acc).copy()
    acc[s] = value
    return acc


# ---------------------------------------------------------------------------
# Host-side plan verification (dry-run / benchmark drift checks)
# ---------------------------------------------------------------------------


def _witness_payload(name: str, p: int, n0: int, seed: int):
    rng = np.random.default_rng(seed)
    if name == "affine":
        return (rng.standard_normal((p, n0)),
                rng.standard_normal((p, n0)))
    if name == "matmul":
        return rng.standard_normal((p, 4, 4)) * 0.5
    if name in ("add", "xor"):
        return rng.integers(0, 1 << 30, size=(p, n0)).astype(np.int64)
    return rng.standard_normal((p, n0))


def _host_reference(kind: str, x, op, ident_fn, p: int):
    V = [jax.tree.map(lambda a: np.asarray(a)[q], x) for q in range(p)]
    out = []
    if kind == "exclusive":
        acc = ident_fn(V[0])
        for q in range(p):
            out.append(acc)
            acc = op(acc, V[q])
    elif kind == "inclusive":
        acc = ident_fn(V[0])
        for q in range(p):
            acc = op(acc, V[q])
            out.append(acc)
    else:  # allreduce
        acc = ident_fn(V[0])
        for q in range(p):
            acc = op(acc, V[q])
        out = [acc] * p
    return jax.tree.map(lambda *ws: np.stack(ws, axis=0), *out)


def verify_plan(plan, *, rank_elems: int = 2, seed: int = 0) -> dict:
    """Execute ``plan``'s schedule(s) in the numpy simulator against a
    sequential host reference; returns measured-vs-predicted stats.

    Multi-axis plans are verified per sub-plan.  Used by the dry-run
    (every cell's resolved scan plans) and the benchmark ``--check``
    smoke modes so plan/measurement drift fails fast, without devices.
    """
    if plan.sub_plans:
        subs = [verify_plan(s, rank_elems=rank_elems, seed=seed)
                for s in plan.sub_plans]
        return {"algorithm": plan.algorithm, "p": plan.p,
                "segments": plan.segments,
                "ok": all(s["ok"] for s in subs), "sub": subs}
    m = monoid_lib.get(plan.spec.monoid)
    op = monoid_lib.NUMPY_OPS.get(m.name, m.op)
    ident_fn = monoid_lib.NUMPY_IDENTITY.get(
        m.name, lambda t: jax.tree.map(np.asarray, m.identity_like(t)))
    S = max(1, plan.segments)
    n0 = S * rank_elems
    x = _witness_payload(m.name, plan.p, n0, seed)
    sched = plan.schedule()
    with collect_stats() as st:
        got = SimulatorExecutor().execute(sched, x, m)
    want = _host_reference(plan.spec.kind, x, op, ident_fn, plan.p)
    close = all(
        np.allclose(g, w, rtol=1e-10, atol=1e-12)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    # byte accounting: the witness is built with S | element count, so
    # the plan's per-round law (one m/S-byte segment per seg round,
    # full m per shift/exchange round) must match measurement exactly
    per_rank = jax.tree.map(lambda a: np.asarray(a)[0], x)
    leaves = [np.asarray(t) for t in jax.tree.leaves(per_rank)]
    div = S if any(s2.kind == "seg_shift" for s2 in sched.steps) else 1
    bytes_expected = plan.rounds * sum(
        -(-t.size // div) * t.dtype.itemsize for t in leaves)
    res = {
        "algorithm": plan.algorithm, "p": plan.p,
        "segments": plan.segments,
        "rounds_predicted": plan.rounds, "rounds_measured": st.rounds,
        "ops_predicted": plan.op_applications,
        "ops_measured": st.op_applications,
        "allgathers_predicted": plan.allgathers,
        "allgathers_measured": st.allgathers,
        "bytes_expected": bytes_expected,
        "bytes_measured": sum(st.bytes_per_round),
        "correct": bool(close),
    }
    res["ok"] = bool(
        close
        and st.rounds == plan.rounds
        and st.op_applications == plan.op_applications
        and st.allgathers == plan.allgathers
        and sum(st.bytes_per_round) == bytes_expected)
    return res
