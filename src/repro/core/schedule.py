"""Executable schedule IR for scan plans (DESIGN.md §7).

A :class:`Schedule` is the explicit, inspectable program of a scan
algorithm: a sequence of :class:`RoundStep`s — peer offsets for the
``ppermute`` of each simultaneous send-receive round, SPMD receive
masks, the ⊕ combine direction, identity fixups — over per-rank payload
:class:`Segment`s.  Registered algorithms *build* schedules
(``build_123`` …), the planner derives its predicted round/⊕/all-gather
counts by counting the IR, and three executors run the same schedule:

  * :class:`SPMDExecutor` — one ``lax.ppermute`` per round inside
    ``shard_map`` (what ``scan_api.scan`` runs on a mesh);
  * :class:`SimulatorExecutor` — pure-numpy, rank-by-rank lockstep
    execution at any p with no devices (dry-run plan verification,
    benchmark drift checks, property tests);
  * :class:`PallasExecutor` — the SPMD executor with the per-round ⊕
    combine hook lowered through the on-chip Pallas block-combine
    kernel (``kernels/blelloch_exscan.block_combine``).

Because the planner's counts and the executors consume the *same* IR,
``ScanPlan`` predictions equal ``collect_stats()`` measurements by
construction — the IR is the single source of truth for what runs.

Three schedule *transforms* extend single algorithms into programs:

  * :func:`segment` — the paper's large-m pipelining: the p−1-round
    neighbour ring becomes p−2+S rounds of one m/S-byte segment each.
  * :func:`compose` — the DESIGN §5 multi-axis rewrite inlined into
    ONE schedule: inner exscan + minor-axis allreduce + outer exscan
    + one combining ⊕, each :class:`RoundStep` tagged with the mesh
    axis it runs over and stitched together by register control steps
    (``stage`` saves/rebinds the accumulator between phases, ``merge``
    applies the final ⊕).  Multi-axis plans therefore lower, simulate
    and Pallas-execute exactly like single-axis ones.
  * :func:`fuse` — k same-axis/same-kind scan payloads packed into one
    flattened buffer described by a :class:`PayloadLayout`, so all k
    scans ride the SAME q rounds (α·q once instead of k·α·q) and are
    unpacked afterwards.

``with_total``/``build_scan_total`` additionally fuse an exclusive
scan with an allreduce of the same payload ("scan_total" kind): for
power-of-two p a single (prefix, total) butterfly computes both in
⌈log₂p⌉ rounds; otherwise the exscan's last rank completes the total
locally and broadcasts it — either way one schedule, one payload
stream, instead of two back-to-back collectives.

Execution engine (compiled round tables):  the SPMD executor lowers
homogeneous step runs through per-round parameter *tables* instead of
re-deriving everything inside an open-coded Python loop.  Runs whose
rounds share one peer permutation — the segmented ring, whose p−2+S
rounds all ppermute r → r+1 — roll into a SINGLE ``lax.scan`` body
driven by the stacked round parameters (the per-round segment index
``t`` as a ``jnp`` array), so trace size and compile time are O(1) in
p and S rather than O(p+S).  Rounds whose peer offsets vary (doubling
shift chains, butterfly exchanges) must keep one ``ppermute`` trace
site each — XLA's ``ppermute`` takes a *static* permutation — but
those chains are O(log p) rounds by construction, so their traces
stay logarithmic.  The rolled ring is additionally *double-buffered*:
each loop iteration first issues round t's ``ppermute`` and only then
stores round t−1's received segment (carried as the pending
double-buffer), so XLA can overlap the neighbour communication with
the previous round's combine/store work; the final pending store
drains after the loop.  ``SPMDExecutor(unrolled=True)`` keeps the
legacy one-trace-site-per-round ring for the rolled-vs-unrolled
bit-identity law the tests enforce.

⊕ accounting is monoid-aware: for commutative monoids the butterfly
``exchange`` elides the redundant second combine order (2→1 ⊕) and the
fused ``scan_reduce`` round folds the window total once (3→2 ⊕);
``RoundStep.op_count(commutative)`` / ``Schedule.op_count`` expose the
elided counts, the planner prices them, and the executors record
exactly them into :func:`collect_stats`.

Byte prediction note: the plan's ``bytes_on_wire`` for a segmented
schedule is ``rounds · ceil(m/S)``; the traced program zero-pads each
flattened leaf up to a multiple of S, so prediction and measurement
agree exactly when S divides every leaf's element count (the planner
only considers power-of-two S, which also keeps the padding bounded).
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import monoid as monoid_lib
from repro.core import oracle


# ---------------------------------------------------------------------------
# Trace/execution-time instrumentation.  Both the SPMD executor (at trace
# time) and the numpy simulator (at execution time) record rounds, ⊕
# applications and all-gathers here, so tests and benchmarks can assert
# the planner's predicted costs on the program that actually runs.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveStats:
    rounds: int = 0  # ppermute calls (communication rounds)
    op_applications: int = 0  # ⊕ applications per device (SPMD lockstep)
    allgathers: int = 0
    bytes_per_round: list = dataclasses.field(default_factory=list)
    # Pallas-path accounting (recorded by PallasExecutor only; the
    # generic/simulator executors leave both at 0).  ``hbm_passes``
    # counts sequential sweeps over a round's payload — kernel
    # launches plus the XLA select sweeps the fused round path
    # absorbs into the kernel; see RoundStep.kernel_passes.
    kernel_launches: int = 0  # pallas_call launches
    hbm_passes: int = 0  # payload HBM traversals of round ⊕ work


_tls = threading.local()


@contextlib.contextmanager
def collect_stats():
    """Context manager capturing round/op counts of scans traced (SPMD)
    or executed (simulator) inside."""
    stats = CollectiveStats()
    prev = getattr(_tls, "stats", None)
    _tls.stats = stats
    try:
        yield stats
    finally:
        _tls.stats = prev


def _stats() -> CollectiveStats | None:
    return getattr(_tls, "stats", None)


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _record_round(tree):
    s = _stats()
    if s is not None:
        s.rounds += 1
        s.bytes_per_round.append(_nbytes(tree))


def _record_op(n: int = 1):
    """Count n ⊕ *executions* (a traced-once loop body records its trip
    count, so stats mean executions, not trace sites)."""
    s = _stats()
    if s is not None:
        s.op_applications += n


def _record_allgather():
    s = _stats()
    if s is not None:
        s.allgathers += 1


def _record_kernel(launches: int, passes: int):
    """Count on-chip kernel launches / HBM passes of one round's ⊕
    work (Pallas executor only; execution counts, like _record_op)."""
    s = _stats()
    if s is not None:
        s.kernel_launches += launches
        s.hbm_passes += passes


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    """One of S contiguous blocks of the flattened per-rank payload.

    Each leaf is flattened and zero-padded to a multiple of ``count``;
    block ``index`` holds elements [index·k, (index+1)·k) with
    k = ceil(size/count).  ⊕ must combine aligned element blocks
    independently for this to be sound (``Monoid.segmentable``)."""

    index: int
    count: int


@dataclasses.dataclass(frozen=True)
class RoundStep:
    """One round of a schedule.

    kind:
      "shift"       — ppermute r → r+skip; masked receive; combine.
      "seg_shift"   — pipelined-ring round ``t``: neighbour ppermute of
                      one payload segment; rank r stores received
                      segment s = t+1−r (when 0 ≤ s < S) as its result
                      and, if ``prep``, forwards recv ⊕ V[s] next
                      round (1 ⊕).  ``seg`` carries S.
      "exchange"    — butterfly ppermute r ↔ r^skip; two
                      order-preserving combines selected by the rank's
                      side bit.
      "block_exchange" — one round of the block-distributed exscan
                      family (halving/quartering/reduce_scatter): the
                      payload is split into ``seg`` = 2^t rows and the
                      round moves ``rows`` of them (the per-round byte
                      law ``rows · ceil(m/seg)`` the planner prices).
                      ``phase`` narrows the semantics: "fold" pairs off
                      the p mod 2^t surplus ranks, "up" halves the
                      owned row range against virtual partner v^skip
                      (saving both pre-combine halves for the down
                      sweep), "mid" runs a two-⊕ exscan over the
                      2^t-aligned windows on each rank's single owned
                      row, "down" doubles the row range back while
                      turning window prefixes into rank prefixes, and
                      "unfold" returns the folded pairs' results.
                      ``bound`` carries the fold count ρ, ``t`` the
                      phase round index.
      "scan_reduce" — fused exscan+allreduce butterfly round: exchange
                      the window total T with r^skip while the lower
                      side also folds the received total into the
                      exclusive prefix P (3 ⊕ in SPMD lockstep).  After
                      the run P is saved into register ``reg``.
      "allgather"   — XLA-native all-gather of the input V.
      "fold"        — local left-fold of the gathered values below own
                      rank (``fold_count`` ⊕ executions).
      "bcast"       — broadcast rank ``root``'s value (via all-gather).
      "stage"       — control (no round): save W into register ``reg``
                      (if set), rebind the stage input X ← W when
                      ``src == "w"``, then reinit W per ``init``
                      ("identity" | "x" | "w" | a register name).
      "merge"       — control ⊕: W ← W ⊕ reg (reg "$x": the current
                      stage input); W covers the lower ranks.

    axis: mesh axis name this step runs over (None: the executor's
      default axis) — composed multi-axis schedules tag every step.
    send (shift only): "x" the input V, "w" the accumulator,
      "w_op_x" the prepared W ⊕ V (counts one ⊕).
    mask/bound (shift only): receive participation — "ge": r ≥ bound,
      "gt": r > bound.  Non-participants keep W (identity fixup).
    combine (shift only): "copy" W ← recv, or "op" W ← recv ⊕ W (the
      recv side always covers lower ranks — non-commutative safe).
    """

    kind: str
    skip: int = 0
    send: str = "w"
    mask: str = "ge"
    bound: int = 0
    combine: str = "none"
    t: int = -1  # seg_shift round index
    prep: bool = False  # seg_shift: forward-prep ⊕ this round
    fold_count: int = 0  # fold: ⊕ executions
    root: int = 0  # bcast source rank
    axis: Any = None  # mesh axis this step runs over (None: default)
    seg: int = 0  # seg_shift: segment count S of this run
    reg: str = ""  # stage save / merge source / scan_reduce prefix reg
    src: str = ""  # stage: "w" rebinds X ← W
    init: str = "identity"  # stage: new W ("identity"|"x"|"w"|register)
    phase: str = ""  # block_exchange: fold|up|mid|down|unfold
    rows: int = 0  # block_exchange: payload rows this round moves

    @property
    def is_round(self) -> bool:
        """Does this step cost one ppermute communication round?"""
        return self.kind in ("shift", "seg_shift", "exchange",
                             "scan_reduce", "block_exchange")

    @property
    def ops(self) -> int:
        """⊕ executions per device (SPMD lockstep) for this step,
        for a non-commutative monoid (the worst case)."""
        return self.op_count(commutative=False)

    def op_count(self, commutative: bool = False) -> int:
        """⊕ executions per device for this step.

        Commutative monoids elide the redundant combine order: a
        butterfly ``exchange`` computes one combine instead of both
        orders (2→1), and a fused ``scan_reduce`` round folds the
        window total once instead of twice (3→2).  The executors
        apply the same elision, so plans priced off this count match
        :func:`collect_stats` measurements for every monoid."""
        n = 0
        if self.kind == "shift":
            n += 1 if self.send == "w_op_x" else 0
            n += 1 if self.combine == "op" else 0
        elif self.kind == "seg_shift":
            n += 1 if self.prep else 0
        elif self.kind == "exchange":
            n += 1 if commutative else 2
        elif self.kind == "scan_reduce":
            n += 2 if commutative else 3
        elif self.kind == "block_exchange":
            if self.phase in ("fold", "unfold"):
                n += 1  # the folded pair's single combine
            elif self.phase == "up":
                # exchange-shaped: commutative elides the second order
                n += 1 if commutative else 2
            elif self.phase == "mid":
                # copy round carries no ⊕; later rounds prep the send
                # (P ⊕ T) and fold the received window prefix
                n += 0 if self.combine == "copy" else 2
            elif self.phase == "down":
                # lower half preps P ⊕ O_j, upper half adjusts P ⊕ S_j
                # (different operands: no commutative elision)
                n += 2
        elif self.kind == "fold":
            n += self.fold_count
        elif self.kind == "merge":
            n += 1
        return n

    def kernel_passes(self, commutative: bool = False, *,
                      fused: bool = True) -> int:
        """HBM passes over this round's payload on the Pallas path.

        A "pass" is one sequential sweep of the payload: a kernel
        launch, or an XLA select sweep the baseline path runs on a
        kernel's output.  ``fused=True`` is the engine's fused round
        path (one grid pass does the combine orders, the mask/side
        select and the store); ``fused=False`` is the per-round
        ``block_combine`` baseline (one launch per ⊕ plus host-graph
        selects).  Copy/gather rounds carry no ⊕ work and count 0 —
        the metric prices combine traffic, which both modes share
        otherwise.  The fusion wins: ring prep 2→1, non-commutative
        butterfly 3→1, scan_reduce 2→1 (commutative) / 5→1."""
        if self.kind == "shift":
            n = 1 if self.send == "w_op_x" else 0
            return n + (1 if self.combine == "op" else 0)
        if self.kind == "seg_shift":
            if not self.prep:
                return 0
            return 1 if fused else 2  # baseline: combine + valid-select
        if self.kind == "exchange":
            if commutative:
                return 1
            return 1 if fused else 3  # baseline: 2 orders + side select
        if self.kind == "scan_reduce":
            if fused:
                return 1  # (P, T) pair batched into one launch
            return 2 if commutative else 5  # 3 launches + 2 selects
        if self.kind == "block_exchange":
            if self.phase in ("fold", "unfold"):
                # one masked combine; baseline pays the mask select
                return 1 if fused else 2
            if self.phase == "up":
                if commutative:
                    return 1
                return 1 if fused else 3  # 2 orders + side select
            if self.phase == "mid":
                if self.combine == "copy":
                    return 0
                # prep combine + masked window combine (baseline pays
                # the window-mask select on the second)
                return 2 if fused else 3
            # down: two combines plus the side/adjust selects stay in
            # the host graph — no fused down-round kernel, both modes
            # sweep the half-payload four times
            return 4
        if self.kind == "fold":
            return self.fold_count
        if self.kind == "merge":
            return 1
        return 0

    def kernel_launches(self, commutative: bool = False, *,
                        fused: bool = True) -> int:
        """``pallas_call`` launches for this round on the Pallas path
        (per payload dtype group; k same-dtype leaves batch into one
        launch on the fused path)."""
        if self.kind == "shift":
            n = 1 if self.send == "w_op_x" else 0
            return n + (1 if self.combine == "op" else 0)
        if self.kind == "seg_shift":
            return 1 if self.prep else 0
        if self.kind == "exchange":
            return 1 if (commutative or fused) else 2
        if self.kind == "scan_reduce":
            if fused:
                return 1
            return 2 if commutative else 3
        if self.kind == "block_exchange":
            if self.phase in ("fold", "unfold"):
                return 1
            if self.phase == "up":
                return 1 if (commutative or fused) else 2
            if self.phase == "mid":
                return 0 if self.combine == "copy" else 2
            return 2  # down: prep + adjust combines
        if self.kind == "fold":
            return self.fold_count
        if self.kind == "merge":
            return 1
        return 0

    def describe(self) -> str:
        at = f"  @{self.axis}" if self.axis is not None else ""
        if self.kind == "shift":
            send = {"x": "V", "w": "W", "w_op_x": "W⊕V"}[self.send]
            cmp_ = {"ge": ">=", "gt": ">"}[self.mask]
            comb = "W←recv" if self.combine == "copy" else "W←recv⊕W"
            return (f"shift +{self.skip:<4d} send={send:<4s} "
                    f"recv r{cmp_}{self.bound}  {comb}{at}")
        if self.kind == "seg_shift":
            tail = "; send←recv⊕V[s]" if self.prep else "  (drain)"
            return f"ring  t={self.t:<3d} seg s=t+1−r  W[s]←recv{tail}{at}"
        if self.kind == "exchange":
            return f"xchg  r↔r^{self.skip}  W←ordered(recv,W){at}"
        if self.kind == "scan_reduce":
            return (f"scrd  r↔r^{self.skip}  T←ordered(recv,T); "
                    f"low: P←recv⊕P{at}")
        if self.kind == "block_exchange":
            what = {
                "fold": "pair 2i→2i+1: Y←recv⊕V",
                "up": f"v↔v^{self.skip}: keep/swap half rows",
                "mid": ("window copy P←T[w−1]"
                        if self.combine == "copy"
                        else f"w→w+{self.skip}: P←recv⊕P"),
                "down": f"v↔v^{self.skip}: widen P, low sends P⊕O",
                "unfold": "pair 2i+1→2i: return E; odd: P⊕lo",
            }[self.phase]
            return (f"blk   {self.phase:<6s} rows={self.rows}/"
                    f"{self.seg}  {what}{at}")
        if self.kind == "allgather":
            return f"all-gather V{at}"
        if self.kind == "fold":
            return f"local fold of {self.fold_count + 1} gathered values"
        if self.kind == "bcast":
            return f"broadcast rank {self.root} (all-gather){at}"
        if self.kind == "stage":
            save = f" save W→{self.reg!r};" if self.reg else ""
            src = " X←W;" if self.src == "w" else ""
            return f"stage{save}{src} W←{self.init}"
        if self.kind == "merge":
            other = "X" if self.reg == "$x" else repr(self.reg)
            return f"merge W←W⊕{other}"
        return self.kind


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An executable scan program: init state + ordered RoundSteps.

    ``axes`` names the mesh axes (major→minor, with sizes) of a
    composed multi-axis schedule; single-axis schedules leave it empty
    and run over the executor's axis.  ``outputs`` lists what
    ``execute`` returns — "$w" is the final accumulator, anything else
    a register name; more than one entry returns a tuple.  ``layout``
    (set by :func:`fuse`) packs a sequence of payloads into one
    flattened buffer around the run.
    """

    algorithm: str
    kind: str  # "exclusive" | "inclusive" | "allreduce" | "scan_total"
    p: int
    init: str = "identity"  # initial accumulator W: "identity" | "x"
    segments: tuple[Segment, ...] = (Segment(0, 1),)
    steps: tuple[RoundStep, ...] = ()
    axes: tuple = ()  # ((axis_name, size), ...) major→minor; composed
    outputs: tuple = ("$w",)
    layout: "PayloadLayout | None" = None

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def rounds(self) -> int:
        return sum(1 for s in self.steps if s.is_round)

    @property
    def op_applications(self) -> int:
        """⊕ executions for a non-commutative monoid (worst case);
        use :meth:`op_count` for the monoid-aware number."""
        return self.op_count(commutative=False)

    def op_count(self, commutative: bool = False) -> int:
        """⊕ executions per device, honouring the commutative-monoid
        elision in butterfly/scan_reduce rounds."""
        return sum(s.op_count(commutative) for s in self.steps)

    def kernel_passes(self, commutative: bool = False, *,
                      fused: bool = True) -> int:
        """Total HBM passes of the schedule's ⊕ work on the Pallas
        path (see :meth:`RoundStep.kernel_passes`); what
        ``collect_stats().hbm_passes`` measures under the Pallas
        executor in the matching mode."""
        return sum(s.kernel_passes(commutative, fused=fused)
                   for s in self.steps)

    def kernel_launches(self, commutative: bool = False, *,
                        fused: bool = True) -> int:
        """Total ``pallas_call`` launches on the Pallas path."""
        return sum(s.kernel_launches(commutative, fused=fused)
                   for s in self.steps)

    @property
    def allgathers(self) -> int:
        return sum(1 for s in self.steps
                   if s.kind in ("allgather", "bcast"))

    def describe(self) -> str:
        """Round-by-round human-readable listing (no tracing needed)."""
        head = (f"{self.kind} [{self.algorithm}] p={self.p} "
                f"S={self.n_segments} rounds={self.rounds} "
                f"⊕={self.op_applications} "
                f"allgathers={self.allgathers} (W₀={self.init})")
        if self.axes:
            head += " axes=" + "x".join(
                f"{name}:{size}" for name, size in self.axes)
        lines = [head]
        rnd = 0
        for st in self.steps:
            tag = f"r{rnd}" if st.is_round else "--"
            rnd += 1 if st.is_round else 0
            lines.append(f"  {tag:>4s}: {st.describe()}")
        return "\n".join(lines)


def _segs(S: int) -> tuple[Segment, ...]:
    return tuple(Segment(i, S) for i in range(S))


# ---------------------------------------------------------------------------
# Per-round byte laws, priced off the IR.  The planner, the calibration
# features and ``expected_round_bytes`` all read these, so a schedule
# whose rounds move less than the full payload (the segmented ring's
# m/S segments, the block family's row slices) is priced exactly as the
# executors transmit it.
# ---------------------------------------------------------------------------


def step_wire_bytes(st: RoundStep, nbytes: int,
                    default_seg: int = 1) -> int:
    """Bytes one round of ``st`` puts on the wire for an ``nbytes``
    payload: a ceil(m/S) segment per pipelined ring round,
    rows·ceil(m/2^t) for a block-exchange round, the full payload
    otherwise.  Non-round steps move nothing here (all-gathers are
    priced separately, as in ``ScanPlan.bytes_on_wire``)."""
    if not st.is_round:
        return 0
    if st.kind == "seg_shift":
        return -(-nbytes // (st.seg or default_seg))
    if st.kind == "block_exchange":
        return st.rows * -(-nbytes // st.seg)
    return nbytes


def wire_bytes(sched: "Schedule", nbytes: int) -> int:
    """Total round wire bytes of the schedule under the per-round law
    (excluding all-gather traffic)."""
    return sum(step_wire_bytes(st, nbytes, sched.n_segments)
               for st in sched.steps)


def op_wire_bytes(sched: "Schedule", nbytes: int,
                  commutative: bool = False) -> int:
    """⊕-traffic bytes: each step's ⊕ count times the bytes one of its
    ⊕ touches.  For uniform schedules this equals
    ``op_count · ceil(m/S)`` (the legacy planner law); block-exchange
    steps combine only the rows they move."""
    seg = _max_seg(sched)
    total = 0
    for st in sched.steps:
        n = st.op_count(commutative)
        if not n:
            continue
        if st.kind == "block_exchange":
            total += n * st.rows * -(-nbytes // st.seg)
        else:
            total += n * -(-nbytes // seg)
    return total


def pass_wire_bytes(sched: "Schedule", nbytes: int,
                    commutative: bool = False, *,
                    fused: bool = True) -> int:
    """Kernel-pass traffic bytes (the gamma_pass cost-model term):
    each step's HBM passes times the bytes one pass sweeps."""
    seg = _max_seg(sched)
    total = 0
    for st in sched.steps:
        n = st.kernel_passes(commutative, fused=fused)
        if not n:
            continue
        if st.kind == "block_exchange":
            total += n * st.rows * -(-nbytes // st.seg)
        else:
            total += n * -(-nbytes // seg)
    return total


# ---------------------------------------------------------------------------
# Builders: one per registered algorithm.  The planner counts rounds/⊕/
# all-gathers off these schedules, so by construction plans predict what
# the executors measure.
# ---------------------------------------------------------------------------


def build_123(p: int) -> Schedule:
    """Algorithm 1 (123-doubling): skip schedule 1, 2, 3·2^(k−2);
    q = ⌈log₂(p−1)+log₂(4/3)⌉ rounds, q−1 result-path ⊕."""
    steps: list[RoundStep] = []
    if p >= 2:
        steps.append(RoundStep("shift", skip=1, send="x", mask="ge",
                               bound=1, combine="copy"))
    if p >= 3:
        # Round 1 (skip 2): send W ⊕ V (rank 0's W is the identity, so
        # it sends plain V exactly as in the paper); combine iff r >= 2.
        steps.append(RoundStep("shift", skip=2, send="w_op_x", mask="ge",
                               bound=2, combine="op"))
        for s in oracle.skips_123(p)[2:]:
            # rank complete once its window bottoms out (paper: 0 < f)
            steps.append(RoundStep("shift", skip=s, send="w", mask="gt",
                                   bound=s, combine="op"))
    return Schedule("123", "exclusive", p, steps=tuple(steps))


def build_1doubling(p: int) -> Schedule:
    """Shift + straight doubling: 1 + ⌈log₂(p−1)⌉ rounds."""
    steps: list[RoundStep] = []
    if p >= 2:
        steps.append(RoundStep("shift", skip=1, send="x", mask="ge",
                               bound=1, combine="copy"))
        for s in oracle.skips_1doubling(p)[1:]:
            steps.append(RoundStep("shift", skip=s, send="w", mask="gt",
                                   bound=s, combine="op"))
    return Schedule("1doubling", "exclusive", p, steps=tuple(steps))


def build_two_op(p: int) -> Schedule:
    """Two-⊕ doubling: ⌈log₂ p⌉ rounds, two ⊕ per round after the first."""
    steps: list[RoundStep] = []
    if p >= 2:
        steps.append(RoundStep("shift", skip=1, send="x", mask="ge",
                               bound=1, combine="copy"))
        k = 1
        while (1 << k) < p:
            s = 1 << k
            steps.append(RoundStep("shift", skip=s, send="w_op_x",
                                   mask="ge", bound=s, combine="op"))
            k += 1
    return Schedule("two_op", "exclusive", p, steps=tuple(steps))


def build_native(p: int) -> Schedule:
    """Library baseline: all-gather everyone's V, fold locally below own
    rank — zero ppermutes but p·m wire bytes and p−1 local ⊕."""
    steps: tuple[RoundStep, ...] = ()
    if p >= 2:
        steps = (RoundStep("allgather"),
                 RoundStep("fold", fold_count=p - 1))
    return Schedule("native", "exclusive", p, steps=steps)


def build_ring(p: int, segments: int = 1) -> Schedule:
    """Pipelined segmented neighbour ring: p−2+S rounds of one
    m/S-byte segment each (S=1: the plain p−1-round ring).

    Round t: rank r receives segment s = t+1−r (its exclusive prefix
    for that block, complete on arrival) and forwards recv ⊕ V[s] —
    one ⊕ per non-final round, p−3+S total."""
    S = max(1, int(segments))
    if p <= 1:
        return Schedule("ring", "exclusive", p, segments=_segs(S))
    n = p - 2 + S
    steps = tuple(RoundStep("seg_shift", skip=1, t=t, prep=(t < n - 1),
                            seg=S)
                  for t in range(n))
    return Schedule("ring", "exclusive", p, segments=_segs(S),
                    steps=steps)


def _build_block(name: str, p: int, depth: int) -> Schedule:
    """The block-distributed exscan family (vector halving/doubling).

    The payload is split into R = 2^t elementwise rows
    (t = min(depth, ⌊log₂p⌋)) and the scan runs in five phases over
    M = p − ρ *virtual* ranks (ρ = p mod 2^t surplus ranks pair off in
    a fold pre-round and rejoin in an unfold post-round):

      up    — t butterfly rounds halve each rank's owned row range
              against virtual partner v^2^k, so after round k every
              2^(k+1)-rank window's fold is block-distributed over it;
      mid   — a two-⊕ exscan over the M/2^t windows, each rank
              carrying only its single owned row;
      down  — t rounds double the row range back, converting window
              prefixes into per-rank exclusive prefixes: the lower
              sibling sends P ⊕ O_k (its saved pre-combine half), the
              upper adjusts its own rows by the saved received half.

    Round/byte laws (power-of-two p): 2(1−2^−t)·m + (q−t)/2^t·m wire
    bytes over q+t rounds (q = ⌈log₂p⌉) — t=1 ≈ (q+1)/2·m in q+1
    rounds, t=2 ≈ (q+4)/4·m in q+2, t=q ≈ 2(1−1/p)·m in 2q rounds —
    a graded ladder between the doubling schedules (q·m) and the
    segmented ring (→m as S grows).  ρ≠0 adds the fold/unfold round
    pair.  Rows combine elementwise, so these schedules require a
    segmentable monoid (like :func:`segment`)."""
    steps: list[RoundStep] = []
    if p >= 2:
        t = max(1, min(depth, p.bit_length() - 1))
        R = 1 << t
        rho = p % R
        n_w = (p - rho) >> t
        common = dict(seg=R, bound=rho)
        if rho:
            steps.append(RoundStep("block_exchange", phase="fold",
                                   rows=R, skip=1, t=0, **common))
        for k in range(t):
            steps.append(RoundStep("block_exchange", phase="up",
                                   rows=R >> (k + 1), skip=1 << k, t=k,
                                   **common))
        if n_w >= 2:
            steps.append(RoundStep("block_exchange", phase="mid",
                                   rows=1, skip=1, t=0, combine="copy",
                                   **common))
            i = 1
            while (1 << i) < n_w:
                steps.append(RoundStep("block_exchange", phase="mid",
                                       rows=1, skip=1 << i, t=i,
                                       combine="op", **common))
                i += 1
        for j in reversed(range(t)):
            steps.append(RoundStep("block_exchange", phase="down",
                                   rows=R >> (j + 1), skip=1 << j, t=j,
                                   **common))
        if rho:
            steps.append(RoundStep("block_exchange", phase="unfold",
                                   rows=R, skip=1, t=0, **common))
    return Schedule(name, "exclusive", p, steps=tuple(steps))


def build_halving(p: int) -> Schedule:
    """Träff-2026 exclusive scan, depth-1 halving: ⌈log₂p⌉+1 rounds
    (power-of-two p) of ≈(⌈log₂p⌉+1)/2·m total wire bytes."""
    return _build_block("halving", p, 1)


def build_quartering(p: int) -> Schedule:
    """Träff-2026 exclusive scan, depth-2 quartering: ⌈log₂p⌉+2
    rounds (power-of-two p) of ≈(⌈log₂p⌉+4)/4·m total wire bytes."""
    return _build_block("quartering", p, 2)


def build_reduce_scatter(p: int) -> Schedule:
    """Full-depth reduce-scatter (vector halving/doubling) exscan:
    2⌈log₂p⌉ rounds of ≈2·(p−1)/p·m total wire bytes."""
    return _build_block("reduce_scatter", p, max(1, p.bit_length()))


def build_hillis_steele(p: int) -> Schedule:
    """Hillis-Steele inclusive scan: ⌈log₂ p⌉ rounds, one ⊕ each."""
    steps = tuple(RoundStep("shift", skip=s, send="w", mask="ge",
                            bound=s, combine="op")
                  for s in oracle.skips_two_op(p))
    return Schedule("hillis_steele", "inclusive", p, init="x",
                    steps=steps)


def build_butterfly(p: int) -> Schedule:
    """Recursive-doubling all-reduce: ⌈log₂ p⌉ exchange rounds for
    power-of-two p; otherwise inclusive scan + broadcast of the last
    rank (order-preserving for non-commutative monoids)."""
    if p <= 1:
        return Schedule("butterfly", "allreduce", p, init="x")
    if p & (p - 1):  # non-power-of-two
        incl = build_hillis_steele(p)
        steps = incl.steps + (RoundStep("bcast", root=p - 1),)
        return Schedule("butterfly", "allreduce", p, init="x",
                        steps=steps)
    steps = []
    k = 0
    while (1 << k) < p:
        steps.append(RoundStep("exchange", skip=1 << k))
        k += 1
    return Schedule("butterfly", "allreduce", p, init="x",
                    steps=tuple(steps))


def with_total(base: Schedule) -> Schedule:
    """Fuse an allreduce of the input onto an exclusive-scan schedule.

    After the exscan the last rank alone holds the full prefix, so one
    local ⊕ with its own V completes the total, and one broadcast
    distributes it — no second collective sweep.  Returns a
    "scan_total" schedule with ``outputs = (prefix, total)``.
    """
    if base.kind != "exclusive":
        raise ValueError(
            f"with_total composes over exclusive schedules, "
            f"not {base.kind!r}")
    steps = base.steps + (
        RoundStep("stage", reg="prefix", init="w"),
        RoundStep("merge", reg="$x"),
    )
    if base.p >= 2:
        steps = steps + (RoundStep("bcast", root=base.p - 1),)
    return Schedule(f"{base.algorithm}+total", "scan_total", base.p,
                    init=base.init, segments=base.segments, steps=steps,
                    outputs=("prefix", "$w"))


def build_scan_total(p: int) -> Schedule:
    """Fused exscan+allreduce ("scan_total"): for power-of-two p a
    single (prefix, total) butterfly — each round exchanges the window
    total T with r^2^k while the lower side folds the received total
    into its exclusive prefix P — computes BOTH in ⌈log₂ p⌉ rounds,
    the allreduce's round count.

    Non-power-of-two p (where the r^2^k pairing no longer closes)
    reroutes at plan level to an exscan+``with_total`` variant: the
    cheaper, by (rounds, ⊕), of the 123-doubling and two-⊕-doubling
    exscans plus one local ⊕ and a broadcast — the 123 variant wins
    every tie (equal rounds, strictly fewer result-path ⊕), but the
    reroute keeps the choice explicit rather than assumed.
    ``outputs = (prefix, total)``."""
    if p >= 2 and not (p & (p - 1)):
        steps = []
        k = 0
        while (1 << k) < p:
            steps.append(RoundStep("scan_reduce", skip=1 << k,
                                   reg="prefix"))
            k += 1
        return Schedule("fused_doubling", "scan_total", p, init="x",
                        steps=tuple(steps), outputs=("prefix", "$w"))
    sched = min((with_total(build_123(p)), with_total(build_two_op(p))),
                key=lambda s: (s.rounds, s.op_applications))
    return dataclasses.replace(sched, algorithm="fused_doubling")


def segment(schedule: Schedule, S: int) -> Schedule:
    """The segmentation transform: split the payload into S row-blocks
    and stream them through p−2+S neighbour rounds.

    Only schedules made of neighbour rounds (the ring) pipeline this
    way; doubling schedules have data dependencies across non-neighbour
    peers and raise (including their trivially-empty p <= 1 forms)."""
    if schedule.algorithm != "ring" or not all(
            s.kind == "seg_shift" for s in schedule.steps):
        raise ValueError(
            f"only neighbour-ring schedules are segmentable, "
            f"not {schedule.algorithm!r}")
    return build_ring(schedule.p, S)


# ---------------------------------------------------------------------------
# Multi-axis composition (DESIGN §5 as a schedule transform)
# ---------------------------------------------------------------------------


_STAGE_INITS = ("identity", "x", "w")


def _tag_axis(steps, axis):
    """Tag untagged steps with ``axis`` (control steps stay axis-free)."""
    out = []
    for st in steps:
        if st.axis is None and st.kind not in ("stage", "merge"):
            st = dataclasses.replace(st, axis=axis)
        out.append(st)
    return tuple(out)


def _ns_regs(steps, ns: str):
    """Namespace every register reference so inlined sub-schedules
    cannot collide with the composing schedule's own registers."""
    out = []
    for st in steps:
        rep = {}
        if st.reg and st.reg != "$x":
            rep["reg"] = ns + st.reg
        if st.kind == "stage" and st.init not in _STAGE_INITS:
            rep["init"] = ns + st.init
        out.append(dataclasses.replace(st, **rep) if rep else st)
    return tuple(out)


def _ns_outputs(outputs, ns: str):
    return tuple(o if o == "$w" else ns + o for o in outputs)


def _outer_parts(outer: Schedule, outer_axis):
    """Inlineable (steps, axes) of the outer schedule: already-composed
    outers carry their own axis tags; single-axis ones get tagged."""
    steps = _ns_regs(outer.steps, "o:")
    if outer.axes:
        return steps, outer.axes
    if outer_axis is None:
        raise ValueError("outer_axis is required for a single-axis "
                         "outer schedule")
    return _tag_axis(steps, outer_axis), ((outer_axis, outer.p),)


def compose(inner: Schedule, reduce_: Schedule, outer: Schedule, *,
            minor_axis, outer_axis=None) -> Schedule:
    """Inline the DESIGN §5 multi-axis exscan rewrite into ONE schedule.

        exscan(x, (A, B)) = exscan(total_B(x), A) ⊕ exscan(x, B)

    ``inner`` (exclusive) and ``reduce_`` (allreduce) run over the
    minor axis, ``outer`` (exclusive; possibly itself composed) over
    the major axes, stitched by register control steps:  the inner
    prefix is saved, the minor-axis total becomes the outer stage's
    input, and one final ``merge`` applies the combining ⊕.  Every
    step is axis-tagged, so the result lowers/simulates/executes like
    any single-axis schedule.
    """
    if inner.kind != "exclusive" or outer.kind != "exclusive":
        raise ValueError("compose() takes exclusive inner/outer "
                         f"schedules, got {inner.kind!r}/{outer.kind!r}")
    if reduce_.kind != "allreduce":
        raise ValueError(f"compose() needs an allreduce middle "
                         f"schedule, got {reduce_.kind!r}")
    if reduce_.p != inner.p:
        raise ValueError("inner exscan and minor-axis allreduce must "
                         f"share p ({inner.p} != {reduce_.p})")
    o_steps, o_axes = _outer_parts(outer, outer_axis)
    steps = (
        _tag_axis(inner.steps, minor_axis)
        + (RoundStep("stage", reg="inner", init=reduce_.init),)
        + _tag_axis(_ns_regs(reduce_.steps, "r:"), minor_axis)
        + (RoundStep("stage", src="w", init=outer.init),)
        + o_steps
        + (RoundStep("merge", reg="inner"),)
    )
    name = (f"composite({inner.algorithm}+{reduce_.algorithm}"
            f"+{outer.algorithm})")
    return Schedule(name, "exclusive", inner.p * outer.p,
                    init=inner.init, steps=steps,
                    axes=o_axes + ((minor_axis, inner.p),))


def compose_total(inner: Schedule, outer: Schedule, *,
                  minor_axis, outer_axis=None) -> Schedule:
    """Multi-axis "scan_total": the §5 rewrite where the minor-axis
    allreduce IS the inner scan_total's total — no separate reduce
    stage.  Both sub-schedules must be "scan_total" (prefix in
    register ``prefix``, total in W); the result keeps that contract,
    so composition nests for any number of axes."""
    for s, who in ((inner, "inner"), (outer, "outer")):
        if s.kind != "scan_total":
            raise ValueError(f"compose_total needs scan_total "
                             f"sub-schedules; {who} is {s.kind!r}")
    o_steps, o_axes = _outer_parts(outer, outer_axis)
    steps = (
        _tag_axis(_ns_regs(inner.steps, "i:"), minor_axis)
        # W now holds the minor-axis total: it is the outer stage input
        + (RoundStep("stage", src="w", init=outer.init),)
        + o_steps
        # W = grand total; stash it, combine the two partial prefixes,
        # then restore the (prefix in reg, total in W) contract
        + (RoundStep("stage", reg="total", init="o:prefix"),
           RoundStep("merge", reg="i:prefix"),
           RoundStep("stage", reg="prefix", init="total"))
    )
    name = f"composite({inner.algorithm}+{outer.algorithm})"
    return Schedule(name, "scan_total", inner.p * outer.p,
                    init=inner.init, steps=steps,
                    axes=o_axes + ((minor_axis, inner.p),),
                    outputs=("prefix", "$w"))


# ---------------------------------------------------------------------------
# Payload fusion: k concurrent same-kind scans packed into one buffer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PayloadLayout:
    """Packing of k pytree payloads into one flat buffer per leaf slot.

    All payloads share ``treedef``; per leaf slot j the packed buffer
    concatenates every payload's flattened leaf j (``dtypes[j]`` must
    agree across payloads so ⊕ applies uniformly).  ``offsets[i][j]``/
    ``shapes[i][j]`` locate payload i's leaf j inside buffer j;
    ``totals[j]`` is buffer j's element count.  Sound for monoids that
    combine aligned element positions independently
    (``Monoid.segmentable``)."""

    treedef: Any
    dtypes: tuple  # per slot: numpy dtype str, shared by all payloads
    shapes: tuple  # per payload: per slot leaf shape
    offsets: tuple  # per payload: per slot element offset
    totals: tuple  # per slot: total packed elements

    @property
    def n(self) -> int:
        """Number of packed payloads."""
        return len(self.shapes)


def make_layout(xs, *, lead: int = 0) -> PayloadLayout:
    """Build the :class:`PayloadLayout` packing payloads ``xs``
    (``lead`` leading axes — e.g. the simulator's rank axis — are
    excluded from the per-payload shapes)."""
    if not xs:
        raise ValueError("make_layout needs at least one payload")
    _, treedef = jax.tree.flatten(xs[0])
    dtypes = None
    shapes, offsets = [], []
    offs = None
    for x in xs:
        leaves, td = jax.tree.flatten(x)
        if td != treedef:
            raise ValueError(
                f"fused payloads must share one tree structure "
                f"({td} != {treedef})")
        if dtypes is None:
            dtypes = tuple(np.dtype(lf.dtype).str for lf in leaves)
            offs = [0] * len(leaves)
        row_s, row_o = [], []
        for j, lf in enumerate(leaves):
            if np.dtype(lf.dtype).str != dtypes[j]:
                raise ValueError(
                    f"fused payloads must share leaf dtypes; slot {j} "
                    f"has {np.dtype(lf.dtype).str} vs {dtypes[j]}")
            shp = tuple(int(d) for d in lf.shape[lead:])
            row_s.append(shp)
            row_o.append(offs[j])
            offs[j] += int(np.prod(shp, dtype=np.int64))
        shapes.append(tuple(row_s))
        offsets.append(tuple(row_o))
    return PayloadLayout(treedef=treedef, dtypes=dtypes,
                         shapes=tuple(shapes), offsets=tuple(offsets),
                         totals=tuple(offs))


def pack_payloads(layout: PayloadLayout, xs, *, xp=jnp, lead: int = 0):
    """Pack payloads into the layout's flat buffers (one pytree with
    the shared treedef whose leaves are the packed buffers)."""
    flat = [jax.tree.flatten(x)[0] for x in xs]
    if len(flat) != layout.n:
        raise ValueError(f"layout packs {layout.n} payloads, "
                         f"got {len(flat)}")
    bufs = []
    for j in range(len(layout.dtypes)):
        parts = []
        for i in range(layout.n):
            a = xp.asarray(flat[i][j])
            parts.append(a.reshape(a.shape[:lead] + (-1,)))
        bufs.append(xp.concatenate(parts, axis=lead) if len(parts) > 1
                    else parts[0])
    return jax.tree.unflatten(layout.treedef, bufs)


def unpack_payloads(layout: PayloadLayout, packed, *, lead: int = 0):
    """Slice the packed buffers back into the k original payloads."""
    bufs = jax.tree.flatten(packed)[0]
    outs = []
    for i in range(layout.n):
        leaves = []
        for j, buf in enumerate(bufs):
            off = layout.offsets[i][j]
            shp = layout.shapes[i][j]
            size = int(np.prod(shp, dtype=np.int64))
            sl = buf[..., off:off + size]
            leaves.append(sl.reshape(buf.shape[:lead] + shp))
        outs.append(jax.tree.unflatten(layout.treedef, leaves))
    return outs


def fuse(schedules, layout: PayloadLayout) -> Schedule:
    """Fuse k concurrent same-axis/same-kind scans into one schedule:
    the packed payload (per ``layout``) rides the rounds of the
    cheapest compatible schedule, so k scans cost one scan's α·q.

    All schedules must agree on (kind, p, axes) and on their output
    list; executors pack the payload sequence on entry and unpack the
    results on exit — multi-output schedules (scan_total's
    (prefix, total)) unpack to one output tuple per payload."""
    if not schedules:
        raise ValueError("fuse() needs at least one schedule")
    base = min(schedules, key=lambda s: (s.rounds, s.op_applications))
    for s in schedules:
        if (s.kind, s.p, s.axes) != (base.kind, base.p, base.axes):
            raise ValueError(
                "fused schedules must share kind/p/axes; got "
                f"{(s.kind, s.p, s.axes)} vs "
                f"{(base.kind, base.p, base.axes)}")
        if s.outputs != base.outputs:
            raise ValueError(
                "fused schedules must share outputs; got "
                f"{s.outputs} vs {base.outputs}")
        if s.layout is not None:
            raise ValueError("schedule is already fused")
    return dataclasses.replace(
        base, layout=layout,
        algorithm=f"fused[{layout.n}]({base.algorithm})")


def unpack_fused_outputs(layout: PayloadLayout, out, n_outputs: int = 1,
                         *, lead: int = 0):
    """Unpack a fused execution's result back into per-payload results.

    ``n_outputs`` is ``len(schedule.outputs)`` — it cannot be inferred
    from ``out``'s type because tuple-leaf payloads (affine) make a
    single output a tuple too.  Single-output schedules return the
    list of k unpacked payloads; multi-output schedules (scan_total)
    return one tuple per payload — payload i gets
    ``(output0_i, output1_i, ...)``, so a fused scan_total hands every
    request its own (prefix, total)."""
    if n_outputs > 1:
        per_out = [unpack_payloads(layout, o, lead=lead) for o in out]
        return [tuple(po[i] for po in per_out)
                for i in range(layout.n)]
    return unpack_payloads(layout, out, lead=lead)


# ---------------------------------------------------------------------------
# Payload segmentation helpers: each leaf is flattened and split into S
# contiguous element blocks (sound for monoids whose ⊕ combines aligned
# element positions independently — ``Monoid.segmentable``).
# ---------------------------------------------------------------------------


def _jnp_split(a, S: int):
    """Any shape -> (S, ceil(size/S)), flattened and zero-padded."""
    a = jnp.asarray(a).reshape(-1)
    n = a.shape[0]
    k = -(-n // S)
    pad = S * k - n
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,), a.dtype)])
    return a.reshape(S, k)


def _jnp_unsplit(seg, like):
    n = like.size
    return seg.reshape(-1)[:n].reshape(like.shape)


def _np_split(a, S: int):
    a = np.asarray(a).reshape(-1)
    n = a.shape[0]
    k = -(-n // S)
    pad = S * k - n
    if pad:
        a = np.concatenate([a, np.zeros((pad,), a.dtype)])
    return a.reshape(S, k)


def _np_unsplit(seg, like):
    like = np.asarray(like)
    return np.asarray(seg).reshape(-1)[:like.size].reshape(like.shape)


# ---------------------------------------------------------------------------
# Stage-run decomposition shared by the executors: a schedule's steps
# split into control steps (stage/merge) and maximal runs of compute
# steps over one axis (seg_shift and scan_reduce runs kept homogeneous,
# since they carry run-level auxiliary state).
# ---------------------------------------------------------------------------


_STATEFUL = ("seg_shift", "scan_reduce", "block_exchange")


def _stage_runs(steps):
    runs: list = []
    cur: list = []

    def flush():
        nonlocal cur
        if cur:
            runs.append(cur)
            cur = []

    for st in steps:
        if st.kind in ("stage", "merge"):
            flush()
            runs.append(st)
            continue
        if cur and (cur[0].axis != st.axis
                    or (cur[0].kind in _STATEFUL) !=
                    (st.kind in _STATEFUL)
                    or (st.kind in _STATEFUL
                        and cur[0].kind != st.kind)):
            flush()
        cur.append(st)
    flush()
    return runs


def _axis_size(sched: Schedule, axis_tag) -> int:
    if axis_tag is None or not sched.axes:
        return sched.p
    for name, size in sched.axes:
        if name == axis_tag:
            return size
    raise ValueError(
        f"step axis {axis_tag!r} not among schedule axes {sched.axes}")


def _run_seg_count(run, sched: Schedule) -> int:
    return run[0].seg or sched.n_segments


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


class Executor:
    """One interface, three backends: ``execute(schedule, x, monoid)``.

    ``combine`` is the RoundStep ⊕ hook — subclasses may lower it onto
    different compute substrates (the Pallas executor runs it through
    the on-chip block-combine kernel).  ``masked_combine`` is the fused
    masked form a shift round uses: ONE select on the combine output
    (W ← keep ? lo ⊕ hi : hi) instead of the legacy identity-fixup
    pass + combine + select triple."""

    def combine(self, m: monoid_lib.Monoid, lo, hi):
        """⊕ with ``lo`` covering the lower ranks."""
        return m.op(lo, hi)

    def masked_combine(self, m: monoid_lib.Monoid, keep, lo, hi):
        """Fused masked ⊕: where(keep, lo ⊕ hi, hi), selecting once on
        the combine output.  ``lo`` may be ppermute zero-fill on
        non-kept ranks — the select discards it, so no identity fixup
        pass is needed."""
        combined = self.combine(m, lo, hi)
        return jax.tree.map(
            lambda c, h: jnp.where(keep, c, h), combined, hi)

    def exchange_combine(self, m: monoid_lib.Monoid, recv, w, low_side):
        """One non-commutative butterfly round's update: both combine
        orders, selected by the rank's side bit.  The generic path is
        two ⊕ plus a select sweep; the Pallas engine fuses all three
        into one grid pass."""
        lo = self.combine(m, recv, w)
        hi = self.combine(m, w, recv)
        return jax.tree.map(
            lambda a, b: jnp.where(low_side, a, b), lo, hi)

    def scan_reduce_combine(self, m: monoid_lib.Monoid, recv, w,
                            prefix, low_side):
        """One fused exscan+allreduce round's (T, P) register update.
        Returns (new_w, new_prefix).  The generic path launches one ⊕
        per combine plus selects; the Pallas engine batches the pair
        into a single grid pass."""
        if m.commutative:
            prefix = self.masked_combine(m, low_side, recv, prefix)
            w = self.combine(m, recv, w)
            return w, prefix
        new_p = self.combine(m, recv, prefix)
        t_lo = self.combine(m, recv, w)
        t_hi = self.combine(m, w, recv)
        prefix = jax.tree.map(
            lambda a, b: jnp.where(low_side, a, b), new_p, prefix)
        w = jax.tree.map(
            lambda a, b: jnp.where(low_side, a, b), t_lo, t_hi)
        return w, prefix

    def prep_combine(self, m: monoid_lib.Monoid, valid, recv, seg,
                     ident):
        """The segmented ring's forward-prep ⊕: recv ⊕ V[s] where
        valid, else plain V[s].  Generic path: identity-fixup select
        then combine (two payload sweeps); the Pallas engine runs it
        as one masked-combine pass."""
        base = jax.tree.map(
            lambda t, i: jnp.where(valid, t, i), recv, ident)
        return self.combine(m, base, seg)

    def _note_round_kernels(self, st: "RoundStep",
                            m: monoid_lib.Monoid):
        """Stats hook: executors that lower ⊕ onto on-chip kernels
        record their launch/HBM-pass counts here (no-op otherwise)."""

    def execute(self, schedule: Schedule, x, m: monoid_lib.Monoid):
        raise NotImplementedError


def _ppermute_up(tree, axis_name, skip: int, p: int):
    """The raw ppermute of one shift round (no stats recording):
    rank r sends to r+skip (r+skip < p); non-receiving ranks get
    zero-fill, which callers mask away."""
    perm = [(r, r + skip) for r in range(p - skip)]
    return jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm), tree)


def _shift_up(tree, axis_name, skip: int, p: int):
    """One communication round: rank r sends to r+skip (r+skip < p).

    Non-receiving ranks get zero-fill from ppermute; callers mask."""
    _record_round(tree)
    return _ppermute_up(tree, axis_name, skip, p)


class SPMDExecutor(Executor):
    """Executes a schedule as the SPMD ppermute program of its rounds.

    Must run where the schedule's axis names are bound (inside
    ``shard_map``); ``axis_name`` is the default for untagged steps.
    Composed multi-axis schedules carry per-step axis tags and run as
    one program.  MPI rank conditionals become the schedule's receive
    masks: a rank with no source "receives" the monoid identity, making
    the combine a no-op (DESIGN.md §2) — implemented as ONE select on
    the combine output (:meth:`Executor.masked_combine`), not a
    separate identity-fixup pass.

    Homogeneous runs execute through compiled round tables: the
    segmented ring's rounds all share the r → r+1 neighbour
    permutation, so the whole run rolls into a single ``lax.scan``
    body over the stacked per-round segment indices — trace size O(1)
    in p and S — with the ring double-buffered (round t's ppermute is
    issued before round t−1's store; see :meth:`_run_segmented`).
    ``unrolled=True`` keeps one trace site per ring round (the legacy
    form) for the rolled-vs-unrolled bit-identity law; varying-offset
    rounds (shift chains, butterfly exchanges) always trace one
    ``ppermute`` site each, as XLA permutations are static."""

    def __init__(self, axis_name=None, *, unrolled: bool = False):
        self.axis_name = axis_name
        self.unrolled = unrolled

    def execute(self, sched: Schedule, x, m: monoid_lib.Monoid):
        if sched.layout is not None:
            packed = pack_payloads(sched.layout, list(x), xp=jnp)
            out = self._execute(sched, packed, m)
            return unpack_fused_outputs(sched.layout, out,
                                        len(sched.outputs))
        return self._execute(sched, x, m)

    def _execute(self, sched: Schedule, x, m: monoid_lib.Monoid):
        regs: dict = {}
        w = x if sched.init == "x" else m.identity_like(x)
        for run in _stage_runs(sched.steps):
            if isinstance(run, RoundStep):  # control step
                st = run
                if st.kind == "stage":
                    if st.reg:
                        regs[st.reg] = w
                    if st.src == "w":
                        x = w
                    if st.init == "identity":
                        w = m.identity_like(x)
                    elif st.init == "x":
                        w = x
                    elif st.init != "w":
                        w = regs[st.init]
                else:  # merge
                    other = x if st.reg == "$x" else regs[st.reg]
                    w = self.combine(m, w, other)
                    _record_op()
                    self._note_round_kernels(st, m)
                continue
            axis = run[0].axis if run[0].axis is not None \
                else self.axis_name
            p = _axis_size(sched, run[0].axis)
            if run[0].kind == "seg_shift":
                w = self._run_segmented(run, x, m, axis, p,
                                        _run_seg_count(run, sched))
            elif run[0].kind == "scan_reduce":
                w, prefix = self._run_scan_reduce(run, x, w, m, axis, p)
                if run[-1].reg:
                    regs[run[-1].reg] = prefix
            elif run[0].kind == "block_exchange":
                w = self._run_block(run, x, m, axis, p)
            else:
                w = self._run_steps(run, x, w, m, axis, p)
        outs = tuple(w if o == "$w" else regs[o]
                     for o in sched.outputs)
        return outs[0] if len(outs) == 1 else outs

    def _run_steps(self, steps, x, w, m, axis, p):
        r = lax.axis_index(axis)
        gathered = None
        for st in steps:
            if st.kind == "shift":
                if st.send == "x":
                    src = x
                elif st.send == "w":
                    src = w
                else:  # "w_op_x": rank 0's W is identity -> sends V
                    src = self.combine(m, w, x)
                    _record_op()
                recv = _shift_up(src, axis, st.skip, p)
                has = (r >= st.bound) if st.mask == "ge" else \
                    (r > st.bound)
                if st.combine == "op":
                    # fused masked combine: one select on the combine
                    # output; ppermute zero-fill on maskless ranks is
                    # discarded by the select, no identity fixup pass
                    w = self.masked_combine(m, has, recv, w)
                    _record_op()
                else:  # "copy"
                    w = jax.tree.map(
                        lambda c, v: jnp.where(has, c, v), recv, w)
            elif st.kind == "exchange":
                perm = [(i, i ^ st.skip) for i in range(p)]
                _record_round(w)
                recv = jax.tree.map(
                    lambda t: lax.ppermute(t, axis, perm), w)
                if m.commutative:
                    # both combine orders agree: compute one (2→1 ⊕)
                    w = self.combine(m, recv, w)
                    _record_op()
                else:
                    low_side = (r & st.skip) != 0  # partner is lower
                    w = self.exchange_combine(m, recv, w, low_side)
                    _record_op(2)
            elif st.kind == "allgather":
                _record_allgather()
                gathered = jax.tree.map(
                    lambda t: lax.all_gather(t, axis, axis=0), x)
            elif st.kind == "fold":
                ident = m.identity_like(x)

                def body(i, acc):
                    vi = jax.tree.map(lambda g: g[i], gathered)
                    take = i < r
                    combined = self.combine(m, acc, vi)
                    return jax.tree.map(
                        lambda c, a: jnp.where(take, c, a), combined,
                        acc)

                _record_op(st.fold_count)  # body executes fold_count×
                w = lax.fori_loop(0, st.fold_count, body, ident)
            elif st.kind == "bcast":
                _record_allgather()
                w = jax.tree.map(
                    lambda t: lax.all_gather(t, axis, axis=0)[st.root],
                    w)
            self._note_round_kernels(st, m)
        return w

    def _run_scan_reduce(self, steps, x, w, m, axis, p):
        """The fused exscan+allreduce butterfly: W carries the window
        total T (entering as V via init="x"), the auxiliary P the
        exclusive prefix; each round exchanges T with r^skip and the
        lower side folds the received total into P as well.  The
        identity init of P is hoisted out of the round loop; for
        commutative monoids the two T combine orders collapse into
        one (3→2 ⊕ per round)."""
        r = lax.axis_index(axis)
        prefix = m.identity_like(x)  # hoisted: built once per run
        for st in steps:
            perm = [(i, i ^ st.skip) for i in range(p)]
            _record_round(w)
            recv = jax.tree.map(
                lambda t: lax.ppermute(t, axis, perm), w)
            low_side = (r & st.skip) != 0  # partner covers lower ranks
            w, prefix = self.scan_reduce_combine(m, recv, w, prefix,
                                                 low_side)
            _record_op(2 if m.commutative else 3)
            self._note_round_kernels(st, m)
        return w, prefix

    def _run_segmented(self, steps, x, m, axis, p, S):
        """The pipelined ring: stream S leaf row-blocks through
        neighbour rounds; per-rank segment indices are dynamic
        (rank r handles segment t+1−r in round t).

        All rounds share the r → r+1 neighbour permutation, so the run
        compiles to a round table: the per-round segment indices
        ``t`` stack into one array and a single ``lax.scan`` body
        executes every round — trace size O(1) in p and S.  The body
        is double-buffered: round t's ppermute is issued FIRST, then
        round t−1's received segment (the pending buffer in the carry)
        is stored, so XLA overlaps the neighbour communication with
        the previous round's store; the last pending segment drains
        after the loop.  The segment-shaped identity is built once,
        outside the rounds.  ``unrolled=True`` runs the legacy
        one-trace-site-per-round loop instead (bit-identical outputs;
        the property the tests enforce)."""
        r = lax.axis_index(axis)
        V = jax.tree.map(lambda a: _jnp_split(a, S), x)
        R = m.identity_like(V)
        cur = jax.tree.map(lambda a: a[0], V)  # rank 0 sends V[0] first
        # hoisted out of the rounds: ONE segment-shaped identity
        ident = m.identity_like(cur)
        # the loop body below is traced once; stats mean executions
        for st in steps:
            _record_round(cur)
            if st.prep:
                _record_op()
            self._note_round_kernels(st, m)

        def seg_of(tree, slot):
            return jax.tree.map(
                lambda t: lax.dynamic_slice_in_dim(t, slot, 1, 0)[0],
                tree)

        def store(acc, seg, valid, slot):
            old = jax.tree.map(
                lambda t: lax.dynamic_slice_in_dim(t, slot, 1, 0), acc)
            upd = jax.tree.map(
                lambda o, c: jnp.where(valid, c[None], o), old, seg)
            return jax.tree.map(
                lambda t, u: lax.dynamic_update_slice_in_dim(
                    t, u, slot, 0), acc, upd)

        def prep(recv, valid, sc):
            # forward Q = recv ⊕ V[s] next round (rank 0: the identity
            # base makes this plain V[t+1], its next raw segment)
            return self.prep_combine(m, valid, recv, seg_of(V, sc),
                                     ident)

        if self.unrolled:
            for st in steps:
                s_recv = st.t + 1 - r
                valid = (r >= 1) & (s_recv >= 0) & (s_recv < S)
                sc = jnp.clip(s_recv, 0, S - 1)
                recv = _ppermute_up(cur, axis, 1, p)
                R = store(R, recv, valid, sc)
                if st.prep:
                    cur = prep(recv, valid, sc)
            return jax.tree.map(_jnp_unsplit, R, x)

        def body(carry, t):
            cur, pend, pvalid, pslot, R = carry
            # round t's communication is issued before round t−1's
            # store — the pending double-buffer XLA overlaps with it
            recv = _ppermute_up(cur, axis, 1, p)
            R = store(R, pend, pvalid, pslot)
            s_recv = t + 1 - r
            valid = (r >= 1) & (s_recv >= 0) & (s_recv < S)
            sc = jnp.clip(s_recv, 0, S - 1)
            cur = prep(recv, valid, sc)
            return (cur, recv, valid, sc, R), None

        # The rolled body preps every iteration; the final (drain)
        # round's prep is dead — its result never leaves the loop —
        # so stats count the IR's p−3+S preps, the result-path ⊕.
        ts = jnp.asarray([st.t for st in steps], dtype=jnp.int32)
        init = (cur, ident, jnp.zeros((), bool),
                jnp.zeros((), jnp.int32), R)
        (_, pend, pvalid, pslot, R), _ = lax.scan(body, init, ts)
        R = store(R, pend, pvalid, pslot)  # drain the last round
        return jax.tree.map(_jnp_unsplit, R, x)

    def _run_block(self, steps, x, m, axis, p):
        """The block-distributed exscan family (see
        :func:`_build_block`).  The payload lives split into R = 2^t
        rows; per-rank row offsets are traced, so each phase round is
        one static ``ppermute`` over the M virtual ranks' physical
        representatives plus static-size dynamic row slices — O(log p)
        trace sites, like the other doubling chains.  Surplus ranks
        (the fold's even partners) idle through the core phases: they
        are in no permutation, and their locally-computed garbage is
        never observed."""
        r = lax.axis_index(axis)
        st0 = steps[0]
        R = st0.seg
        t_eff = R.bit_length() - 1
        rho = st0.bound
        M = p - rho
        reps = [2 * u + 1 if u < rho else u + rho for u in range(M)]
        Y = jax.tree.map(lambda a: _jnp_split(a, R), x)
        v = jnp.where(r < 2 * rho, r // 2, r - rho)
        folded = r < 2 * rho
        odd_folded = folded & (r % 2 == 1)
        even_folded = folded & (r % 2 == 0)
        lo_in = None  # fold: the saved received pair value
        O_saved: dict = {}  # up round k: own pre-combine kept half
        S_saved: dict = {}  # up round k: received partner half
        T = P = None

        def permute(tree, perm):
            return jax.tree.map(
                lambda a: lax.ppermute(a, axis, perm), tree)

        def rows_of(tree, start, n):
            return jax.tree.map(
                lambda a: lax.dynamic_slice_in_dim(a, start, n, 0),
                tree)

        for st in steps:
            if st.phase == "fold":
                _record_round(Y)
                recv = permute(
                    Y, [(2 * i, 2 * i + 1) for i in range(rho)])
                lo_in = recv
                Y = self.masked_combine(m, odd_folded, recv, Y)
            elif st.phase == "up":
                k = st.t
                half = R >> (k + 1)
                bit = (v >> k) & 1
                # the current buffer IS the owned range, so the kept/
                # sent halves are buffer-local: low or high by bit_k(v)
                kept = rows_of(Y, bit * half, half)
                sent = rows_of(Y, (1 - bit) * half, half)
                _record_round(sent)
                recv = permute(
                    sent,
                    [(reps[u], reps[u ^ (1 << k)]) for u in range(M)])
                O_saved[k], S_saved[k] = kept, recv
                if m.commutative:
                    Y = self.combine(m, recv, kept)
                else:
                    # bit set: the partner covers lower virtual ranks
                    Y = self.exchange_combine(m, recv, kept, bit != 0)
            elif st.phase == "mid":
                if T is None:
                    T = Y  # the own-row window fold
                    P = m.identity_like(T)
                w_idx = v >> t_eff
                s = st.skip  # window stride
                d = s << t_eff  # virtual-rank distance
                perm = [(reps[u], reps[u + d]) for u in range(M - d)]
                if st.combine == "copy":
                    _record_round(T)
                    recv = permute(T, perm)
                    P = jax.tree.map(
                        lambda c, h: jnp.where(w_idx >= s, c, h),
                        recv, P)
                else:
                    # window 0's P is the identity, so it sends plain T
                    send = self.combine(m, P, T)
                    _record_round(send)
                    recv = permute(send, perm)
                    P = self.masked_combine(m, w_idx >= s, recv, P)
            elif st.phase == "down":
                j = st.t
                half = R >> (j + 1)
                if P is None:  # single window: no mid rounds ran
                    P = m.identity_like(Y)
                bit = (v >> j) & 1
                lower = bit == 0
                prepped = self.combine(m, P, O_saved[j])
                send = jax.tree.map(
                    lambda a, b: jnp.where(lower, a, b), prepped, P)
                _record_round(send)
                recv = permute(
                    send,
                    [(reps[u], reps[u ^ (1 << j)]) for u in range(M)])
                adj = self.combine(m, P, S_saved[j])
                own = jax.tree.map(
                    lambda pp, a: jnp.where(lower, pp, a), P, adj)
                # widen: own rows keep their side of the doubled
                # range, the received sibling rows fill the other
                P = jax.tree.map(
                    lambda o, c: jnp.where(
                        lower,
                        jnp.concatenate([o, c], axis=0),
                        jnp.concatenate([c, o], axis=0)), own, recv)
            else:  # unfold
                _record_round(P)
                recv = permute(
                    P, [(2 * i + 1, 2 * i) for i in range(rho)])
                adj = self.combine(m, P, lo_in)
                P = jax.tree.map(
                    lambda a, c, pp: jnp.where(
                        odd_folded, a,
                        jnp.where(even_folded, c, pp)), adj, recv, P)
            _record_op(st.op_count(m.commutative))
            self._note_round_kernels(st, m)
        if P is None:  # p == 1: no steps at all, but guard anyway
            P = Y
        return jax.tree.map(_jnp_unsplit, P, x)


class PallasExecutor(SPMDExecutor):
    """SPMD executor whose RoundStep ⊕ hooks run on-chip through the
    single-pass scan engine (``kernels.scan_engine``, DESIGN §7):
    elementwise monoids (``Monoid.leaf_op``) and the affine pair are
    tiled through VMEM; other structured monoids (matmul) fall back to
    the plain op.

    ``fused=True`` (default) is the engine's fused round path: a
    round's combine order(s), its receive-mask/side select, and the
    result store run in ONE grid pass, with a round's same-dtype
    payload leaves (fused-layout slots, scan_reduce's (P, T) pair)
    batched into a single ``pallas_call``.  ``fused=False`` keeps the
    legacy per-round per-leaf ``block_combine`` launches with
    host-graph selects — the baseline ``benchmarks/exec_bench.py``
    measures the fusion against.  Either mode records its kernel
    launch / HBM-pass counts into :func:`collect_stats`
    (``kernel_launches`` / ``hbm_passes``), matching
    :meth:`Schedule.kernel_passes` by construction.

    Note: ``shard_map`` has no replication rule for ``pallas_call`` —
    wrap the call site with ``check_vma=False`` (``check_rep=False`` on
    older jax)."""

    def __init__(self, axis_name=None, *, interpret: bool | None = None,
                 block_rows: int = 256, fused: bool = True):
        super().__init__(axis_name)
        self.interpret = interpret
        self.block_rows = block_rows
        self.fused = fused

    def _interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def _engine(self):
        from repro.kernels import scan_engine
        return scan_engine

    def combine(self, m: monoid_lib.Monoid, lo, hi):
        se = self._engine()
        if self.fused:
            out = se.tree_combine(m, lo, hi,
                                  block_rows=self.block_rows,
                                  interpret=self._interpret())
            if out is not None:
                return out
        elif m.leaf_op is not None:
            interpret = self._interpret()
            return jax.tree.map(
                lambda a, b: se.block_combine(
                    a, b, m.leaf_op, block_rows=self.block_rows,
                    interpret=interpret), lo, hi)
        return super().combine(m, lo, hi)

    def masked_combine(self, m: monoid_lib.Monoid, keep, lo, hi):
        """The fused masked path: select(keep, a ⊕ b, b) in ONE pass
        through VMEM (the kernel's ``keep`` operand), instead of a
        combine kernel launch followed by a host-graph select."""
        se = self._engine()
        if self.fused:
            out = se.tree_combine(m, lo, hi, keep=keep,
                                  block_rows=self.block_rows,
                                  interpret=self._interpret())
            if out is not None:
                return out
        elif m.leaf_op is not None:
            interpret = self._interpret()
            return jax.tree.map(
                lambda a, b: se.block_combine(
                    a, b, m.leaf_op, keep=keep,
                    block_rows=self.block_rows, interpret=interpret),
                lo, hi)
        return super().masked_combine(m, keep, lo, hi)

    def exchange_combine(self, m: monoid_lib.Monoid, recv, w, low_side):
        if self.fused:
            out = self._engine().tree_exchange(
                m, recv, w, low_side, block_rows=self.block_rows,
                interpret=self._interpret())
            if out is not None:
                return out
        return super().exchange_combine(m, recv, w, low_side)

    def scan_reduce_combine(self, m: monoid_lib.Monoid, recv, w,
                            prefix, low_side):
        if self.fused:
            out = self._engine().tree_scan_reduce(
                m, recv, w, prefix, low_side,
                block_rows=self.block_rows,
                interpret=self._interpret())
            if out is not None:
                return out
        return super().scan_reduce_combine(m, recv, w, prefix,
                                           low_side)

    def prep_combine(self, m: monoid_lib.Monoid, valid, recv, seg,
                     ident):
        if self.fused:
            # one masked-combine pass: valid ? recv ⊕ V[s] : V[s]
            # (identity absorption folds the fixup select away)
            return self.masked_combine(m, valid, recv, seg)
        return super().prep_combine(m, valid, recv, seg, ident)

    def _note_round_kernels(self, st: RoundStep, m: monoid_lib.Monoid):
        if not self._engine().supports(m):
            return  # plain-XLA fallback: no kernel accounting
        _record_kernel(
            st.kernel_launches(m.commutative, fused=self.fused),
            st.kernel_passes(m.commutative, fused=self.fused))


class SimulatorExecutor(Executor):
    """Pure-numpy rank-by-rank execution of a schedule at any p — no
    devices, no tracing.  Leaves carry a leading rank axis of size p
    (row-major over the schedule's axes for composed multi-axis
    schedules: each run's rounds act within independent axis groups,
    exactly like MPI communicator splits).

    Records the same aggregate stats as the SPMD executor into the
    ambient :func:`collect_stats` context, so plan-vs-execution drift is
    checkable host-side (dry-run, benchmark ``--check`` modes)."""

    def execute(self, sched: Schedule, x, m: monoid_lib.Monoid):
        op = monoid_lib.NUMPY_OPS.get(m.name, m.op)
        ident_fn = monoid_lib.NUMPY_IDENTITY.get(m.name)
        if ident_fn is None:
            def ident_fn(t):
                return jax.tree.map(np.asarray, m.identity_like(t))

        if sched.layout is not None:
            xs = [jax.tree.map(np.asarray, xi) for xi in x]
            packed = pack_payloads(sched.layout, xs, xp=np, lead=1)
            out = self._execute(sched, packed, m, op, ident_fn)
            return unpack_fused_outputs(sched.layout, out,
                                        len(sched.outputs), lead=1)
        return self._execute(sched, x, m, op, ident_fn)

    def _execute(self, sched, x, m, op, ident_fn):
        p = sched.p
        if p == 0:
            return x
        X = [jax.tree.map(lambda a: np.asarray(a)[q], x)
             for q in range(p)]
        if sched.init == "x":
            W = [jax.tree.map(np.copy, v) for v in X]
        else:
            W = [ident_fn(v) for v in X]
        regs: dict = {}
        for run in _stage_runs(sched.steps):
            if isinstance(run, RoundStep):  # control step
                st = run
                if st.kind == "stage":
                    if st.reg:
                        regs[st.reg] = list(W)
                    if st.src == "w":
                        X = list(W)
                    if st.init == "identity":
                        W = [ident_fn(v) for v in X]
                    elif st.init == "x":
                        W = [jax.tree.map(np.copy, v) for v in X]
                    elif st.init != "w":
                        W = list(regs[st.init])
                else:  # merge
                    other = X if st.reg == "$x" else regs[st.reg]
                    _record_op()
                    W = [op(W[q], other[q]) for q in range(p)]
                continue
            groups = _axis_groups(sched, run[0].axis)
            if run[0].kind == "seg_shift":
                self._run_segmented(run, X, W, op, ident_fn, groups,
                                    _run_seg_count(run, sched))
            elif run[0].kind == "block_exchange":
                self._run_block(run, X, W, op, ident_fn, groups,
                                m.commutative)
            elif run[0].kind == "scan_reduce":
                prefix = self._run_scan_reduce(run, X, W, op, ident_fn,
                                               groups, m.commutative)
                if run[-1].reg:
                    regs[run[-1].reg] = prefix
            else:
                self._run_steps(run, X, W, op, ident_fn, groups,
                                m.commutative)
        outs = []
        for o in sched.outputs:
            vals = W if o == "$w" else regs[o]
            outs.append(jax.tree.map(
                lambda *ws: np.stack(ws, axis=0), *vals))
        return outs[0] if len(outs) == 1 else tuple(outs)

    def _run_steps(self, steps, X, W, op, ident_fn, groups,
                   commutative=False):
        gathered: dict = {}
        for st in steps:
            if st.kind == "shift":
                recorded = False
                for g in groups:
                    pg = len(g)
                    if st.send == "x":
                        payload = [X[i] for i in g]
                    elif st.send == "w":
                        payload = [W[i] for i in g]
                    else:
                        payload = [op(W[i], X[i]) for i in g]
                    if not recorded:
                        if st.send == "w_op_x":
                            _record_op()
                        _record_round(payload[0])
                        if st.combine == "op":
                            _record_op()
                        recorded = True
                    ok = (lambda q: q >= st.bound) if st.mask == "ge" \
                        else (lambda q: q > st.bound)
                    old = [W[i] for i in g]
                    for q in range(st.skip, pg):
                        if ok(q):
                            recv = payload[q - st.skip]
                            W[g[q]] = recv if st.combine == "copy" \
                                else op(recv, old[q])
            elif st.kind == "exchange":
                _record_round(W[groups[0][0]])
                _record_op(st.op_count(commutative))
                for g in groups:
                    old = [W[i] for i in g]
                    for q, i in enumerate(g):
                        j = q ^ st.skip
                        # commutative monoids compute one combine
                        # order (2→1 ⊕ in SPMD lockstep); order here
                        # matches the SPMD executor bit-for-bit
                        W[i] = op(old[j], old[q]) if (
                            commutative or q & st.skip) \
                            else op(old[q], old[j])
            elif st.kind == "allgather":
                _record_allgather()
                for gi, g in enumerate(groups):
                    gathered[gi] = [X[i] for i in g]
            elif st.kind == "fold":
                _record_op(st.fold_count)
                for gi, g in enumerate(groups):
                    got = gathered[gi]
                    for q, i in enumerate(g):
                        acc = ident_fn(X[i])
                        for t in range(q):
                            acc = op(acc, got[t])
                        W[i] = acc
            elif st.kind == "bcast":
                _record_allgather()
                for g in groups:
                    root_val = W[g[st.root]]
                    for i in g:
                        W[i] = root_val

    def _run_scan_reduce(self, steps, X, W, op, ident_fn, groups,
                         commutative=False):
        prefix = [ident_fn(v) for v in X]
        for st in steps:
            _record_round(W[groups[0][0]])
            _record_op(st.op_count(commutative))
            for g in groups:
                old = [W[i] for i in g]
                for q, i in enumerate(g):
                    j = q ^ st.skip
                    if q & st.skip:  # partner covers lower ranks
                        prefix[i] = op(old[j], prefix[i])
                        W[i] = op(old[j], old[q])
                    else:
                        # commutative: one combine order (3→2 ⊕)
                        W[i] = op(old[j], old[q]) if commutative \
                            else op(old[q], old[j])
        return prefix

    def _run_segmented(self, steps, X, W, op, ident_fn, groups, S):
        state = []
        seg_of = (lambda v, s: jax.tree.map(lambda a: a[s], v))
        for g in groups:
            Vs = [jax.tree.map(lambda a: _np_split(a, S), X[i])
                  for i in g]
            R = [ident_fn(v) for v in Vs]
            cur = [jax.tree.map(lambda a: a[0].copy(), v) for v in Vs]
            # hoisted out of the rounds: one segment-shaped identity
            # per rank (was rebuilt every round for pre-window ranks)
            idents = [ident_fn(seg_of(v, 0)) for v in Vs]
            state.append((Vs, R, cur, idents))
        for st in steps:
            _record_round(state[0][2][0])
            if st.prep:
                _record_op()
            for gi, g in enumerate(groups):
                Vs, R, cur, idents = state[gi]
                pg = len(g)
                recv = [None] + cur[:-1]  # neighbour shift r-1 -> r
                ncur = list(cur)
                for q in range(pg):
                    s = st.t + 1 - q
                    valid = q >= 1 and 0 <= s < S
                    sc = min(max(s, 0), S - 1)
                    base = recv[q] if valid else idents[q]
                    if valid:
                        R[q] = jax.tree.map(
                            lambda acc, b: _np_set_seg(acc, sc, b),
                            R[q], base)
                    if st.prep:
                        ncur[q] = op(base, seg_of(Vs[q], sc))
                state[gi] = (Vs, R, ncur, idents)
        for gi, g in enumerate(groups):
            Vs, R, _, _ = state[gi]
            for q, i in enumerate(g):
                W[i] = jax.tree.map(_np_unsplit, R[q],
                                    jax.tree.map(np.asarray, X[i]))

    def _run_block(self, steps, X, W, op, ident_fn, groups,
                   commutative=False):
        """Rank-by-rank twin of ``SPMDExecutor._run_block``: state is
        kept per *virtual* rank (the fold's even partners idle through
        the core phases), combine orders match the SPMD executor
        bit-for-bit, and each step records one representative
        transmitted tree — ``rows`` rows of the split payload, the
        IR's byte law."""
        st0 = steps[0]
        R = st0.seg
        t_eff = R.bit_length() - 1
        rho = st0.bound
        pg = len(groups[0])
        M = pg - rho
        reps = [2 * u + 1 if u < rho else u + rho for u in range(M)]
        sl = (lambda tree, a, n:
              jax.tree.map(lambda x_: x_[a:a + n], tree))
        state = []
        for g in groups:
            Vs = [jax.tree.map(lambda a: _np_split(a, R), X[i])
                  for i in g]
            state.append({
                "Vs": Vs, "lo": [None] * pg,
                "Y": [jax.tree.map(np.copy, Vs[reps[u]])
                      for u in range(M)],
                "O": {}, "S": {},
                "T": None, "P": None, "even": None,
            })
        for st in steps:
            _record_round(jax.tree.map(lambda a: a[:st.rows],
                                       state[0]["Vs"][0]))
            _record_op(st.op_count(commutative))
            for s_ in state:
                Vs, Y = s_["Vs"], s_["Y"]
                if st.phase == "fold":
                    for u in range(rho):
                        s_["lo"][2 * u + 1] = Vs[2 * u]
                        Y[u] = op(Vs[2 * u], Y[u])
                elif st.phase == "up":
                    k = st.t
                    half = R >> (k + 1)
                    kept, sent = [], []
                    for u in range(M):
                        bit = (u >> k) & 1
                        # buffer-local halves: the current buffer IS
                        # the owned row range
                        kept.append(sl(Y[u], bit * half, half))
                        sent.append(sl(Y[u], (1 - bit) * half, half))
                    recvs = [sent[u ^ (1 << k)] for u in range(M)]
                    s_["O"][k], s_["S"][k] = kept, recvs
                    for u in range(M):
                        bit = (u >> k) & 1
                        Y[u] = op(recvs[u], kept[u]) \
                            if (commutative or bit) \
                            else op(kept[u], recvs[u])
                elif st.phase == "mid":
                    if s_["T"] is None:
                        s_["T"] = list(Y)
                        s_["P"] = [ident_fn(y) for y in Y]
                    T, P = s_["T"], s_["P"]
                    s = st.skip
                    d = s << t_eff
                    if st.combine == "copy":
                        send = T
                    else:
                        send = [op(P[u], T[u]) for u in range(M)]
                    s_["P"] = [
                        (send[u - d] if st.combine == "copy"
                         else op(send[u - d], P[u]))
                        if (u >> t_eff) >= s else P[u]
                        for u in range(M)]
                elif st.phase == "down":
                    j = st.t
                    if s_["P"] is None:  # single window: no mid ran
                        s_["P"] = [ident_fn(y) for y in Y]
                    P, O, S2 = s_["P"], s_["O"][j], s_["S"][j]
                    send = [P[u] if (u >> j) & 1
                            else op(P[u], O[u]) for u in range(M)]
                    newP = []
                    for u in range(M):
                        bit = (u >> j) & 1
                        recv = send[u ^ (1 << j)]
                        own = op(P[u], S2[u]) if bit else P[u]
                        a, b = (own, recv) if bit == 0 \
                            else (recv, own)
                        newP.append(jax.tree.map(
                            lambda x_, y_: np.concatenate(
                                [x_, y_], axis=0), a, b))
                    s_["P"] = newP
                else:  # unfold
                    P = s_["P"]
                    # even partners get the pre-adjust prefix (copy)
                    s_["even"] = [P[u] for u in range(rho)]
                    for u in range(rho):
                        P[u] = op(P[u], s_["lo"][2 * u + 1])
        for gi, g in enumerate(groups):
            s_ = state[gi]
            for u in range(M):
                i = g[reps[u]]
                W[i] = jax.tree.map(
                    _np_unsplit, s_["P"][u],
                    jax.tree.map(np.asarray, X[i]))
            for u in range(rho):
                i = g[2 * u]
                W[i] = jax.tree.map(
                    _np_unsplit, s_["even"][u],
                    jax.tree.map(np.asarray, X[i]))


def _axis_groups(sched: Schedule, axis_tag):
    """Independent rank groups of one axis of a (possibly composed)
    schedule: flat ranks are row-major over ``sched.axes``; a step over
    axis j acts within each group obtained by fixing every other
    coordinate — the simulator twin of a named-axis collective."""
    p = sched.p
    if axis_tag is None or not sched.axes:
        return [list(range(p))]
    names = [name for name, _ in sched.axes]
    sizes = [size for _, size in sched.axes]
    j = names.index(axis_tag)
    strides = [1] * len(sizes)
    for i in range(len(sizes) - 2, -1, -1):
        strides[i] = strides[i + 1] * sizes[i + 1]
    others = [range(s) for i, s in enumerate(sizes) if i != j]
    groups = []
    for combo in itertools.product(*others):
        coords = list(combo)
        coords.insert(j, 0)
        base = sum(c * strides[i] for i, c in enumerate(coords))
        groups.append([base + k * strides[j] for k in range(sizes[j])])
    return groups


def _np_set_seg(acc, s: int, value):
    acc = np.asarray(acc).copy()
    acc[s] = value
    return acc


# ---------------------------------------------------------------------------
# Trace-size accounting (the compiled-round-table win, measurable)
# ---------------------------------------------------------------------------


def jaxpr_eqn_count(jaxpr) -> int:
    """Total equation count of a (closed) jaxpr, including nested
    sub-jaxprs (a rolled ``lax.scan`` body counts once — the honest
    metric for the round-table trace-size win; an unrolled ring pays
    its body once per round)."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eq in jaxpr.eqns:
        n += 1
        for v in eq.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for sub in vs:
                if hasattr(sub, "jaxpr") or hasattr(sub, "eqns"):
                    n += jaxpr_eqn_count(sub)
    return n


def trace_eqn_count(sched: Schedule, m: monoid_lib.Monoid, x, *,
                    axis_name="x", mesh=None,
                    unrolled: bool = False) -> int:
    """Equation count of the schedule's traced SPMD program (no
    compilation, no execution — ``jax.make_jaxpr`` under
    ``shard_map``).  ``x`` carries a leading rank axis of size p;
    requires a mesh (or enough devices to build one) spanning p."""
    from jax import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    if mesh is None:
        devs = jax.devices()
        if len(devs) < sched.p:
            raise RuntimeError(
                f"tracing a p={sched.p} schedule needs {sched.p} "
                f"devices, have {len(devs)}")
        mesh = Mesh(np.array(devs[:sched.p]).reshape(sched.p),
                    (axis_name,))
    ex = SPMDExecutor(axis_name, unrolled=unrolled)
    specs = jax.tree.map(lambda _: P(axis_name), x)
    fn = shard_map(lambda v: ex.execute(sched, v, m), mesh=mesh,
                   in_specs=(specs,), out_specs=specs)
    return jaxpr_eqn_count(jax.make_jaxpr(fn)(x))


# ---------------------------------------------------------------------------
# Host-side plan verification (dry-run / benchmark drift checks)
# ---------------------------------------------------------------------------


def _witness_payload(name: str, p: int, n0: int, seed: int):
    rng = np.random.default_rng(seed)
    if name == "affine":
        return (rng.standard_normal((p, n0)),
                rng.standard_normal((p, n0)))
    if name == "matmul":
        return rng.standard_normal((p, 4, 4)) * 0.5
    if name in ("add", "xor"):
        return rng.integers(0, 1 << 30, size=(p, n0)).astype(np.int64)
    return rng.standard_normal((p, n0))


def _host_reference(kind: str, x, op, ident_fn, p: int):
    V = [jax.tree.map(lambda a: np.asarray(a)[q], x) for q in range(p)]
    if kind == "scan_total":
        return (_host_reference("exclusive", x, op, ident_fn, p),
                _host_reference("allreduce", x, op, ident_fn, p))
    out = []
    if kind == "exclusive":
        acc = ident_fn(V[0])
        for q in range(p):
            out.append(acc)
            acc = op(acc, V[q])
    elif kind == "inclusive":
        acc = ident_fn(V[0])
        for q in range(p):
            acc = op(acc, V[q])
            out.append(acc)
    else:  # allreduce
        acc = ident_fn(V[0])
        for q in range(p):
            acc = op(acc, V[q])
        out = [acc] * p
    return jax.tree.map(lambda *ws: np.stack(ws, axis=0), *out)


def _max_seg(sched: Schedule) -> int:
    return max((st.seg or sched.n_segments for st in sched.steps
                if st.kind == "seg_shift"), default=1)


def expected_round_bytes(sched: Schedule, per_rank) -> int:
    """The schedule's per-round byte law summed over its rounds: one
    m/S-byte segment per pipelined ring round, the full payload per
    shift/exchange/scan_reduce round (all-gathers are accounted
    separately, as in ``ScanPlan.bytes_on_wire``)."""
    leaves = [np.asarray(t) for t in jax.tree.leaves(per_rank)]
    total = 0
    for st in sched.steps:
        if not st.is_round:
            continue
        if st.kind == "seg_shift":
            S = st.seg or sched.n_segments
            total += sum(-(-t.size // S) * t.dtype.itemsize
                         for t in leaves)
        elif st.kind == "block_exchange":
            total += sum(st.rows * -(-t.size // st.seg)
                         * t.dtype.itemsize for t in leaves)
        else:
            total += sum(t.size * t.dtype.itemsize for t in leaves)
    return total


def verify_plan(plan, *, rank_elems: int = 2, seed: int = 0) -> dict:
    """Execute ``plan``'s schedule in the numpy simulator against a
    sequential host reference; returns measured-vs-predicted stats.

    Since the composition refactor every plan — single-axis,
    multi-axis (composed into one axis-annotated schedule) and
    scan_total — verifies through the same path.  Used by the dry-run
    (every cell's resolved scan plans) and the benchmark ``--check``
    smoke modes so plan/measurement drift fails fast, without devices.
    """
    m = monoid_lib.get(plan.spec.monoid)
    op = monoid_lib.NUMPY_OPS.get(m.name, m.op)
    ident_fn = monoid_lib.NUMPY_IDENTITY.get(
        m.name, lambda t: jax.tree.map(np.asarray, m.identity_like(t)))
    sched = plan.schedule()
    S = max(_max_seg(sched), 1)
    n0 = S * rank_elems
    x = _witness_payload(m.name, plan.p, n0, seed)
    with collect_stats() as st:
        got = SimulatorExecutor().execute(sched, x, m)
    want = _host_reference(plan.spec.kind, x, op, ident_fn, plan.p)
    close = all(
        np.allclose(g, w, rtol=1e-10, atol=1e-12)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    # byte accounting: the witness is built with S | element count, so
    # the schedule's per-round law must match measurement exactly
    per_rank = jax.tree.map(lambda a: np.asarray(a)[0], x)
    bytes_expected = expected_round_bytes(sched, per_rank)
    res = {
        "algorithm": plan.algorithm, "p": plan.p,
        "segments": plan.segments,
        "rounds_predicted": plan.rounds, "rounds_measured": st.rounds,
        "ops_predicted": plan.op_applications,
        "ops_measured": st.op_applications,
        "allgathers_predicted": plan.allgathers,
        "allgathers_measured": st.allgathers,
        "bytes_expected": bytes_expected,
        "bytes_measured": sum(st.bytes_per_round),
        "correct": bool(close),
    }
    res["ok"] = bool(
        close
        and st.rounds == plan.rounds
        and st.op_applications == plan.op_applications
        and st.allgathers == plan.allgathers
        and sum(st.bytes_per_round) == bytes_expected)
    return res
