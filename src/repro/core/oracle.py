"""Pure-numpy message-schedule oracle for the paper's scan algorithms.

This module simulates, rank-by-rank and round-by-round, the exact
communication schedules of the three exclusive-scan algorithms from the
paper (plus the Hillis-Steele inclusive scan), counting

  * communication rounds (simultaneous send-receive steps),
  * per-rank applications of ``op`` split into receive-path combines and
    send-side preparations,

so that tests can check Theorem 1 and the costs claimed for the
baselines, and so the SPMD (``ppermute``) implementations in
``core.exscan`` can be validated against a faithful, independent
executable specification of the paper's Algorithm 1.

The simulator is deliberately written in the paper's own terms (skips,
Send∥Recv pairs, per-rank W/T buffers), NOT in terms of the SPMD
masking tricks used on TPU.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence


@dataclasses.dataclass
class ScheduleStats:
    algorithm: str
    p: int
    rounds: int
    # per-rank counts, length p
    combines: list  # ⊕ applications on the rank's own result path
    preps: list  # ⊕ applications preparing a value to send
    messages: int  # total point-to-point messages

    @property
    def max_ops(self) -> int:
        return max(c + s for c, s in zip(self.combines, self.preps))

    @property
    def result_path_ops(self) -> int:
        """⊕ count of the last rank (the critical rank) — Theorem 1's q-1."""
        return self.combines[-1] + self.preps[-1]


def q_123(p: int) -> int:
    """Theorem 1 round count: ceil(log2(p-1) + log2(4/3)) (p >= 2)."""
    if p <= 1:
        return 0
    if p == 2:
        return 1
    return math.ceil(math.log2(p - 1) + math.log2(4.0 / 3.0))


def rounds_1doubling(p: int) -> int:
    if p <= 1:
        return 0
    if p == 2:
        return 1
    return 1 + math.ceil(math.log2(p - 1))


def rounds_two_op(p: int) -> int:
    if p <= 1:
        return 0
    return math.ceil(math.log2(p))


def _block_params(p: int, depth: int) -> tuple[int, int, int]:
    """(t, rho, n_w) of the block-distributed exscan family.

    ``t`` is the effective halving depth (clamped to ⌊log₂p⌋), ``rho``
    the number of folded pairs (p mod 2^t), ``n_w`` the window count
    the mid-phase two-⊕ exscan runs over.
    """
    t = max(1, min(depth, p.bit_length() - 1))
    rho = p % (1 << t)
    return t, rho, (p - rho) >> t


def rounds_block(p: int, depth: int) -> int:
    """Closed-form round count of the block-distributed exscan family:
    (2 if p mod 2^t else 0) fold/unfold + 2t halving/doubling +
    ⌈log₂ n_w⌉ mid-phase rounds."""
    if p <= 1:
        return 0
    t, rho, n_w = _block_params(p, depth)
    return (2 if rho else 0) + 2 * t + rounds_two_op(n_w)


def rounds_halving(p: int) -> int:
    return rounds_block(p, 1)


def rounds_quartering(p: int) -> int:
    return rounds_block(p, 2)


def rounds_reduce_scatter(p: int) -> int:
    """Full vector-halving depth: 2⌈log₂p⌉ rounds at power-of-two p."""
    if p <= 1:
        return 0
    return rounds_block(p, p.bit_length())


def skips_123(p: int) -> list[int]:
    """The 123-doubling skip schedule s_0=1, s_1=2, s_k=3*2^(k-2)."""
    if p <= 1:
        return []
    if p == 2:
        return [1]
    skips = [1, 2]
    k = 2
    while 3 * (1 << (k - 2)) < p - 1:
        skips.append(3 * (1 << (k - 2)))
        k += 1
    return skips


def skips_1doubling(p: int) -> list[int]:
    if p <= 1:
        return []
    skips = [1]
    k = 1
    while (1 << (k - 1)) < p - 1:
        skips.append(1 << (k - 1))
        k += 1
    return skips


def skips_two_op(p: int) -> list[int]:
    if p <= 1:
        return []
    skips = [1]
    k = 1
    while (1 << k) < p:
        skips.append(1 << k)
        k += 1
    return skips


def _exscan_reference(inputs: Sequence[Any], op: Callable, identity: Any):
    """Sequential exclusive fold: out[r] = V_0 ⊕ … ⊕ V_{r-1}; out[0]=identity."""
    out = [identity]
    acc = None
    for v in inputs[:-1]:
        acc = v if acc is None else op(acc, v)
        out.append(acc)
    return out


def simulate_123(inputs: Sequence[Any], op: Callable, identity: Any):
    """Faithful rank-by-rank execution of the paper's Algorithm 1.

    Returns (results, ScheduleStats).  ``results[0]`` is ``identity``
    (the exclusive prefix of rank 0 is empty).
    """
    p = len(inputs)
    V = list(inputs)
    W: list[Any] = [identity] * p
    combines = [0] * p
    preps = [0] * p
    messages = 0
    if p <= 1:
        return W, ScheduleStats("123", p, 0, combines, preps, 0)

    # Round 0: skip 1 — rank r sends V_r to r+1, receives V_{r-1} into W.
    sent = {r: V[r] for r in range(p - 1)}
    for r in range(1, p):
        W[r] = sent[r - 1]  # copy, no ⊕
    messages += p - 1
    rounds = 1
    if p == 2:
        return W, ScheduleStats("123", p, rounds, combines, preps, messages)

    # Round 1: skip 2 — rank r sends W ⊕ V (rank 0 sends plain V), receiver
    # combines W ← T ⊕ W.  Rank 0 is done after this round.
    sent = {}
    for r in range(p - 2):
        if r == 0:
            sent[r] = V[r]  # rank 0 has no W; sends its input
        else:
            sent[r] = op(W[r], V[r])
            preps[r] += 1
        messages += 1
    recv = {r + 2: w for r, w in sent.items()}
    for r in range(2, p):
        W[r] = op(recv[r], W[r])
        combines[r] += 1
    rounds += 1

    # Rounds k >= 2: skip s_k = 3 * 2^(k-2); plain doubling on W.
    k = 2
    while True:
        s = 3 * (1 << (k - 2))
        if s >= p - 1:
            break
        sent = {}
        for r in range(1, p - s):  # rank 0 returned after round 1
            sent[r] = W[r]
            messages += 1
        for r in range(1 + s, p):
            f = r - s
            # paper: receive while 0 < f (rank already complete once f<=0)
            W[r] = op(sent[f], W[r])
            combines[r] += 1
        rounds += 1
        k += 1

    return W, ScheduleStats("123", p, rounds, combines, preps, messages)


def simulate_1doubling(inputs: Sequence[Any], op: Callable, identity: Any):
    """Shift + straight doubling on p-1 ranks (1-doubling)."""
    p = len(inputs)
    V = list(inputs)
    W: list[Any] = [identity] * p
    combines = [0] * p
    preps = [0] * p
    messages = 0
    if p <= 1:
        return W, ScheduleStats("1doubling", p, 0, combines, preps, 0)

    # Round 0: shift V to rank+1.
    for r in range(1, p):
        W[r] = V[r - 1]
    messages += p - 1
    rounds = 1

    # Rounds k >= 1: skip s_k = 2^(k-1); W ← W_{r-s} ⊕ W while r - s > 0.
    k = 1
    while True:
        s = 1 << (k - 1)
        if s >= p - 1:
            break
        sent = {r: W[r] for r in range(1, p - s)}
        messages += len(sent)
        for r in range(1 + s, p):
            W[r] = op(sent[r - s], W[r])
            combines[r] += 1
        rounds += 1
        k += 1

    return W, ScheduleStats("1doubling", p, rounds, combines, preps, messages)


def simulate_two_op(inputs: Sequence[Any], op: Callable, identity: Any):
    """Two-⊕ doubling: invariant W_r = ⊕_{max(0,r-s_k+1)}^{r-1}, s_k = 2^k."""
    p = len(inputs)
    V = list(inputs)
    W: list[Any] = [identity] * p
    combines = [0] * p
    preps = [0] * p
    messages = 0
    if p <= 1:
        return W, ScheduleStats("two_op", p, 0, combines, preps, 0)

    # Round 0 (k=0, skip 1): send V, receive-copy into W.
    for r in range(1, p):
        W[r] = V[r - 1]
    messages += p - 1
    rounds = 1

    k = 1
    while (1 << k) < p:
        s = 1 << k
        sent = {}
        for r in range(p - s):
            sent[r] = op(W[r], V[r]) if r >= 1 else V[r]
            if r >= 1:
                preps[r] += 1
            messages += 1
        for r in range(s, p):
            if r - s + 1 > 0:  # not yet complete
                W[r] = op(sent[r - s], W[r])
                combines[r] += 1
        rounds += 1
        k += 1

    return W, ScheduleStats("two_op", p, rounds, combines, preps, messages)


def simulate_inclusive(inputs: Sequence[Any], op: Callable, identity: Any):
    """Hillis-Steele inclusive scan (for completeness / tests)."""
    p = len(inputs)
    W = list(inputs)
    combines = [0] * p
    preps = [0] * p
    messages = 0
    rounds = 0
    k = 0
    while (1 << k) < p:
        s = 1 << k
        sent = {r: W[r] for r in range(p - s)}
        messages += len(sent)
        for r in range(s, p):
            W[r] = op(sent[r - s], W[r])
            combines[r] += 1
        rounds += 1
        k += 1
    return W, ScheduleStats("inclusive", p, rounds, combines, preps, messages)


SIMULATORS = {
    "123": simulate_123,
    "1doubling": simulate_1doubling,
    "two_op": simulate_two_op,
}


def verify(p: int, algorithm: str = "123") -> ScheduleStats:
    """Run a schedule on distinguishable inputs and assert correctness.

    Uses the free monoid (tuple concatenation) — the most discriminating
    associative operator: any reordering, duplication or omission of an
    input is detected, and commutativity is NOT assumed.
    """
    inputs = [(r,) for r in range(p)]
    op = lambda lo, hi: lo + hi
    identity = ()
    expect = _exscan_reference(inputs, op, identity)
    got, stats = SIMULATORS[algorithm](inputs, op, identity)
    assert got == expect, (
        f"{algorithm} p={p}: wrong result\n got={got}\n want={expect}"
    )
    return stats
