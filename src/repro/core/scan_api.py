"""Unified scan API: ``ScanSpec`` in, ``ScanPlan`` out, one ``scan()``.

The paper's central observation is that the *right* prefix-scan
algorithm depends on the regime: for small payloads the round count
dominates (123-doubling's q = ceil(log2(p-1)+log2(4/3)) rounds win),
while for large payloads bandwidth dominates and pipelined/ring or
all-gather approaches win.  Instead of hardwiring ``algorithm="123"``
strings at every call site, callers describe *what* they need with a
:class:`ScanSpec` and the planner decides *how*:

    spec = ScanSpec(kind="exclusive", axis_name="data", monoid="add",
                    algorithm="auto")
    y = scan(x, spec)                  # inside shard_map

    pl = plan(spec, p=256, nbytes=64)  # inspectable, before any tracing
    pl.algorithm, pl.rounds, pl.op_applications, pl.bytes_on_wire

Algorithm implementations (in :mod:`repro.core.collectives`) register
themselves with :func:`register_algorithm`, carrying their theoretical
round/⊕/byte costs from :mod:`repro.core.oracle`, so a ``ScanPlan``
predicts the exact ``collect_stats()`` measurements of the traced
program — a property the test suite asserts for every registered
algorithm.

``algorithm="auto"`` minimizes the α·rounds + β·bytes + γ·ops model of
:class:`CostModel` (per-axis interconnect tiers via ``launch.mesh
.axis_cost_model``; see DESIGN.md §7 for the model table).  Plans are
cached by (axis sizes, kind, monoid, payload signature, cost model).
Multi-axis scans (e.g. ``("pod", "data")``) are rewritten by the
planner into sub-plans: exscan over the minor axis, allreduce of the
minor-axis total, exscan of the totals over the major axes, plus one
combining ⊕ (DESIGN.md §5).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import threading
from typing import Any, Callable

import numpy as np

from repro.core import monoid as monoid_lib


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModel:
    """α-β-γ communication cost model for algorithm selection.

    ``cost = alpha * latency_hops + beta * serial_bytes
           + gamma * op_applications * payload_bytes * monoid.op_cost``

    alpha: seconds per one-ported send-receive hop (ppermute launch +
      link traversal).  An all-gather counts as its internal hop count
      (ring-based on torus interconnects: p-1 hops).
    beta: seconds per byte on the bandwidth-critical path.
    gamma: seconds per byte touched by one ⊕ application (HBM streaming
      of the two operands), scaled by the monoid's relative op cost.
    """

    alpha: float = 1e-6  # ICI launch+hop latency
    beta: float = 1.0 / 50e9  # ICI link bandwidth
    gamma: float = 2.0 / 819e9  # HBM streaming for one ⊕

    def cost(self, *, hops: int, serial_bytes: float, ops: int,
             payload_bytes: int, op_cost: float = 1.0) -> float:
        return (self.alpha * hops
                + self.beta * serial_bytes
                + self.gamma * ops * payload_bytes * op_cost)


DEFAULT_COST_MODEL = CostModel()

_tls = threading.local()


@contextlib.contextmanager
def use_cost_model(cm):
    """Install ``cm`` as the default cost model for ``scan``/``plan``
    calls inside the context.  ``cm`` is either a :class:`CostModel` or
    a callable ``axis_name -> CostModel`` so multi-axis plans can price
    each sub-axis by its own interconnect tier (e.g.
    ``launch.mesh.axis_cost_model``: DCI for "pod", ICI otherwise)."""
    prev = getattr(_tls, "cost_model", None)
    _tls.cost_model = cm
    try:
        yield cm
    finally:
        _tls.cost_model = prev


def current_cost_model():
    return getattr(_tls, "cost_model", None) or DEFAULT_COST_MODEL


def _resolve_cm(cm, axis_name) -> CostModel:
    return cm(axis_name) if callable(cm) else cm


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanAlgorithm:
    """A registered scan implementation plus its theoretical costs.

    The count functions take the axis size ``p`` and must predict the
    ``collect_stats()`` measurements of the traced implementation
    exactly (tests enforce this for p in 2..17):

      rounds:          ppermute communication rounds.
      op_applications: per-device ⊕ executions.
      allgathers:      XLA-native all-gather calls.

    The byte/latency functions feed the cost model only:

      latency_hops(p):        one-ported hops on the critical path
                              (defaults to rounds + (p-1)·allgathers —
                              all-gathers are ring-based on tori).
      wire_bytes(p, m):       total bytes through each device's port
                              (defaults to rounds·m + allgathers·p·m).
      serial_bytes(p, m):     bandwidth-critical-path bytes; pipelined
                              algorithms get credit here (defaults to
                              wire_bytes).
    """

    name: str
    kind: str  # "exclusive" | "inclusive" | "allreduce"
    fn: Callable[[Any, str, monoid_lib.Monoid], Any]
    rounds: Callable[[int], int]
    op_applications: Callable[[int], int]
    allgathers: Callable[[int], int]
    latency_hops: Callable[[int], int]
    wire_bytes: Callable[[int, int], float]
    serial_bytes: Callable[[int, int], float]


_REGISTRY: dict[tuple[str, str], ScanAlgorithm] = {}

KINDS = ("exclusive", "inclusive", "allreduce")


def register_algorithm(name: str, *, kind: str,
                       rounds: Callable[[int], int],
                       ops: Callable[[int], int],
                       allgathers: Callable[[int], int] | None = None,
                       latency_hops: Callable[[int], int] | None = None,
                       wire_bytes: Callable[[int, int], float] | None = None,
                       serial_bytes: Callable[[int, int], float] | None = None):
    """Class decorator registering a scan implementation with its costs.

    Usage (collectives.py)::

        @register_algorithm("123", kind="exclusive", rounds=oracle.q_123,
                            ops=lambda p: 0 if p <= 2 else oracle.q_123(p))
        def exscan_123(x, axis_name, m): ...
    """
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    ag = allgathers or (lambda p: 0)
    hops = latency_hops or (lambda p: rounds(p) + (p - 1) * ag(p))
    wire = wire_bytes or (lambda p, m: rounds(p) * m + ag(p) * p * m)
    serial = serial_bytes or wire

    def deco(fn):
        key = (kind, name)
        if key in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered "
                             f"for kind {kind!r}")
        _REGISTRY[key] = ScanAlgorithm(
            name=name, kind=kind, fn=fn, rounds=rounds,
            op_applications=ops, allgathers=ag, latency_hops=hops,
            wire_bytes=wire, serial_bytes=serial)
        return fn

    return deco


def _ensure_registered():
    # Implementations live in collectives.py and register on import;
    # imported lazily here to avoid a module cycle.
    if not _REGISTRY:
        from repro.core import collectives  # noqa: F401


def algorithms(kind: str | None = None) -> tuple[str, ...]:
    """Registered algorithm names (optionally for one kind)."""
    _ensure_registered()
    return tuple(sorted(n for k, n in _REGISTRY
                        if kind is None or k == kind))


def get_algorithm(kind: str, name: str) -> ScanAlgorithm:
    _ensure_registered()
    try:
        return _REGISTRY[(kind, name)]
    except KeyError:
        raise ValueError(
            f"unknown {kind} scan algorithm {name!r}; "
            f"known: {algorithms(kind)}") from None


# ---------------------------------------------------------------------------
# ScanSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """Declarative description of a scan collective.

    Attributes:
      kind: "exclusive" | "inclusive" | "allreduce".
      monoid: a :class:`repro.core.monoid.Monoid` or registry name.
      algorithm: a registered algorithm name, or "auto" to let the
        planner pick by cost model.
      axis_name: mesh axis name, or tuple of names major→minor (ranks
        row-major over the tuple).  May be None for pure planning math.
      payload_bytes: per-rank message size hint m, used by ``plan``
        when no concrete operand is available yet.
    """

    kind: str = "exclusive"
    monoid: Any = "add"
    algorithm: str = "auto"
    axis_name: Any = None
    payload_bytes: int | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if isinstance(self.axis_name, list):
            object.__setattr__(self, "axis_name", tuple(self.axis_name))

    @property
    def axes(self) -> tuple:
        """Axis names as a tuple (a single placeholder if unset)."""
        if self.axis_name is None:
            return (None,)
        if isinstance(self.axis_name, tuple):
            return self.axis_name
        return (self.axis_name,)

    def over(self, axis_name, **replacements) -> "ScanSpec":
        """This spec re-targeted at ``axis_name`` (e.g. per call site),
        with optional field overrides: ``spec.over("data",
        monoid="affine")``."""
        if isinstance(axis_name, list):
            axis_name = tuple(axis_name)
        return dataclasses.replace(self, axis_name=axis_name,
                                   **replacements)


# ---------------------------------------------------------------------------
# ScanPlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """A resolved scan: algorithm choice + predicted costs, pre-tracing.

    ``rounds``/``op_applications``/``allgathers`` predict exactly what
    ``collectives.collect_stats()`` measures when the plan is executed.
    ``bytes_on_wire`` is the total bytes through each device's port for
    the planned payload.  Multi-axis scans carry ``sub_plans``
    (inner exscan, minor-axis allreduce, outer exscan) and one extra
    combining ⊕ at the top level.
    """

    spec: ScanSpec
    p: int  # total ranks (product over axes)
    algorithm: str  # resolved (never "auto")
    payload_bytes: int
    rounds: int
    op_applications: int
    allgathers: int
    bytes_on_wire: float
    cost: float  # cost-model seconds estimate
    cost_model: CostModel
    sub_plans: tuple = ()

    def describe(self) -> str:
        """Human-readable one-liner (benchmarks print these)."""
        head = (f"{self.spec.kind} scan over p={self.p} "
                f"[{self.algorithm}] rounds={self.rounds} "
                f"ops={self.op_applications} "
                f"allgathers={self.allgathers} "
                f"wire={self.bytes_on_wire:.0f}B "
                f"cost={self.cost * 1e6:.2f}us")
        for sp in self.sub_plans:
            head += "\n  " + sp.describe().replace("\n", "\n  ")
        return head


def _monoid_name_and_cost(monoid) -> tuple[str, float]:
    m = monoid_lib.get(monoid)
    return m.name, getattr(m, "op_cost", 1.0)


def _plan_single(spec: ScanSpec, p: int, nbytes: int, cm) -> ScanPlan:
    """Plan one axis: resolve "auto" by cost, fill predicted counts."""
    cm = _resolve_cm(cm, spec.axes[-1])
    _, op_cost = _monoid_name_and_cost(spec.monoid)

    def one(algo: ScanAlgorithm) -> ScanPlan:
        return ScanPlan(
            spec=spec, p=p, algorithm=algo.name, payload_bytes=nbytes,
            rounds=algo.rounds(p), op_applications=algo.op_applications(p),
            allgathers=algo.allgathers(p),
            bytes_on_wire=algo.wire_bytes(p, nbytes),
            cost=cm.cost(hops=algo.latency_hops(p),
                         serial_bytes=algo.serial_bytes(p, nbytes),
                         ops=algo.op_applications(p),
                         payload_bytes=nbytes, op_cost=op_cost),
            cost_model=cm)

    if spec.algorithm != "auto":
        return one(get_algorithm(spec.kind, spec.algorithm))
    _ensure_registered()
    candidates = [a for (k, _), a in sorted(_REGISTRY.items())
                  if k == spec.kind]
    if not candidates:
        raise ValueError(f"no algorithms registered for {spec.kind!r}")
    # deterministic tie-break: lowest cost, then fewest rounds, name
    plans = [one(a) for a in candidates]
    return min(plans, key=lambda pl: (pl.cost, pl.rounds, pl.algorithm))


@functools.lru_cache(maxsize=1024)
def _plan_cached(spec: ScanSpec, ps: tuple, nbytes: int, cm) -> ScanPlan:
    if len(ps) == 1:
        return _plan_single(spec, ps[0], nbytes, cm)
    # Multi-axis rewrite (DESIGN.md §5): exscan within the minor axis,
    # allreduce of the minor-axis total, exscan of totals over the
    # major axes, then one ⊕ combining outer and inner.
    if spec.kind != "exclusive":
        raise ValueError(
            f"multi-axis scan only supports kind='exclusive', "
            f"got {spec.kind!r}")
    _, op_cost = _monoid_name_and_cost(spec.monoid)
    axes = spec.axes
    inner = _plan_cached(
        spec.over(axes[-1]), (ps[-1],), nbytes, cm)
    reduce_ = _plan_cached(
        spec.over(axes[-1], kind="allreduce", algorithm="auto"),
        (ps[-1],), nbytes, cm)
    outer = _plan_cached(
        spec.over(axes[:-1] if len(axes) > 2 else axes[0]),
        ps[:-1], nbytes, cm)
    subs = (inner, reduce_, outer)
    cm_top = _resolve_cm(cm, axes[-1])  # final ⊕ is local compute
    return ScanPlan(
        spec=spec, p=int(np.prod(ps)),
        algorithm=inner.algorithm, payload_bytes=nbytes,
        rounds=sum(s.rounds for s in subs),
        op_applications=sum(s.op_applications for s in subs) + 1,
        allgathers=sum(s.allgathers for s in subs),
        bytes_on_wire=sum(s.bytes_on_wire for s in subs),
        cost=sum(s.cost for s in subs) + cm_top.gamma * nbytes * op_cost,
        cost_model=cm_top, sub_plans=subs)


def plan(spec: ScanSpec, p: int | tuple | None = None, *,
         nbytes: int | None = None,
         cost_model=None) -> ScanPlan:
    """Resolve ``spec`` into an inspectable :class:`ScanPlan`.

    Args:
      spec: what to compute.
      p: axis size, or tuple of sizes matching ``spec.axes`` for a
        multi-axis scan (major→minor).
      nbytes: per-rank payload size in bytes (falls back to
        ``spec.payload_bytes``, then 0 — a pure round-count plan).
      cost_model: overrides the ambient :func:`current_cost_model`; a
        :class:`CostModel` or a per-axis ``axis_name -> CostModel``
        callable (must be a stable module-level function — it is part
        of the plan-cache key by identity).

    Plans are cached by (spec, axis sizes, payload bytes, cost model);
    repeated calls with the same signature return the same object.
    """
    if p is None:
        raise ValueError("plan() needs the axis size(s) p")
    ps = tuple(p) if isinstance(p, (tuple, list)) else (int(p),)
    if len(ps) != len(spec.axes):
        raise ValueError(
            f"got {len(ps)} axis sizes for {len(spec.axes)} axes "
            f"({spec.axes})")
    m_bytes = nbytes if nbytes is not None else (spec.payload_bytes or 0)
    cm = cost_model or current_cost_model()
    return _plan_cached(spec, ps, int(m_bytes), cm)


def plan_cache_clear():
    _plan_cached.cache_clear()


# ---------------------------------------------------------------------------
# scan(): execute a spec inside shard_map
# ---------------------------------------------------------------------------


def _tree_nbytes(tree) -> int:
    import jax

    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def _run_plan(pl: ScanPlan, x, m: monoid_lib.Monoid):
    if pl.sub_plans:
        from repro.core import collectives

        inner_pl, reduce_pl, outer_pl = pl.sub_plans
        inner = _run_plan(inner_pl, x, m)
        total = _run_plan(reduce_pl, x, m)
        outer = _run_plan(outer_pl, total, m)
        combined = m.op(outer, inner)
        collectives._record_op()
        return combined
    algo = get_algorithm(pl.spec.kind, pl.algorithm)
    axis = pl.spec.axes[-1] if len(pl.spec.axes) == 1 else pl.spec.axes
    return algo.fn(x, axis, m)


def scan(x, spec: ScanSpec, *, cost_model=None):
    """Execute ``spec`` on pytree ``x`` along its named mesh axes.

    Must be called inside ``shard_map`` (or wherever the axis names are
    bound).  Resolves a :class:`ScanPlan` first — with the payload size
    taken from ``x`` itself — then runs it; ``algorithm="auto"`` specs
    therefore adapt per call site to the actual message size.
    """
    _ensure_registered()
    from jax import lax

    if spec.axis_name is None:
        raise ValueError("scan() needs spec.axis_name to be set "
                         "(use spec.over(axis_name))")
    m = monoid_lib.get(spec.monoid)
    ps = tuple(lax.axis_size(a) for a in spec.axes)
    pl = plan(spec, ps if len(ps) > 1 else ps[0],
              nbytes=_tree_nbytes(x), cost_model=cost_model)
    return _run_plan(pl, x, m)


# ---------------------------------------------------------------------------
# Host-side twin
# ---------------------------------------------------------------------------


def host_exscan(lengths: np.ndarray) -> np.ndarray:
    """Numpy twin of the exclusive scan for host-side code (the data
    pipeline's document offsets): out[r] = sum(lengths[:r]), out[0]=0."""
    lengths = np.asarray(lengths)
    out = np.zeros_like(lengths)
    if lengths.shape[0] > 1:
        np.cumsum(lengths[:-1], axis=0, out=out[1:])
    return out
