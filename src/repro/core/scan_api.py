"""Unified scan API: ``ScanSpec`` in, ``ScanPlan`` out, one ``scan()``.

The paper's central observation is that the *right* prefix-scan
algorithm depends on the regime: for small payloads the round count
dominates (123-doubling's q = ceil(log2(p-1)+log2(4/3)) rounds win),
while for large payloads bandwidth dominates and pipelined/ring or
all-gather approaches win.  Instead of hardwiring ``algorithm="123"``
strings at every call site, callers describe *what* they need with a
:class:`ScanSpec` and the planner decides *how*:

    spec = ScanSpec(kind="exclusive", axis_name="data", monoid="add",
                    algorithm="auto")
    y = scan(x, spec)                  # inside shard_map

    pl = plan(spec, p=256, nbytes=64)  # inspectable, before any tracing
    pl.algorithm, pl.rounds, pl.op_applications, pl.bytes_on_wire

Algorithms register *schedule builders* (:mod:`repro.core.schedule`)
with :func:`register_algorithm`: every registered algorithm builds an
explicit :class:`~repro.core.schedule.Schedule` — per-round peer
offsets, masks, combine directions — and the planner derives its
predicted round/⊕/all-gather counts by counting that IR.  Because the
executors run the same IR, a ``ScanPlan`` predicts the exact
``collect_stats()`` measurements of the program that runs — a property
the test suite asserts for every registered algorithm.  Plans are
executable and inspectable: ``plan.schedule()`` lists the rounds
without tracing, ``plan.execute(x)`` runs under ``shard_map``, and
``plan.lower(executor)`` retargets the same schedule at the SPMD,
numpy-simulator or Pallas executor.

``algorithm="auto"`` minimizes the α·rounds + β·bytes + γ·ops model of
:class:`CostModel` (per-axis interconnect tiers via ``launch.mesh
.axis_cost_model``; see DESIGN.md §7 for the model table).  Plans are
cached by (axis sizes, kind, monoid, payload signature, cost model);
:func:`plan_cache_info` reports hits/misses/size.

Multi-axis scans (e.g. ``("pod", "data")``) are rewritten by the
planner into sub-plans — exscan over the minor axis, allreduce of the
minor-axis total, exscan of the totals over the major axes, plus one
combining ⊕ (DESIGN.md §5) — and since the composition refactor the
rewrite is *inlined into one axis-annotated schedule*
(``schedule_lib.compose``): ``plan.schedule()``/``execute()``/
``lower()`` work for multi-axis plans exactly like single-axis ones,
with ``sub_plans`` kept as inspectable provenance.

Two fused entry points amortize rounds across concurrent collectives
in the paper's latency-dominated small-m regime:

  * :func:`fused_scan` — k independent same-axis/same-kind scans pack
    into one flattened payload (``schedule_lib.fuse``) and ride a
    single schedule's q rounds, when the cost model says the α saving
    beats the β cost of the packed payload (:func:`plan_fused`).
  * :func:`scan_with_total` — an exclusive scan and an allreduce of
    the same payload fused into one "scan_total" schedule (for
    power-of-two p: both in the allreduce's ⌈log₂p⌉ rounds).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Any, Callable

import numpy as np

from repro.core import monoid as monoid_lib
from repro.core import schedule as schedule_lib


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModel:
    """The immutable α-β-γ *pricing kernel* for algorithm selection.

    ``cost = alpha * latency_hops + beta * serial_bytes
           + gamma * op_applications * payload_bytes * monoid.op_cost``

    alpha: seconds per one-ported send-receive hop (ppermute launch +
      link traversal).  An all-gather counts as its internal hop count
      (ring-based on torus interconnects: p-1 hops).
    beta: seconds per byte on the bandwidth-critical path.
    gamma: seconds per byte touched by one ⊕ application (HBM streaming
      of the two operands), scaled by the monoid's relative op cost.
    gamma_pass: seconds per byte per *HBM pass* of the round kernels
      (``Schedule.kernel_passes``, DESIGN §7).  The default 0.0 keeps
      γ pricing purely op-count-based — identical to historical
      behavior — while a calibrated profile can charge the fused
      single-pass round path less than the baseline multi-pass one
      (ops alone cannot tell them apart: fusion changes the pass
      count, not the ⊕ count).
    source: provenance of the constants — "default" (hand-guessed
      values) or "calibrated" (fitted by :mod:`repro.core.tune` from
      measured schedule timings).  Part of equality/hash, so plans
      priced under a calibrated model never alias cached plans priced
      under identical-looking defaults.
    """

    alpha: float = 1e-6  # ICI launch+hop latency
    beta: float = 1.0 / 50e9  # ICI link bandwidth
    gamma: float = 2.0 / 819e9  # HBM streaming for one ⊕
    gamma_pass: float = 0.0  # per-byte-per-HBM-pass (0: op-count only)
    source: str = "default"  # "default" | "calibrated"

    def parts(self, *, hops: int, serial_bytes: float, ops: int,
              payload_bytes: int, op_cost: float = 1.0,
              passes: int = 0, op_bytes: float = -1.0,
              pass_bytes: float = -1.0) -> dict:
        """The three cost components, separately (``explain()`` uses
        them to say *why* a candidate lost).  ``passes`` — the plan's
        HBM-pass count — folds into the γ component when
        ``gamma_pass`` is nonzero (it prices memory traffic, like γ).

        ``op_bytes`` / ``pass_bytes`` (when >= 0) override the uniform
        ``ops·payload_bytes`` / ``passes·payload_bytes`` products with
        the schedule's exact per-step byte laws — needed by the
        block-distributed algorithms whose ⊕ rounds each touch a
        different slice of the payload (``schedule.op_wire_bytes``)."""
        gamma_op = (op_bytes if op_bytes >= 0
                    else ops * payload_bytes)
        gamma_mem = (pass_bytes if pass_bytes >= 0
                     else passes * payload_bytes)
        return {
            "alpha": self.alpha * hops,
            "beta": self.beta * serial_bytes,
            "gamma": self.gamma * gamma_op * op_cost
            + self.gamma_pass * gamma_mem,
        }

    def cost(self, *, hops: int, serial_bytes: float, ops: int,
             payload_bytes: int, op_cost: float = 1.0,
             passes: int = 0, op_bytes: float = -1.0,
             pass_bytes: float = -1.0) -> float:
        return sum(self.parts(
            hops=hops, serial_bytes=serial_bytes, ops=ops,
            payload_bytes=payload_bytes, op_cost=op_cost,
            passes=passes, op_bytes=op_bytes,
            pass_bytes=pass_bytes).values())


DEFAULT_COST_MODEL = CostModel()

PROFILE_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class CostProfile:
    """A full pricing *profile*: per-tier :class:`CostModel` kernels
    plus the provenance that justifies them.

    The planner prices every decision off one of these (directly, or
    through a per-axis resolver like ``launch.mesh.axis_cost_model``).
    A profile is either the hand-guessed ``source="default"`` one, or
    ``source="calibrated"`` — fitted by :mod:`repro.core.tune` from
    measured schedule timings on a specific mesh, in which case
    ``mesh_fingerprint`` records which machine the constants describe
    and ``residuals`` the per-tier relative fit error.

    Attributes:
      tiers: ``((tier_name, CostModel), ...)`` — e.g. "ici"/"dci".
      source: "default" | "calibrated".
      mesh_fingerprint: identity of the mesh the profile was measured
        on ("" for defaults).
      axis_tiers: ``((axis_name, tier_name), ...)`` routing mesh axes
        to tiers (axes not listed use ``default_tier``).
      default_tier: tier for unlisted axes.
      residuals: ``((tier_name, relative_rms_residual), ...)`` fit
        diagnostics from the calibration's non-negative least squares.
      schema_version: persisted-JSON schema version
        (:data:`PROFILE_SCHEMA_VERSION`).
    """

    tiers: tuple
    source: str = "default"
    mesh_fingerprint: str = ""
    axis_tiers: tuple = ()
    default_tier: str = "ici"
    residuals: tuple = ()
    schema_version: int = PROFILE_SCHEMA_VERSION

    def __post_init__(self):
        for field in ("tiers", "axis_tiers", "residuals"):
            v = getattr(self, field)
            if isinstance(v, dict):
                object.__setattr__(self, field, tuple(v.items()))

    def model(self, tier: str) -> CostModel:
        for name, cm in self.tiers:
            if name == tier:
                return cm
        raise KeyError(f"profile has no tier {tier!r}; "
                       f"known: {tuple(n for n, _ in self.tiers)}")

    def tier_for_axis(self, axis_name) -> str:
        """Tier for a mesh axis name or axis tuple.  A tuple routes to
        any member's listed NON-default tier first (a collective over
        ("data", "pod") traverses DCI no matter the tuple order), then
        to a listed default-tier mapping, then to ``default_tier``."""
        names = (axis_name,) if isinstance(axis_name, str) else \
            tuple(axis_name or ())
        routing = dict(self.axis_tiers)
        for n in names:
            tier = routing.get(n)
            if tier is not None and tier != self.default_tier:
                return tier
        for n in names:
            if n in routing:
                return routing[n]
        return self.default_tier

    def for_axis(self, axis_name) -> CostModel:
        """The pricing kernel for a mesh axis (or axis tuple — the
        slowest member's tier wins; see :meth:`tier_for_axis`)."""
        return self.model(self.tier_for_axis(axis_name))

    def provenance(self, default_mesh_fingerprint: str = "") -> dict:
        """The provenance record consumers log/persist (train prints
        it, dryrun stores it per cell, the benchmark JSON embeds it) —
        one shape everywhere.  ``default_mesh_fingerprint`` fills the
        mesh identity for default profiles, which carry none."""
        return {
            "source": self.source,
            "fingerprint": self.fingerprint(),
            "mesh_fingerprint": (self.mesh_fingerprint
                                 or default_mesh_fingerprint),
            "fit_residuals": dict(self.residuals),
        }

    def fingerprint(self) -> str:
        """Stable content hash — the plan-cache and profile-store key.
        Two profiles with identical constants but different provenance
        (source/mesh) fingerprint differently."""
        import hashlib

        # gamma_pass joins the blob only when set, so profiles written
        # before the pass-aware γ term keep their recorded fingerprints
        blob = repr((self.schema_version, self.source,
                     self.mesh_fingerprint, self.axis_tiers,
                     self.default_tier,
                     tuple((n, cm.alpha, cm.beta, cm.gamma, cm.source)
                           if cm.gamma_pass == 0.0 else
                           (n, cm.alpha, cm.beta, cm.gamma,
                            cm.gamma_pass, cm.source)
                           for n, cm in self.tiers))).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    def to_json(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "source": self.source,
            "mesh_fingerprint": self.mesh_fingerprint,
            "default_tier": self.default_tier,
            "axis_tiers": dict(self.axis_tiers),
            "residuals": dict(self.residuals),
            "tiers": {
                name: {"alpha": cm.alpha, "beta": cm.beta,
                       "gamma": cm.gamma, "source": cm.source,
                       **({"gamma_pass": cm.gamma_pass}
                          if cm.gamma_pass else {})}
                for name, cm in self.tiers
            },
            "fingerprint": self.fingerprint(),
        }

    @classmethod
    def from_json(cls, obj: dict) -> "CostProfile":
        if obj.get("schema_version") != PROFILE_SCHEMA_VERSION:
            raise ValueError(
                f"cost-profile schema {obj.get('schema_version')!r} "
                f"!= supported {PROFILE_SCHEMA_VERSION}")
        return cls(
            tiers=tuple(
                (name, CostModel(alpha=t["alpha"], beta=t["beta"],
                                 gamma=t["gamma"],
                                 gamma_pass=t.get("gamma_pass", 0.0),
                                 source=t.get("source", "default")))
                for name, t in sorted(obj["tiers"].items())),
            source=obj.get("source", "default"),
            mesh_fingerprint=obj.get("mesh_fingerprint", ""),
            axis_tiers=tuple(sorted(obj.get("axis_tiers", {}).items())),
            default_tier=obj.get("default_tier", "ici"),
            residuals=tuple(sorted(obj.get("residuals", {}).items())))


_tls = threading.local()


@contextlib.contextmanager
def use_cost_model(cm):
    """Install ``cm`` as the default cost model for ``scan``/``plan``
    calls inside the context.  ``cm`` is a :class:`CostModel`, a
    :class:`CostProfile` (axes routed to tiers via its ``axis_tiers``),
    or a callable ``axis_name -> CostModel`` so multi-axis plans can
    price each sub-axis by its own interconnect tier (e.g.
    ``launch.mesh.axis_cost_model``: DCI for "pod", ICI otherwise).

    Re-entrant: contexts nest, each exit restores the previous model
    (an explicit per-thread stack, so interleaved generators that
    close out of order fail loudly instead of corrupting the state).
    """
    stack = getattr(_tls, "cm_stack", None)
    if stack is None:
        stack = _tls.cm_stack = []
    stack.append(cm)
    try:
        yield cm
    finally:
        popped = stack.pop()
        if popped is not cm:
            raise RuntimeError(
                "use_cost_model contexts exited out of order")


def current_cost_model():
    stack = getattr(_tls, "cm_stack", None)
    if stack:
        # use_cost_model(None) means "the defaults", not "inherit"
        return stack[-1] or DEFAULT_COST_MODEL
    # backward-compat: PR-1-era direct _tls.cost_model assignment
    return getattr(_tls, "cost_model", None) or DEFAULT_COST_MODEL


def _resolve_cm(cm, axis_name) -> CostModel:
    if isinstance(cm, CostProfile):
        return cm.for_axis(axis_name)
    resolved = cm(axis_name) if callable(cm) else cm
    if isinstance(resolved, CostProfile):
        resolved = resolved.for_axis(axis_name)
    return resolved


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------


# The planner only considers power-of-two segment counts (exact byte
# prediction for power-of-two payloads, bounded padding) up to this
# cap.  Since the rolled round-table executor the traced ring is O(1)
# in S, so the cap only bounds padding slack and pipeline fill cost.
MAX_SEGMENTS = 64


@dataclasses.dataclass(frozen=True)
class ScanAlgorithm:
    """A registered scan algorithm: a schedule builder plus metadata.

    ``build(p)`` (or ``build(p, segments)`` when ``segmentable``)
    returns the :class:`~repro.core.schedule.Schedule` the executors
    run.  Rounds / ⊕ / all-gather predictions are *counted off that
    IR*, so plans match ``collect_stats()`` measurements by
    construction (tests still enforce this for p in 2..17).

    Cost-model inputs derived per (p, m, S):

      latency_hops:  rounds + (p−1)·allgathers (all-gathers are
                     ring-based on torus interconnects).
      wire_bytes:    rounds·ceil(m/S) + allgathers·p·m — the bytes
                     through each device's port; for the segmented ring
                     this IS the serialized critical path, which is how
                     pipelining earns its large-m win honestly.
    """

    name: str
    kind: str  # "exclusive" | "inclusive" | "allreduce"
    build: Callable[..., "schedule_lib.Schedule"]
    segmentable: bool = False
    # Block-distributed algorithms split payload leaves into row
    # blocks, so the monoid's ⊕ must act elementwise over aligned
    # positions (Monoid.segmentable) even though the *schedule* takes
    # no segment parameter.  "auto" skips them for non-segmentable
    # monoids (matmul); pinning one raises.
    requires_segmentable: bool = False

    def schedule(self, p: int,
                 segments: int = 1) -> "schedule_lib.Schedule":
        return _build_cached(self, int(p), int(segments))


@functools.lru_cache(maxsize=4096)
def _build_cached(algo: ScanAlgorithm, p: int, segments: int):
    if algo.segmentable:
        return algo.build(p, segments)
    if segments != 1:
        raise ValueError(
            f"algorithm {algo.name!r} does not support segmentation")
    return algo.build(p)


_REGISTRY: dict[tuple[str, str], ScanAlgorithm] = {}

KINDS = ("exclusive", "inclusive", "allreduce", "scan_total")


def register_algorithm(name: str, *, kind: str,
                       segmentable: bool = False,
                       requires_segmentable: bool = False):
    """Decorator registering a schedule builder as a scan algorithm.

    Usage (collectives.py)::

        register_algorithm("123", kind="exclusive")(schedule.build_123)
        register_algorithm("ring", kind="exclusive",
                           segmentable=True)(schedule.build_ring)

    ``segmentable`` builders take ``(p, segments)`` and must honour the
    p−2+S pipelined round structure the planner prices.
    """
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")

    def deco(build):
        key = (kind, name)
        if key in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered "
                             f"for kind {kind!r}")
        _REGISTRY[key] = ScanAlgorithm(
            name=name, kind=kind, build=build, segmentable=segmentable,
            requires_segmentable=requires_segmentable)
        return build

    return deco


def _ensure_registered():
    # Implementations live in collectives.py and register on import;
    # imported lazily here to avoid a module cycle.
    if not _REGISTRY:
        from repro.core import collectives  # noqa: F401


def algorithms(kind: str | None = None) -> tuple[str, ...]:
    """Registered algorithm names (optionally for one kind)."""
    _ensure_registered()
    return tuple(sorted(n for k, n in _REGISTRY
                        if kind is None or k == kind))


def get_algorithm(kind: str, name: str) -> ScanAlgorithm:
    _ensure_registered()
    try:
        return _REGISTRY[(kind, name)]
    except KeyError:
        raise ValueError(
            f"unknown {kind} scan algorithm {name!r}; "
            f"known: {algorithms(kind)}") from None


# ---------------------------------------------------------------------------
# ScanSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """Declarative description of a scan collective.

    Attributes:
      kind: "exclusive" | "inclusive" | "allreduce" | "scan_total"
        (the last fuses an exclusive scan with an allreduce of the
        same payload and yields ``(prefix, total)``).
      monoid: a :class:`repro.core.monoid.Monoid` or registry name.
      algorithm: a registered algorithm name, or "auto" to let the
        planner pick by cost model.
      axis_name: mesh axis name, or tuple of names major→minor (ranks
        row-major over the tuple).  May be None for pure planning math.
      payload_bytes: per-rank message size hint m, used by ``plan``
        when no concrete operand is available yet.
      segments: pin the payload segment count S of segmentable
        algorithms (the pipelined ring); None lets the planner pick S
        from the α/β trade-off.  Non-segmentable algorithms and monoids
        always run S=1.
    """

    kind: str = "exclusive"
    monoid: Any = "add"
    algorithm: str = "auto"
    axis_name: Any = None
    payload_bytes: int | None = None
    segments: int | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if isinstance(self.axis_name, list):
            object.__setattr__(self, "axis_name", tuple(self.axis_name))

    @property
    def axes(self) -> tuple:
        """Axis names as a tuple (a single placeholder if unset)."""
        if self.axis_name is None:
            return (None,)
        if isinstance(self.axis_name, tuple):
            return self.axis_name
        return (self.axis_name,)

    def over(self, axis_name, **replacements) -> "ScanSpec":
        """This spec re-targeted at ``axis_name`` (e.g. per call site),
        with optional field overrides: ``spec.over("data",
        monoid="affine")``."""
        if isinstance(axis_name, list):
            axis_name = tuple(axis_name)
        return dataclasses.replace(self, axis_name=axis_name,
                                   **replacements)


# ---------------------------------------------------------------------------
# ScanPlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """A resolved scan: algorithm choice + predicted costs, pre-tracing.

    ``rounds``/``op_applications``/``allgathers`` predict exactly what
    ``collectives.collect_stats()`` measures when the plan is executed.
    ``bytes_on_wire`` is the total bytes through each device's port for
    the planned payload (for the segmented ring: rounds·ceil(m/S), the
    pipelined serialization).  ``segments`` is the planner-chosen (or
    spec-pinned) payload segment count S.  ``kernel_passes`` is the
    fused-path HBM-pass budget of the schedule's per-round kernels
    (``Schedule.kernel_passes``, DESIGN §7) — what the fused
    ``PallasExecutor`` records in ``collect_stats()``; a cost model
    with nonzero ``gamma_pass`` prices it.  Multi-axis plans report a
    ``composite(inner+allreduce+outer)`` algorithm label and keep
    their ``sub_plans`` (inner exscan, minor-axis allreduce, outer
    exscan) as inspectable provenance — ``schedule()`` inlines them
    into ONE axis-annotated schedule (``schedule_lib.compose``), plus
    one combining ⊕.

    A plan is executable: ``schedule()`` returns the round-by-round IR
    (no tracing), ``execute(x)`` runs it (default: the SPMD executor,
    inside ``shard_map``), ``lower(executor)`` binds a different
    backend (numpy simulator, Pallas combine) — multi-axis plans
    included.
    """

    spec: ScanSpec
    p: int  # total ranks (product over axes)
    algorithm: str  # resolved (never "auto")
    payload_bytes: int
    rounds: int
    op_applications: int
    allgathers: int
    bytes_on_wire: float
    cost: float  # cost-model seconds estimate
    cost_model: CostModel
    segments: int = 1
    sub_plans: tuple = ()
    kernel_passes: int = 0
    # Exact γ-term byte laws off the schedule IR (Σ over ⊕-steps /
    # HBM passes of the bytes each one touches); -1 falls back to the
    # uniform ops·⌈m/S⌉ product, which they equal for every uniform
    # (non-block) schedule.
    op_bytes: float = -1.0
    pass_bytes: float = -1.0

    def schedule(self) -> "schedule_lib.Schedule":
        """The executable round-by-round IR of this plan (cached).

        Multi-axis plans compose their sub-plans' schedules into one
        axis-annotated schedule (DESIGN §5 inlined by
        ``schedule_lib.compose``)."""
        if self.sub_plans:
            axes = self.spec.axes
            outer = self.sub_plans[-1]
            outer_axis = None if outer.sub_plans else outer.spec.axes[-1]
            if self.spec.kind == "scan_total":
                inner, outer = self.sub_plans
                return schedule_lib.compose_total(
                    inner.schedule(), outer.schedule(),
                    minor_axis=axes[-1], outer_axis=outer_axis)
            inner, reduce_, outer = self.sub_plans
            return schedule_lib.compose(
                inner.schedule(), reduce_.schedule(), outer.schedule(),
                minor_axis=axes[-1], outer_axis=outer_axis)
        return get_algorithm(self.spec.kind, self.algorithm).schedule(
            self.p, self.segments)

    def execute(self, x, *, executor=None):
        """Run this plan on pytree ``x``.

        With the default (SPMD) executor this must be called inside
        ``shard_map`` with the spec's axis names bound.  Pass a
        :class:`~repro.core.schedule.SimulatorExecutor` to execute
        host-side numpy arrays with a leading rank axis instead.
        """
        m = monoid_lib.get(self.spec.monoid)
        return _run_plan(self, x, m, executor)

    def lower(self, executor=None) -> Callable:
        """A callable ``x -> result`` bound to ``executor`` (None: the
        SPMD ppermute executor over the spec's axis)."""
        return functools.partial(self.execute, executor=executor)

    def describe(self) -> str:
        """Human-readable one-liner (benchmarks print these)."""
        seg = f" S={self.segments}" if self.segments != 1 else ""
        head = (f"{self.spec.kind} scan over p={self.p} "
                f"[{self.algorithm}{seg}] rounds={self.rounds} "
                f"ops={self.op_applications} "
                f"allgathers={self.allgathers} "
                f"wire={self.bytes_on_wire:.0f}B "
                f"cost={self.cost * 1e6:.2f}us")
        for sp in self.sub_plans:
            head += "\n  " + sp.describe().replace("\n", "\n  ")
        return head

    @property
    def cost_model_source(self) -> str:
        """Provenance of the constants that priced this plan:
        "default" (hand-guessed) or "calibrated" (fitted from measured
        schedule timings by :mod:`repro.core.tune`)."""
        return self.cost_model.source

    def _cost_parts(self) -> dict:
        _, op_cost = _monoid_name_and_cost(self.spec.monoid)
        seg_bytes = -(-self.payload_bytes // self.segments) \
            if self.payload_bytes else 0
        return self.cost_model.parts(
            hops=self.rounds + (self.p - 1) * self.allgathers,
            serial_bytes=self.bytes_on_wire, ops=self.op_applications,
            payload_bytes=seg_bytes, op_cost=op_cost,
            passes=self.kernel_passes, op_bytes=self.op_bytes,
            pass_bytes=self.pass_bytes)

    def explain(self) -> tuple:
        """The runner-up table: every candidate algorithm's predicted
        cost under this plan's cost model, and why each loser lost.

        Returns a tuple of dicts (cheapest first), one per candidate
        algorithm at its best segment count, with the winner marked
        ``chosen=True``.  ``why`` names the dominant α/β/γ component of
        the loser's cost excess over the chosen plan (or notes that the
        spec pinned the choice).  Composite (multi-axis) plans return
        the concatenation of their sub-plans' tables, each row tagged
        with its axis.
        """
        if self.sub_plans:
            return tuple(row for sp in self.sub_plans
                         for row in sp.explain())
        free = dataclasses.replace(self.spec, algorithm="auto",
                                   segments=None)
        best: dict[str, ScanPlan] = {}
        for cand in _candidate_plans(free, self.p, self.payload_bytes,
                                     self.cost_model):
            cur = best.get(cand.algorithm)
            if cur is None or (cand.cost, cand.rounds, cand.segments) \
                    < (cur.cost, cur.rounds, cur.segments):
                best[cand.algorithm] = cand
        best[self.algorithm] = self  # the resolved plan speaks for itself
        chosen_parts = self._cost_parts()
        pinned = self.spec.algorithm != "auto"
        rows = []
        order = sorted(best.values(),
                       key=lambda pl: (pl.cost, pl.rounds, pl.algorithm))
        cheapest = order[0]
        for cand in order:
            parts = cand._cost_parts()
            if cand.algorithm == self.algorithm:
                why = ("pinned by spec" if pinned
                       else "chosen: minimum α·hops+β·bytes+γ·⊕ cost")
                if pinned and cand is not cheapest:
                    why += (f" (auto would pick {cheapest.algorithm}, "
                            f"{(self.cost - cheapest.cost) * 1e6:.3g}us "
                            f"cheaper)")
            else:
                excess = {k: parts[k] - chosen_parts[k] for k in parts}
                delta = cand.cost - self.cost
                if delta >= 0:
                    dom = max(excess, key=lambda k: excess[k])
                    why = (f"+{delta * 1e6:.3g}us vs "
                           f"{self.algorithm}, dominated by {dom} "
                           f"(+{excess[dom] * 1e6:.3g}us)")
                else:
                    # only reachable under a pinned spec: the pin kept
                    # a cheaper candidate from winning
                    dom = min(excess, key=lambda k: excess[k])
                    why = (f"{-delta * 1e6:.3g}us cheaper than pinned "
                           f"{self.algorithm}, led by {dom} "
                           f"({excess[dom] * 1e6:.3g}us)")
            rows.append({
                "axis": self.spec.axes[-1],
                "algorithm": cand.algorithm,
                "segments": cand.segments,
                "rounds": cand.rounds,
                "op_applications": cand.op_applications,
                "allgathers": cand.allgathers,
                "bytes_on_wire": cand.bytes_on_wire,
                "kernel_passes": cand.kernel_passes,
                "cost": cand.cost,
                "cost_alpha": parts["alpha"],
                "cost_beta": parts["beta"],
                "cost_gamma": parts["gamma"],
                "chosen": cand.algorithm == self.algorithm,
                "why": why,
            })
        return tuple(rows)


def _monoid_name_and_cost(monoid) -> tuple[str, float]:
    m = monoid_lib.get(monoid)
    return m.name, getattr(m, "op_cost", 1.0)


def _candidate_plans(spec: ScanSpec, p: int, nbytes: int,
                     cm: CostModel) -> list[ScanPlan]:
    """Every (algorithm, segment-count) candidate for one axis, priced.

    For segmentable algorithms (the pipelined ring) the segment count S
    is part of the optimization: candidates are power-of-two S up to
    ``MAX_SEGMENTS`` (and no finer than one byte per segment), each
    priced at α·(p−2+S) + β·(p−2+S)·⌈m/S⌉ + γ·ops·⌈m/S⌉ — the α/β
    trade-off of the paper's large-m pipelining citation.
    """
    _, op_cost = _monoid_name_and_cost(spec.monoid)
    mono = monoid_lib.get(spec.monoid)

    def one(algo: ScanAlgorithm, S: int) -> ScanPlan:
        sched = algo.schedule(p, S)
        rounds = sched.rounds
        # monoid-aware: commutative monoids elide the redundant
        # combine order in butterfly exchange (2→1) and scan_reduce
        # (3→2) rounds — the executors apply the same elision, so
        # the prediction still equals collect_stats() measurement
        ops = sched.op_count(mono.commutative)
        ag = sched.allgathers
        seg_bytes = -(-nbytes // S) if nbytes else 0
        # per-step byte laws off the IR (DESIGN §7): for uniform
        # schedules these reduce to rounds·⌈m/S⌉ / ops·⌈m/S⌉ exactly;
        # block-distributed schedules shrink per-round payloads, which
        # is where their 2·(p−1)/p·m wire total comes from
        wire = (schedule_lib.wire_bytes(sched, nbytes)
                + ag * p * nbytes)
        op_bytes = schedule_lib.op_wire_bytes(sched, nbytes,
                                              mono.commutative)
        passes = sched.kernel_passes(mono.commutative)
        pass_bytes = schedule_lib.pass_wire_bytes(sched, nbytes,
                                                  mono.commutative)
        return ScanPlan(
            spec=spec, p=p, algorithm=algo.name, payload_bytes=nbytes,
            rounds=rounds, op_applications=ops, allgathers=ag,
            bytes_on_wire=wire,
            cost=cm.cost(hops=rounds + (p - 1) * ag,
                         serial_bytes=wire, ops=ops,
                         payload_bytes=seg_bytes, op_cost=op_cost,
                         passes=passes, op_bytes=op_bytes,
                         pass_bytes=pass_bytes),
            cost_model=cm, segments=S, kernel_passes=passes,
            op_bytes=op_bytes, pass_bytes=pass_bytes)

    def candidates(algo: ScanAlgorithm) -> list[ScanPlan]:
        if algo.requires_segmentable and not mono.segmentable:
            if spec.algorithm != "auto":
                raise ValueError(
                    f"algorithm {algo.name!r} splits the payload into "
                    f"row blocks and requires a segmentable monoid; "
                    f"monoid {mono.name!r} is not")
            return []
        if not (algo.segmentable and mono.segmentable):
            if spec.segments not in (None, 1) and spec.algorithm != "auto":
                raise ValueError(
                    f"algorithm {algo.name!r} (monoid "
                    f"{mono.name!r}) does not support segmentation; "
                    f"got segments={spec.segments}")
            return [one(algo, 1)]
        if spec.segments is not None:
            # pins are honoured verbatim; an S beyond the payload's
            # element count degenerates to 1-element segments (measured
            # bytes exceed the ceil(m/S) prediction)
            return [one(algo, max(1, int(spec.segments)))]
        # segments cannot be finer than one element; the planner only
        # knows bytes, so cap S at nbytes/8 (the largest itemsize) to
        # keep the predicted ceil(m/S) above the achievable floor
        ss, s = [], 1
        while s <= min(MAX_SEGMENTS, max(1, nbytes // 8)):
            ss.append(s)
            s *= 2
        return [one(algo, s) for s in ss]

    _ensure_registered()
    if spec.algorithm != "auto":
        algos = [get_algorithm(spec.kind, spec.algorithm)]
    else:
        algos = [a for (k, _), a in sorted(_REGISTRY.items())
                 if k == spec.kind]
        if not algos:
            raise ValueError(f"no algorithms registered for {spec.kind!r}")
    return [pl for a in algos for pl in candidates(a)]


def _plan_single(spec: ScanSpec, p: int, nbytes: int,
                 cm: CostModel) -> ScanPlan:
    """Plan one axis: resolve "auto" by cost, fill predicted counts."""
    # deterministic tie-break: cost, then rounds, name, fewest segments
    plans = _candidate_plans(spec, p, nbytes, cm)
    return min(plans, key=lambda pl: (pl.cost, pl.rounds, pl.algorithm,
                                      pl.segments))


PLAN_CACHE_MAXSIZE = 1024


def _plan_impl(spec: ScanSpec, ps: tuple, nbytes: int,
               cms: tuple) -> ScanPlan:
    """Memoized planning, keyed by *resolved* per-axis cost models.

    ``cms`` is one :class:`CostModel` per axis of ``spec.axes`` — the
    caller (:func:`plan`) resolves callables/profiles *before* the
    cache lookup, so the key is the pricing constants themselves (a
    value fingerprint), never a resolver's object identity.  Per-call
    closures that resolve to the same constants hit the cache, and
    installing a recalibrated profile changes the key, invalidating
    every stale plan at once."""
    if len(ps) == 1:
        return _plan_single(spec, ps[0], nbytes, cms[0])
    # Multi-axis rewrite (DESIGN.md §5): exscan within the minor axis,
    # allreduce of the minor-axis total, exscan of totals over the
    # major axes, then one ⊕ combining outer and inner.  The top-level
    # algorithm is the honest composite label, never the inner's name;
    # schedule() inlines the sub-plans into one composed schedule.
    if spec.kind not in ("exclusive", "scan_total"):
        raise ValueError(
            f"multi-axis scan only supports kind 'exclusive' or "
            f"'scan_total', got {spec.kind!r}")
    _, op_cost = _monoid_name_and_cost(spec.monoid)
    axes = spec.axes
    inner = _plan_cached(
        spec.over(axes[-1]), (ps[-1],), nbytes, cms[-1:])
    outer = _plan_cached(
        spec.over(axes[:-1] if len(axes) > 2 else axes[0]),
        ps[:-1], nbytes, cms[:-1])
    if spec.kind == "scan_total":
        # the inner scan_total's total IS the minor-axis allreduce:
        # no separate reduce stage (schedule_lib.compose_total)
        subs = (inner, outer)
        label = f"composite({inner.algorithm}+{outer.algorithm})"
    else:
        reduce_ = _plan_cached(
            spec.over(axes[-1], kind="allreduce", algorithm="auto"),
            (ps[-1],), nbytes, cms[-1:])
        subs = (inner, reduce_, outer)
        label = (f"composite({inner.algorithm}+{reduce_.algorithm}"
                 f"+{outer.algorithm})")
    cm_top = cms[-1]  # final ⊕ is local compute
    return ScanPlan(
        spec=spec, p=int(np.prod(ps)),
        algorithm=label, payload_bytes=nbytes,
        rounds=sum(s.rounds for s in subs),
        op_applications=sum(s.op_applications for s in subs) + 1,
        allgathers=sum(s.allgathers for s in subs),
        bytes_on_wire=sum(s.bytes_on_wire for s in subs),
        cost=sum(s.cost for s in subs) + cm_top.gamma * nbytes * op_cost,
        cost_model=cm_top, sub_plans=subs,
        kernel_passes=sum(s.kernel_passes for s in subs),
        op_bytes=(sum(s.op_bytes for s in subs)
                  if all(s.op_bytes >= 0 for s in subs) else -1.0),
        pass_bytes=(sum(s.pass_bytes for s in subs)
                    if all(s.pass_bytes >= 0 for s in subs) else -1.0))


# functools.lru_cache counts a miss even when the wrapped call raises
# (no entry is stored), so eviction accounting needs the error misses
# tracked separately: evictions = misses - error_misses - currsize.
_plan_error_misses = 0


def _plan_counted(spec: ScanSpec, ps: tuple, nbytes: int,
                  cms: tuple) -> ScanPlan:
    global _plan_error_misses
    try:
        return _plan_impl(spec, ps, nbytes, cms)
    except BaseException:
        _plan_error_misses += 1
        raise


_plan_cached = functools.lru_cache(maxsize=PLAN_CACHE_MAXSIZE)(
    _plan_counted)


def plan(spec: ScanSpec, p: int | tuple | None = None, *,
         nbytes: int | None = None,
         cost_model=None) -> ScanPlan:
    """Resolve ``spec`` into an inspectable :class:`ScanPlan`.

    Args:
      spec: what to compute.
      p: axis size, or tuple of sizes matching ``spec.axes`` for a
        multi-axis scan (major→minor).
      nbytes: per-rank payload size in bytes (falls back to
        ``spec.payload_bytes``, then 0 — a pure round-count plan).
      cost_model: overrides the ambient :func:`current_cost_model`; a
        :class:`CostModel`, a :class:`CostProfile`, or a per-axis
        ``axis_name -> CostModel`` callable.

    Plans are cached by (spec, axis sizes, payload bytes, *resolved*
    per-axis pricing constants): callables/profiles are resolved to one
    :class:`CostModel` per axis before the lookup, so equal constants
    hit the cache regardless of resolver identity, and installing a
    recalibrated profile invalidates stale plans by changing the key.
    Repeated calls with the same signature return the same object.
    """
    if p is None:
        raise ValueError("plan() needs the axis size(s) p")
    ps = tuple(p) if isinstance(p, (tuple, list)) else (int(p),)
    if len(ps) != len(spec.axes):
        raise ValueError(
            f"got {len(ps)} axis sizes for {len(spec.axes)} axes "
            f"({spec.axes})")
    m_bytes = nbytes if nbytes is not None else (spec.payload_bytes or 0)
    cm = cost_model if cost_model is not None else current_cost_model()
    cms = tuple(_resolve_cm(cm, a) for a in spec.axes)
    for a, resolved in zip(spec.axes, cms):
        if not isinstance(resolved, CostModel):
            raise TypeError(
                f"cost model for axis {a!r} resolved to "
                f"{type(resolved).__name__}, expected CostModel")
    return _plan_cached(spec, ps, int(m_bytes), cms)


def plan_hierarchical(spec: ScanSpec, *, p_inter: int, p_intra: int,
                      nbytes: int | None = None, cost_model=None,
                      inter_axis: str = "proc",
                      intra_axis: str = "local") -> ScanPlan:
    """Two-level hierarchical planning: factor p = p_inter × p_intra.

    The multi-process execution model (DESIGN §11): ``p_intra`` ranks
    live inside each of ``p_inter`` OS processes/hosts, so the intra
    axis rides the fast "ici" tier while the inter axis crosses the
    slow "dci" tier.  This re-targets ``spec`` at the
    ``(inter_axis, intra_axis)`` pair — the standard multi-axis
    rewrite then composes intra-tier exscan + bridging reduce +
    inter-tier exscan into ONE axis-annotated schedule
    (``schedule_lib.compose``) — and routes ``inter_axis`` to the
    "dci" tier of the pricing profile, so **each tier's algorithm is
    chosen independently by that tier's cost model** (e.g. doubling
    intra-host, segmented ring inter-host).  ``plan.explain()`` shows
    the per-tier runner-up tables, one row set per axis.

    ``cost_model`` defaults to the installed launch-layer profile
    (``launch.mesh.current_profile()``), which carries the ici/dci
    tier split; a plain :class:`CostModel` prices both tiers alike
    (the algorithms may then legitimately coincide).
    """
    if p_inter < 1 or p_intra < 1:
        raise ValueError(f"need p_inter >= 1 and p_intra >= 1, got "
                         f"{p_inter}/{p_intra}")
    cm = cost_model
    if cm is None:
        cm = current_cost_model()
        if cm is DEFAULT_COST_MODEL:
            # nothing installed: the launch layer's tiered profile is
            # the only default that can tell the two tiers apart
            from repro.launch import mesh as mesh_lib  # lazy: no cycle

            cm = mesh_lib.current_profile()
    if isinstance(cm, CostProfile):
        tier_names = tuple(n for n, _ in cm.tiers)
        if ("dci" in tier_names
                and inter_axis not in dict(cm.axis_tiers)):
            cm = dataclasses.replace(
                cm, axis_tiers=cm.axis_tiers + ((inter_axis, "dci"),))
    return plan(spec.over((inter_axis, intra_axis)),
                (int(p_inter), int(p_intra)), nbytes=nbytes,
                cost_model=cm)


def factor_ranks(p: int, nprocs: int) -> tuple[int, int]:
    """Split a total rank count into (p_inter, p_intra) for ``nprocs``
    worker processes; ``nprocs`` must divide ``p``."""
    if nprocs < 1:
        raise ValueError(f"need nprocs >= 1, got {nprocs}")
    if p % nprocs:
        raise ValueError(
            f"process count {nprocs} must divide total ranks {p}")
    return nprocs, p // nprocs


def plan_cache_clear():
    global _plan_error_misses
    _plan_cached.cache_clear()
    _plan_error_misses = 0


def plan_cache_resize(maxsize: int = PLAN_CACHE_MAXSIZE) -> int:
    """Rebuild the plan cache with a new LRU capacity (entries are
    dropped).  The cache is *always* bounded — least-recently-used
    plans are evicted at capacity — so a long-running service cannot
    grow it without bound; services that want a tighter ceiling than
    :data:`PLAN_CACHE_MAXSIZE` (or a larger one for a big declared
    bucket set) install it here before warmup.

    Returns the number of cached entries dropped by the rebuild, which
    is how the autotune controller reports how many stale plans a
    profile install flushed (calling with the current maxsize is the
    idiomatic "drop everything now" — distinct from LRU pressure,
    which ``plan_cache_info()['evictions']`` counts)."""
    global _plan_cached, _plan_error_misses
    if maxsize is not None and maxsize < 1:
        raise ValueError(f"plan cache maxsize must be >= 1, "
                         f"got {maxsize}")
    dropped = _plan_cached.cache_info().currsize
    _plan_cached = functools.lru_cache(maxsize=maxsize)(_plan_counted)
    _plan_error_misses = 0
    return dropped


def plan_cache_info() -> dict:
    """Plan-cache observability: hit/miss counters plus size of the
    memoized ``plan()`` resolution (printed by ``benchmarks/plan_table
    .py --verbose``; the serve subsystem's warmup gate reads the miss
    counter to prove steady state never compiles).  Repeated ``plan()``
    calls with the same (spec, axis sizes, payload bytes, cost model)
    signature are cache hits; ``size`` never exceeds ``maxsize``.

    ``evictions`` counts entries LRU-dropped under capacity pressure
    in the current cache generation (a miss that raised stores no
    entry and is excluded).  ``plan_cache_resize`` starts a fresh
    generation — its *return value* accounts for the dropped entries,
    so drift-invalidation flushes never masquerade as LRU pressure."""
    info = _plan_cached.cache_info()
    evictions = max(0, info.misses - _plan_error_misses - info.currsize)
    return {"hits": info.hits, "misses": info.misses,
            "size": info.currsize, "maxsize": info.maxsize,
            "evictions": evictions}


# ---------------------------------------------------------------------------
# scan(): execute a spec inside shard_map
# ---------------------------------------------------------------------------


def _tree_nbytes(tree) -> int:
    import jax

    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def _run_plan(pl: ScanPlan, x, m: monoid_lib.Monoid, executor=None):
    # Multi-axis plans need no special-casing: schedule() composes the
    # sub-plans into one axis-annotated schedule that every executor
    # runs (the composed steps carry their own axis names, so the
    # default executor axis only matters for single-axis plans).
    if executor is None:
        executor = schedule_lib.SPMDExecutor(pl.spec.axes[-1])
    return executor.execute(pl.schedule(), x, m)


def scan(x, spec: ScanSpec, *, cost_model=None, executor=None):
    """Execute ``spec`` on pytree ``x`` along its named mesh axes.

    Must be called inside ``shard_map`` (or wherever the axis names are
    bound).  Resolves a :class:`ScanPlan` first — with the payload size
    taken from ``x`` itself — then runs the plan's schedule;
    ``algorithm="auto"`` specs therefore adapt per call site to the
    actual message size (including the ring's segment count S).

    ``executor`` overrides the backend (e.g.
    :class:`~repro.core.schedule.PallasExecutor` to run each round's ⊕
    through the on-chip block-combine kernel) — multi-axis specs
    included, since they compose into one axis-annotated schedule.
    """
    _ensure_registered()
    from jax import lax

    if spec.axis_name is None:
        raise ValueError("scan() needs spec.axis_name to be set "
                         "(use spec.over(axis_name))")
    m = monoid_lib.get(spec.monoid)
    ps = tuple(lax.axis_size(a) for a in spec.axes)
    pl = plan(spec, ps if len(ps) > 1 else ps[0],
              nbytes=_tree_nbytes(x), cost_model=cost_model)
    return _run_plan(pl, x, m, executor)


def scan_with_total(x, spec: ScanSpec, *, cost_model=None,
                    executor=None):
    """Fused exclusive scan + allreduce of the same payload: returns
    ``(prefix, total)`` from ONE "scan_total" schedule instead of two
    back-to-back collectives.

    For power-of-two p the fused (prefix, total) butterfly computes
    both in the allreduce's ⌈log₂p⌉ rounds; otherwise the exscan's
    last rank completes the total with one local ⊕ and broadcasts it.
    Pinned exclusive algorithm names carry over (every exclusive
    algorithm registers a ``with_total`` scan_total variant), so
    benchmark pins keep comparing like for like.  Multi-axis specs
    compose: the inner scan_total's total IS the minor-axis allreduce
    the DESIGN §5 rewrite needs, so the fused form shares those rounds
    instead of re-running them.
    """
    if spec.kind not in ("exclusive", "scan_total"):
        raise ValueError(
            f"scan_with_total fuses exclusive scans, got kind="
            f"{spec.kind!r}")
    _ensure_registered()
    algo = spec.algorithm
    if algo != "auto":
        # pins must stay like for like: an unknown name raises (with
        # the scan_total registry) rather than silently running "auto"
        get_algorithm("scan_total", algo)
    return scan(x, spec.over(spec.axis_name, kind="scan_total",
                             algorithm=algo),
                cost_model=cost_model, executor=executor)


# ---------------------------------------------------------------------------
# Fusing k concurrent small scans into shared rounds
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    """The planner's fuse-or-not decision for k concurrent scans.

    ``plans`` are the k serial plans (one per payload), ``packed`` the
    single-plan candidate priced at the packed payload size, ``fused``
    whether packing won: the α saving of riding one schedule's rounds
    must beat the β cost of the packed payload under the ambient cost
    model.  ``rounds``/``cost`` reflect the chosen execution.
    """

    plans: tuple[ScanPlan, ...]
    packed: ScanPlan
    fused: bool

    @property
    def rounds(self) -> int:
        return self.packed.rounds if self.fused else \
            sum(pl.rounds for pl in self.plans)

    @property
    def cost(self) -> float:
        return self.packed.cost if self.fused else \
            sum(pl.cost for pl in self.plans)

    def describe(self) -> str:
        serial = sum(pl.rounds for pl in self.plans)
        head = (f"fused_scan k={len(self.plans)} p={self.packed.p} "
                f"[{'fused' if self.fused else 'serial'}] "
                f"rounds={self.rounds} (serial={serial}) "
                f"cost={self.cost * 1e6:.2f}us")
        return head

    def schedule(self, layout) -> "schedule_lib.Schedule":
        """The fused schedule carrying ``layout`` (raises when the
        decision was serial)."""
        if not self.fused:
            raise ValueError("plan decided against fusing; execute "
                             "the serial plans instead")
        return schedule_lib.fuse([self.packed.schedule()], layout)

    def execute(self, xs, *, executor=None):
        """Run the k scans on payloads ``xs`` (same order as the
        plans), fused or serial per the decision.  Returns the list of
        k results."""
        m = monoid_lib.get(self.plans[0].spec.monoid)
        if not self.fused:
            return [_run_plan(pl, x, m, executor)
                    for pl, x in zip(self.plans, xs)]
        lead = 1 if isinstance(executor,
                               schedule_lib.SimulatorExecutor) else 0
        layout = schedule_lib.make_layout(xs, lead=lead)
        if executor is None:
            executor = schedule_lib.SPMDExecutor(
                self.packed.spec.axes[-1])
        return list(executor.execute(self.schedule(layout), xs, m))

    def verify(self, *, rank_elems: int = 3, seed: int = 0) -> dict:
        """Simulator drift check: the fused execution must reproduce k
        independent host references while measuring exactly the packed
        plan's rounds/⊕/all-gathers (single-scan round count, not k×).
        """
        import jax

        m = monoid_lib.get(self.plans[0].spec.monoid)
        op = monoid_lib.NUMPY_OPS.get(m.name, m.op)
        ident_fn = monoid_lib.NUMPY_IDENTITY.get(
            m.name,
            lambda t: jax.tree.map(np.asarray, m.identity_like(t)))
        p = self.packed.p
        xs = [schedule_lib._witness_payload(
            m.name, p, rank_elems + i, seed + i)
            for i in range(len(self.plans))]
        with schedule_lib.collect_stats() as st:
            got = self.execute(xs,
                               executor=schedule_lib.SimulatorExecutor())
        ok_vals = True
        for g, x in zip(got, xs):
            want = schedule_lib._host_reference(
                self.plans[0].spec.kind, x, op, ident_fn, p)
            ok_vals = ok_vals and all(
                np.allclose(a, b, rtol=1e-10, atol=1e-12)
                for a, b in zip(jax.tree.leaves(g),
                                jax.tree.leaves(want)))
        want_plan = self.packed if self.fused else None
        res = {
            "k": len(self.plans), "p": p, "fused": self.fused,
            "rounds_predicted": self.rounds,
            "rounds_measured": st.rounds,
            "correct": bool(ok_vals),
        }
        if want_plan is not None:
            res.update(
                ops_predicted=want_plan.op_applications,
                ops_measured=st.op_applications,
                allgathers_predicted=want_plan.allgathers,
                allgathers_measured=st.allgathers)
            res["ok"] = bool(
                ok_vals
                and st.rounds == want_plan.rounds
                and st.op_applications == want_plan.op_applications
                and st.allgathers == want_plan.allgathers)
        else:
            res["ok"] = bool(ok_vals and st.rounds == self.rounds)
        return res


def plan_fused(specs, p, nbytes_list, *, cost_model=None) -> FusedPlan:
    """Price k concurrent scans fused vs serial (the tentpole's α/β
    trade-off): the packed candidate pays one schedule's α·q but moves
    the concatenated payload every round; each serial plan optimizes
    its own payload.  Fusion requires one (kind, axis, monoid)
    signature, a single algorithm choice, and a monoid whose ⊕ acts on
    aligned element positions independently (``Monoid.segmentable`` —
    packing concatenates flattened leaves)."""
    specs = list(specs)
    if not specs:
        raise ValueError("plan_fused needs at least one spec")
    s0 = specs[0]
    mono = monoid_lib.get(s0.monoid)
    fusable = mono.segmentable
    for s in specs[1:]:
        if (s.kind, s.axis_name) != (s0.kind, s0.axis_name):
            raise ValueError(
                "fused scans must share kind and axis; got "
                f"{(s.kind, s.axis_name)} vs {(s0.kind, s0.axis_name)}")
        if monoid_lib.get(s.monoid).name != mono.name:
            raise ValueError("fused scans must share one monoid")
        if s.algorithm != s0.algorithm:
            fusable = False  # conflicting pins: run serially
    nbytes_list = [int(nb) for nb in nbytes_list]
    if len(nbytes_list) != len(specs):
        raise ValueError("one payload size per spec required")
    cm = cost_model or current_cost_model()
    serial = tuple(plan(s, p, nbytes=nb, cost_model=cm)
                   for s, nb in zip(specs, nbytes_list))
    packed = plan(s0, p, nbytes=sum(nbytes_list), cost_model=cm)
    fused = bool(fusable and len(specs) > 1
                 and packed.cost < sum(pl.cost for pl in serial))
    return FusedPlan(plans=serial, packed=packed, fused=fused)


def fused_scan(pairs, *, cost_model=None, executor=None):
    """Execute k concurrent scans, fused into shared rounds when the
    cost model approves: ``fused_scan([(x1, spec1), (x2, spec2), ...])``
    returns the list of k results.

    Inside ``shard_map``, k small same-axis exscans issued per step
    (MoE dispatch counts, compression offsets, pipeline offsets) pay
    k·α·q serially; packed into one flattened payload
    (:class:`~repro.core.schedule.PayloadLayout`) they ride a single
    schedule's q rounds.  The decision is :func:`plan_fused`'s — pass
    ``plan_fused(...)`` the same specs/sizes to inspect it first.
    """
    pairs = list(pairs)
    if not pairs:
        return []
    xs = [x for x, _ in pairs]
    specs = [s for _, s in pairs]
    _ensure_registered()
    from jax import lax

    s0 = specs[0]
    if s0.axis_name is None:
        raise ValueError("fused_scan needs spec.axis_name to be set")
    ps = tuple(lax.axis_size(a) for a in s0.axes)
    fp = plan_fused(specs, ps if len(ps) > 1 else ps[0],
                    [_tree_nbytes(x) for x in xs],
                    cost_model=cost_model)
    return fp.execute(xs, executor=executor)


# ---------------------------------------------------------------------------
# Host-side twin
# ---------------------------------------------------------------------------


def host_exscan(lengths: np.ndarray) -> np.ndarray:
    """Numpy twin of the exclusive scan for host-side code (the data
    pipeline's document offsets): out[r] = sum(lengths[:r]), out[0]=0."""
    lengths = np.asarray(lengths)
    out = np.zeros_like(lengths)
    if lengths.shape[0] > 1:
        np.cumsum(lengths[:-1], axis=0, out=out[1:])
    return out


def host_fused_exscan(arrays) -> list:
    """Host twin of :func:`fused_scan` for k exclusive sums over the
    same leading axis: the columns are packed into one buffer and
    scanned in a single pass (one traversal instead of k), then
    unpacked — e.g. the data pipeline's document offsets and ordinals.
    """
    arrays = [np.asarray(a) for a in arrays]
    if not arrays:
        return []
    n = arrays[0].shape[0]
    cols = []
    for a in arrays:
        if a.shape[0] != n:
            raise ValueError("fused host exscans must share their "
                             f"leading axis ({a.shape[0]} != {n})")
        cols.append(a.reshape(n, -1))
    packed = host_exscan(np.concatenate(cols, axis=1))
    outs, off = [], 0
    for a, c in zip(arrays, cols):
        outs.append(packed[:, off:off + c.shape[1]].reshape(a.shape))
        off += c.shape[1]
    return outs
