"""Cost-model calibration: measure schedules, fit α/β/γ, persist.

The paper's empirical contribution is that *measured* crossovers — not
modeled constants — decide which exscan algorithm wins on a machine.
This module turns the planner's hand-guessed α/β/γ defaults into a
**calibrated, provenance-carrying** :class:`~repro.core.scan_api
.CostProfile`:

  1. **Microbenchmark harness** — every registered algorithm's
     *schedule* (the executable IR of :mod:`repro.core.schedule`) is
     timed over a (p × payload-bytes) sweep.  Two clocks:

       * ``walltime`` — the SPMD executor traced under ``shard_map``
         on real devices (:func:`measure_schedule_walltime`);
       * ``simulated`` — the schedule executed in the
         :class:`~repro.core.schedule.SimulatorExecutor` under
         ``collect_stats()``, with seconds derived deterministically
         from the *measured* hop/byte/⊕ counts under a ground-truth
         cost model (:func:`measure_schedule_simulated`).  Device-free
         and bit-reproducible, so calibration runs in CI; any drift
         between the IR's predicted features and the executed
         schedule's measured counts surfaces as fit residual.

  2. **Fit** — per interconnect tier, non-negative least squares
     (:func:`nnls`, Lawson–Hanson) of the measured seconds against the
     IR-derived features (latency hops, serialized bytes, ⊕ bytes)
     recovers α, β, γ ≥ 0 with a relative-RMS residual diagnostic
     (:func:`fit_tier`).

  3. **Persistence** — profiles serialize to JSON keyed by mesh
     fingerprint with schema versioning (:func:`save_profile` /
     :func:`load_profile`); ``launch.mesh.axis_cost_model`` resolves a
     calibrated profile before falling back to defaults, and because
     the plan cache is keyed by resolved pricing constants, installing
     a new profile invalidates every stale plan.

One-command device-free flow::

    PYTHONPATH=src python -m repro.core.tune --simulate
    # writes tune/profiles/profile_<mesh-fingerprint>.json and prints
    # the fitted constants + per-tier fit residuals

after which ``plan(...)`` under ``launch.mesh.axis_cost_model`` yields
``ScanPlan.cost_model_source == "calibrated"``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import time

import numpy as np

from repro.core import monoid as monoid_lib
from repro.core import scan_api
from repro.core import schedule as schedule_lib
from repro.core.scan_api import (
    PROFILE_SCHEMA_VERSION, CostModel, CostProfile)

# Default (p × payload-bytes) sweep: p values straddle powers of two
# (the 123/two_op boundary cases) and m spans the α-dominated to
# β-dominated regimes.  Payload sizes are multiples of 512 bytes so
# every power-of-two segment count S ≤ 64 divides the int64 element
# count exactly (measured bytes == ceil(m/S) with no padding slack).
DEFAULT_PS = (2, 3, 4, 5, 7, 8, 9, 12, 16, 17)
DEFAULT_MS = (512, 8192, 131_072, 1_048_576)
RING_SEGMENTS = (1, 8, 64)

DEFAULT_PROFILE_DIR = os.path.join("tune", "profiles")


# ---------------------------------------------------------------------------
# Non-negative least squares (Lawson–Hanson active set)
# ---------------------------------------------------------------------------


def nnls(A, b, *, max_iter: int | None = None,
         tol: float = 1e-12) -> np.ndarray:
    """Solve ``min ||Ax - b||`` subject to ``x >= 0``.

    The classic Lawson–Hanson active-set method — tiny systems only
    (calibration fits 3 unknowns), so no scipy dependency."""
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64).reshape(-1)
    n = A.shape[1]
    if max_iter is None:
        max_iter = 3 * n + 30
    x = np.zeros(n)
    passive = np.zeros(n, dtype=bool)
    w = A.T @ (b - A @ x)
    for _ in range(max_iter):
        if passive.all() or w[~passive].max(initial=-np.inf) <= tol:
            break
        j = int(np.argmax(np.where(passive, -np.inf, w)))
        passive[j] = True
        while True:
            z = np.zeros(n)
            cols = np.flatnonzero(passive)
            sol, *_ = np.linalg.lstsq(A[:, cols], b, rcond=None)
            z[cols] = sol
            if (z[cols] > tol).all():
                x = z
                break
            # step toward z until the first passive coordinate hits 0
            neg = cols[z[cols] <= tol]
            alpha = min(x[k] / (x[k] - z[k]) for k in neg
                        if x[k] != z[k])
            x = x + alpha * (z - x)
            passive &= x > tol
            if not passive.any():
                x = np.zeros(n)
                break
        w = A.T @ (b - A @ x)
    return np.maximum(x, 0.0)


# ---------------------------------------------------------------------------
# Features: the IR-derived regressors the fit prices seconds against
# ---------------------------------------------------------------------------


def schedule_features(sched: "schedule_lib.Schedule", nbytes: int,
                      op_cost: float = 1.0, *,
                      commutative: bool = False,
                      passes: bool = False) -> tuple:
    """(latency_hops, serial_bytes, op_bytes) counted off the IR.

    Mirrors the planner's pricing conventions exactly
    (``scan_api._candidate_plans``): all-gathers cost p−1 ring hops and
    p·m wire bytes; a pipelined-ring round carries ⌈m/S⌉ bytes; the γ
    regressor is total ⊕ executions × the per-⊕ segment bytes × the
    monoid's relative op cost.  ``commutative`` applies the same
    combine-order elision the executors and planner apply
    (``Schedule.op_count``) — butterfly exchange 2→1, scan_reduce 3→2
    ⊕ per round — so fitted γ constants price elided schedules
    consistently.

    With ``passes=True`` a fourth regressor is appended —
    ``pass_bytes``, the fused-path HBM-pass count
    (``Schedule.kernel_passes``, DESIGN §7) × segment bytes — matching
    what a nonzero ``CostModel.gamma_pass`` prices.  The default stays
    the 3-tuple, so the :class:`Sample` schema and the 3-column NNLS
    design are untouched unless a caller opts in."""
    p = sched.p
    hops = 0.0
    wire = 0.0
    for st in sched.steps:
        if st.is_round:
            hops += 1
            wire += schedule_lib.step_wire_bytes(st, nbytes,
                                                 sched.n_segments)
        elif st.kind in ("allgather", "bcast"):
            hops += p - 1
            wire += p * nbytes
    # per-step ⊕/pass byte laws off the IR (DESIGN §7): uniform
    # schedules reduce to op_count·⌈m/S⌉ exactly; block-distributed
    # rounds each touch rows·⌈m/R⌉ of the payload
    op_bytes = schedule_lib.op_wire_bytes(sched, nbytes,
                                          commutative) * op_cost
    if passes:
        pass_bytes = schedule_lib.pass_wire_bytes(sched, nbytes,
                                                  commutative)
        return hops, wire, op_bytes, pass_bytes
    return hops, wire, op_bytes


@dataclasses.dataclass(frozen=True)
class Sample:
    """One timed schedule execution: features + the clock reading."""

    tier: str
    kind: str
    algorithm: str
    p: int
    nbytes: int
    segments: int
    hops: float
    serial_bytes: float
    op_bytes: float
    seconds: float
    clock: str  # "simulated" | "walltime"


def _witness(p: int, nbytes: int, seed: int = 0) -> np.ndarray:
    if nbytes % 8:
        raise ValueError(f"payload bytes must be a multiple of 8 "
                         f"(int64 add witness), got {nbytes}")
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 30,
                        size=(p, nbytes // 8)).astype(np.int64)


def measure_schedule_simulated(
        sched: "schedule_lib.Schedule", nbytes: int,
        truth: CostModel, *, monoid="add",
        seed: int = 0) -> tuple[float, tuple[float, float, float]]:
    """Execute ``sched`` in the numpy simulator and read the
    deterministic simulated clock: seconds = ``truth`` priced on the
    *measured* hop/byte/⊕ counts of the executed schedule.

    Returns ``(seconds, measured_features)``.  Because the clock is a
    pure function of measured counts, calibration data generated from
    a known α/β/γ lets the fit recover those constants exactly (the
    property the test suite asserts), while any IR-vs-execution drift
    shows up as residual instead of hiding in noise."""
    m = monoid_lib.get(monoid)
    x = _witness(sched.p, nbytes, seed)
    with schedule_lib.collect_stats() as st:
        schedule_lib.SimulatorExecutor().execute(sched, x, m)
    seg = max((s.seg or sched.n_segments for s in sched.steps
               if s.kind == "seg_shift"), default=1)
    hops = st.rounds + (sched.p - 1) * st.allgathers
    wire = sum(st.bytes_per_round) + st.allgathers * sched.p * nbytes
    # measured ops × the IR's per-⊕ byte law: uniform schedules apply
    # every ⊕ to ⌈m/S⌉ bytes; block schedules touch rows·⌈m/R⌉ per
    # round, so the γ regressor comes from op_wire_bytes (the executors
    # apply exactly op_count ⊕ per step — verified by verify_plan — so
    # measured-count × IR-law equals the IR product)
    op_bytes = schedule_lib.op_wire_bytes(
        sched, nbytes, m.commutative) * getattr(m, "op_cost", 1.0)
    seconds = truth.cost(
        hops=st.rounds + (sched.p - 1) * st.allgathers,
        serial_bytes=wire, ops=st.op_applications,
        payload_bytes=-(-nbytes // seg),
        op_cost=getattr(m, "op_cost", 1.0),
        op_bytes=op_bytes)
    return seconds, (float(hops), float(wire), float(op_bytes))


def measure_schedule_walltime(
        sched: "schedule_lib.Schedule", nbytes: int, *, monoid="add",
        axis_name: str = "x", repeats: int = 5,
        seed: int = 0) -> float:
    """Median walltime of the schedule's SPMD program over ``repeats``
    executions on the first ``p`` local devices (jit-compiled once,
    ``block_until_ready`` timed).  Requires ``p`` real devices."""
    import jax
    from jax import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    devs = jax.devices()
    if len(devs) < sched.p:
        raise RuntimeError(
            f"walltime calibration needs {sched.p} devices, have "
            f"{len(devs)}; use --simulate for the device-free clock")
    m = monoid_lib.get(monoid)
    mesh = Mesh(np.array(devs[:sched.p]).reshape(sched.p), (axis_name,))
    ex = schedule_lib.SPMDExecutor(axis_name)
    fn = jax.jit(shard_map(
        lambda v: ex.execute(sched, v, m), mesh=mesh,
        in_specs=P(axis_name), out_specs=P(axis_name)))
    x = _witness(sched.p, nbytes, seed)
    jax.block_until_ready(fn(x))  # compile outside the timed region
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _sweep_cases(ps, ms):
    """(kind, algorithm, p, m, segments) cells of one tier's sweep:
    every registered exclusive algorithm (the ring at several pinned
    segment counts) plus the allreduce butterfly for feature spread."""
    cases = []
    for p in ps:
        for m in ms:
            for name in scan_api.algorithms("exclusive"):
                algo = scan_api.get_algorithm("exclusive", name)
                if algo.segmentable:
                    elems = max(1, m // 8)
                    ss = sorted({min(S, elems) for S in RING_SEGMENTS})
                    cases.extend(("exclusive", name, p, m, S)
                                 for S in ss)
                else:
                    cases.append(("exclusive", name, p, m, 1))
            for name in scan_api.algorithms("allreduce"):
                cases.append(("allreduce", name, p, m, 1))
    return cases


def calibration_sweep(tier: str, truth: CostModel, *,
                      ps=DEFAULT_PS, ms=DEFAULT_MS,
                      clock: str = "simulated",
                      monoid="add") -> list[Sample]:
    """Time every registered algorithm's schedule over the (p × m)
    sweep on one tier; returns the fit's :class:`Sample` rows."""
    mono = monoid_lib.get(monoid)
    op_cost = getattr(mono, "op_cost", 1.0)
    samples = []
    for kind, name, p, m, S in _sweep_cases(ps, ms):
        sched = scan_api.get_algorithm(kind, name).schedule(p, S)
        feats = schedule_features(sched, m, op_cost,
                                  commutative=mono.commutative)
        if clock == "simulated":
            seconds, measured = measure_schedule_simulated(
                sched, m, truth, monoid=monoid)
        elif clock == "walltime":
            seconds, measured = measure_schedule_walltime(
                sched, m, monoid=monoid), feats
        else:
            raise ValueError(f"unknown clock {clock!r}")
        samples.append(Sample(
            tier=tier, kind=kind, algorithm=name, p=p, nbytes=m,
            segments=S, hops=measured[0], serial_bytes=measured[1],
            op_bytes=measured[2], seconds=seconds, clock=clock))
    return samples


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------


def fit_tier(samples: list[Sample]) -> tuple[CostModel, float]:
    """Fit one tier's (α, β, γ) by NNLS of seconds against the
    hop/byte/⊕-byte features; returns the calibrated kernel and the
    relative RMS residual."""
    if not samples:
        raise ValueError("fit_tier needs at least one sample")
    A = np.array([[s.hops, s.serial_bytes, s.op_bytes]
                  for s in samples], dtype=np.float64)
    b = np.array([s.seconds for s in samples], dtype=np.float64)
    # column scaling: hops ~ 1e1 while byte columns reach 1e7 — put
    # every regressor on unit norm so lstsq conditioning is sane
    scale = np.linalg.norm(A, axis=0)
    scale[scale == 0] = 1.0
    x = nnls(A / scale, b) / scale
    resid = float(np.linalg.norm(A @ x - b)
                  / max(np.linalg.norm(b), 1e-300))
    return CostModel(alpha=float(x[0]), beta=float(x[1]),
                     gamma=float(x[2]), source="calibrated"), resid


def fit_profile(samples_by_tier: dict, *, mesh_fingerprint: str,
                axis_tiers=(), default_tier: str = "ici") -> CostProfile:
    """Fit every tier and assemble the calibrated, provenance-carrying
    :class:`CostProfile` (per-tier relative-RMS residual diagnostics
    included)."""
    tiers, residuals = [], []
    for tier in sorted(samples_by_tier):
        cm, resid = fit_tier(samples_by_tier[tier])
        tiers.append((tier, cm))
        residuals.append((tier, resid))
    return CostProfile(
        tiers=tuple(tiers), source="calibrated",
        mesh_fingerprint=mesh_fingerprint,
        axis_tiers=tuple(axis_tiers), default_tier=default_tier,
        residuals=tuple(residuals))


def calibrate(*, simulate: bool = True, truth: CostProfile | None = None,
              ps=DEFAULT_PS, ms=DEFAULT_MS,
              mesh_fingerprint: str | None = None,
              monoid="add") -> CostProfile:
    """End-to-end calibration: sweep → fit → :class:`CostProfile`.

    ``simulate=True`` (the device-free CI path) reads the deterministic
    simulated clock under ``truth`` — the profile describing the
    machine being simulated (default: the launch-layer default ICI/DCI
    profile).  ``simulate=False`` times the SPMD executor on local
    devices; every mesh axis of a host machine rides one interconnect,
    so the walltime path fits a single tier and reuses it for all."""
    if truth is None:
        from repro.launch import mesh as mesh_lib  # lazy: no cycle

        truth = mesh_lib.DEFAULT_PROFILE
    if simulate:
        samples = {tier: calibration_sweep(
            tier, cm, ps=ps, ms=ms, clock="simulated", monoid=monoid)
            for tier, cm in truth.tiers}
        fp = mesh_fingerprint or "simulated-default"
    else:
        import jax

        ps = tuple(p for p in ps if p <= len(jax.devices()))
        if not ps:
            raise RuntimeError("no usable device counts for walltime "
                               "calibration; pass --simulate")
        local = calibration_sweep(
            truth.default_tier, truth.model(truth.default_tier),
            ps=ps, ms=ms, clock="walltime", monoid=monoid)
        samples = {tier: [dataclasses.replace(s, tier=tier)
                          for s in local]
                   for tier, _ in truth.tiers}
        fp = mesh_fingerprint or local_device_fingerprint()
    return fit_profile(samples, mesh_fingerprint=fp,
                       axis_tiers=truth.axis_tiers,
                       default_tier=truth.default_tier)


def local_device_fingerprint() -> str:
    import jax

    devs = jax.devices()
    kind = getattr(devs[0], "device_kind", "unknown")
    return _sanitize(f"{jax.default_backend()}-{kind}-n{len(devs)}")


# ---------------------------------------------------------------------------
# Cross-process ("dci" tier) calibration through the worker harness
# ---------------------------------------------------------------------------

DIST_MS = (8192, 131_072, 1_048_576)


def dist_fingerprint(nprocs: int, ranks_per_proc: int,
                     platform: str = "cpu") -> str:
    """Profile-store key of a multi-process worker topology — distinct
    from every single-host fingerprint, so cross-process constants
    never alias a local profile."""
    return _sanitize(f"dist-{platform}-procs{nprocs}x{ranks_per_proc}")


def measure_schedule_dist(pool, sched: "schedule_lib.Schedule",
                          nbytes: int, *, monoid="add",
                          repeats: int = 3, seed: int = 0) -> float:
    """Median walltime of ``sched`` executed across ``pool``'s worker
    processes (:class:`repro.dist.launcher.WorkerPool`) — the clock
    that prices real inter-process hops (pickle + loopback TCP), which
    the simulated clock cannot see."""
    x = _witness(sched.p, nbytes, seed)
    res = pool.run(sched, x, monoid=monoid, collect=False,
                   repeats=repeats)
    return float(np.median(res.seconds))


HOP_SIZES = (8, 8192, 131_072, 1_048_576)


def measure_hops(pool, *, sizes=HOP_SIZES, repeats: int = 10) -> list:
    """One-way cross-process hop times over a payload-size sweep
    (``pool.measure_hop`` ping-pongs between process 0 and 1).

    The rows — ``{"nbytes", "seconds"}`` — are the raw "dci" latency
    evidence: ``benchmarks/dist_bench.py`` persists them into
    ``BENCH_dist.json`` so the measured α/β of the fabric is
    reconstructable per PR instead of being discarded after fitting."""
    return [{"nbytes": int(n),
             "seconds": pool.measure_hop(int(n), repeats=repeats)}
            for n in sizes]


def calibration_sweep_dist(pool, *, ms=DIST_MS, monoid="add",
                           repeats: int = 3,
                           tier: str = "dci") -> list[Sample]:
    """Time every registered exclusive algorithm (+ the allreduce
    butterfly) across the worker pool at its fixed p; the rows feed
    :func:`fit_tier` for the cross-process tier."""
    mono = monoid_lib.get(monoid)
    op_cost = getattr(mono, "op_cost", 1.0)
    samples = []
    for kind, name, _, m, S in _sweep_cases((pool.p,), ms):
        sched = scan_api.get_algorithm(kind, name).schedule(pool.p, S)
        feats = schedule_features(sched, m, op_cost,
                                  commutative=mono.commutative)
        seconds = measure_schedule_dist(pool, sched, m, monoid=monoid,
                                        repeats=repeats)
        samples.append(Sample(
            tier=tier, kind=kind, algorithm=name, p=pool.p, nbytes=m,
            segments=S, hops=feats[0], serial_bytes=feats[1],
            op_bytes=feats[2], seconds=seconds, clock="dist"))
    return samples


def calibrate_dist(pool=None, *, nprocs: int = 2,
                   ranks_per_proc: int = 1, ms=DIST_MS, monoid="add",
                   repeats: int = 3,
                   base: CostProfile | None = None) -> CostProfile:
    """Fit the "dci" tier from schedules timed across real worker
    processes; the "ici" tier is carried over from ``base`` (default:
    the launch-layer profile), since intra-process rounds never cross
    the harness.  The profile's ``mesh_fingerprint`` encodes the
    process topology (:func:`dist_fingerprint`), so multi-process
    constants never collide with single-host profiles in the store,
    and ``axis_tiers`` routes the "proc" axis to the fitted tier."""
    if base is None:
        from repro.launch import mesh as mesh_lib  # lazy: no cycle

        base = mesh_lib.DEFAULT_PROFILE
    own_pool = pool is None
    if own_pool:
        from repro.dist.launcher import WorkerPool

        pool = WorkerPool(nprocs, ranks_per_proc)
    try:
        samples = calibration_sweep_dist(pool, ms=ms, monoid=monoid,
                                         repeats=repeats)
        dci, resid = fit_tier(samples)
        fp = dist_fingerprint(pool.nprocs, pool.p_intra,
                              getattr(pool, "platform", "cpu"))
    finally:
        if own_pool:
            pool.close()
    try:
        ici = base.model("ici")
    except KeyError:
        ici = base.model(base.default_tier)
    routing = dict(base.axis_tiers)
    routing["proc"] = "dci"
    return CostProfile(
        tiers=(("dci", dci), ("ici", ici)), source="calibrated",
        mesh_fingerprint=fp, axis_tiers=tuple(sorted(routing.items())),
        default_tier="ici", residuals=(("dci", resid),))


# ---------------------------------------------------------------------------
# Profile store: JSON keyed by mesh fingerprint, schema-versioned
# ---------------------------------------------------------------------------


def _sanitize(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "-", name).strip("-") or "default"


def profile_dir(directory: str | None = None) -> str:
    return directory or os.environ.get("REPRO_PROFILE_DIR",
                                       DEFAULT_PROFILE_DIR)


def profile_path(mesh_fingerprint: str,
                 directory: str | None = None) -> str:
    return os.path.join(profile_dir(directory),
                        f"profile_{_sanitize(mesh_fingerprint)}.json")


def save_profile(profile: CostProfile,
                 directory: str | None = None) -> str:
    """Persist ``profile`` under its mesh fingerprint (atomic
    write-then-rename, like the checkpoint store's commit)."""
    path = profile_path(profile.mesh_fingerprint or "default",
                        directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(profile.to_json(), f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_profile_file(path: str) -> CostProfile:
    with open(path) as f:
        return CostProfile.from_json(json.load(f))


# Anything a corrupted, truncated, or wrong-shaped profile file can
# throw while parsing: syntax errors (JSONDecodeError is a ValueError
# subclass), missing keys, and structurally wrong values ("tiers" a
# string/list instead of a mapping raises AttributeError/TypeError).
# A broken store entry must degrade to defaults, never crash planning.
_LOAD_ERRORS = (ValueError, KeyError, TypeError, AttributeError,
                OSError)


def load_profile(mesh_fingerprint: str,
                 directory: str | None = None) -> CostProfile | None:
    """The persisted profile for a mesh fingerprint, or None when
    missing, unreadable, corrupted, or written under an incompatible
    schema version (callers fall back to defaults — a broken profile
    never poisons planning)."""
    path = profile_path(mesh_fingerprint, directory)
    if not os.path.exists(path):
        return None
    try:
        return load_profile_file(path)
    except _LOAD_ERRORS:
        return None


def latest_profile(directory: str | None = None) -> CostProfile | None:
    """Most recently written profile in the store (benchmarks'
    ``--profile DIR`` convenience), or None."""
    d = profile_dir(directory)
    if not os.path.isdir(d):
        return None
    paths = sorted(
        (os.path.join(d, f) for f in os.listdir(d)
         if f.startswith("profile_") and f.endswith(".json")),
        key=os.path.getmtime, reverse=True)
    for path in paths:
        try:
            return load_profile_file(path)
        except _LOAD_ERRORS:
            continue
    return None


# ---------------------------------------------------------------------------
# CLI: the one-command calibration flow
# ---------------------------------------------------------------------------


def _parse_ints(text: str) -> tuple[int, ...]:
    return tuple(int(t) for t in text.split(",") if t)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Calibrate the scan planner's cost profile from "
                    "measured schedule timings.")
    ap.add_argument("--simulate", action="store_true",
                    help="device-free deterministic simulated clock "
                         "(CI path); omit to time real devices")
    ap.add_argument("--out", default=None,
                    help=f"profile store directory (default "
                         f"{DEFAULT_PROFILE_DIR!r} or $REPRO_PROFILE_DIR)")
    ap.add_argument("--fingerprint", default=None,
                    help="mesh fingerprint key to persist under")
    ap.add_argument("--ps", type=_parse_ints, default=DEFAULT_PS,
                    help="comma-separated rank counts to sweep")
    ap.add_argument("--ms", type=_parse_ints, default=DEFAULT_MS,
                    help="comma-separated payload bytes to sweep")
    ap.add_argument("--max-residual", type=float, default=0.05,
                    help="fail if any tier's relative fit residual "
                         "exceeds this (decision-boundary guard)")
    ap.add_argument("--dist", type=int, default=0, metavar="NPROCS",
                    help="fit the 'dci' tier from schedules timed "
                         "across NPROCS worker processes (the "
                         "multi-process harness) instead of the "
                         "local sweep")
    ap.add_argument("--dist-intra", type=int, default=1,
                    help="ranks per worker process for --dist")
    args = ap.parse_args(argv)

    from repro.launch import mesh as mesh_lib

    truth = mesh_lib.DEFAULT_PROFILE
    if args.dist:
        profile = calibrate_dist(nprocs=args.dist,
                                 ranks_per_proc=args.dist_intra)
        residuals = dict(profile.residuals)
        print(f"calibrated profile (clock=dist, "
              f"mesh={profile.mesh_fingerprint}, "
              f"fingerprint={profile.fingerprint()}):")
        for tier, cm in profile.tiers:
            print(f"  {tier}: alpha={cm.alpha:.3e} beta={cm.beta:.3e} "
                  f"gamma={cm.gamma:.3e} "
                  f"residual={residuals.get(tier, 0.0):.3e}")
        path = save_profile(profile, args.out)
        print(f"wrote {path}")
        # no residual gate: real IPC timings carry serialization
        # overheads the linear model absorbs as noise by design
        return 0
    profile = calibrate(simulate=args.simulate, truth=truth,
                        ps=args.ps, ms=args.ms,
                        mesh_fingerprint=args.fingerprint)
    residuals = dict(profile.residuals)
    print(f"calibrated profile (clock="
          f"{'simulated' if args.simulate else 'walltime'}, "
          f"mesh={profile.mesh_fingerprint}, "
          f"fingerprint={profile.fingerprint()}):")
    for tier, cm in profile.tiers:
        line = (f"  {tier}: alpha={cm.alpha:.3e} beta={cm.beta:.3e} "
                f"gamma={cm.gamma:.3e} "
                f"residual={residuals.get(tier, 0.0):.3e}")
        if args.simulate:
            t = truth.model(tier)
            line += (f"  (truth alpha={t.alpha:.3e} beta={t.beta:.3e} "
                     f"gamma={t.gamma:.3e})")
        print(line)
    path = save_profile(profile, args.out)
    print(f"wrote {path}")
    worst = max(residuals.values(), default=0.0)
    if worst > args.max_residual:
        print(f"FAIL: fit residual {worst:.3e} exceeds "
              f"--max-residual {args.max_residual}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
