"""Monoid abstraction for scan/reduce collectives.

The paper's algorithms require only associativity of ``op`` (NOT
commutativity).  The SPMD adaptation additionally requires an identity
element so that edge ranks (which in the MPI formulation conditionally
skip sends/receives) can be expressed uniformly: a rank with no source
"receives" the identity, making the combine a no-op.

A monoid here operates on *pytrees* so that structured states (e.g. the
(decay, state) pairs of an SSM chunk scan, or (A, b) affine maps) can be
scanned with the same collectives as plain vectors.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Monoid:
    """An associative binary operator with identity, over pytrees.

    Attributes:
      name: registry key.
      op: ``op(lo, hi) -> combined`` where ``lo`` covers *lower* ranks.
        Must be associative.  Order is preserved by all collectives, so
        non-commutative monoids are supported.
      identity_like: maps a pytree of arrays to the identity element of
        the same structure/shape/dtype.
      commutative: whether ``op(a, b) == op(b, a)``.  Operative, not
        informational: the executors elide the redundant combine order
        in butterfly ``exchange`` (2→1 ⊕) and fused ``scan_reduce``
        (3→2 ⊕) rounds for commutative monoids, and the planner /
        ``Schedule.op_count`` price the elided counts (also enables
        extra test oracles).
      op_cost: relative cost of one ⊕ application per payload byte
        (1.0 = elementwise add).  Feeds the γ term of the scan planner's
        cost model (scan_api.CostModel) — "expensive" operators push the
        planner toward ⊕-frugal algorithms like 123-doubling.
      segmentable: whether ⊕ combines aligned element positions
        independently, so the pipelined ring may split flattened
        payload leaves into contiguous blocks (core/schedule.py
        ``segment``).  True for elementwise ops (including affine,
        which is elementwise across its aligned (a, b) leaves); False
        when a leaf is one indivisible operand (matmul's (n, n)
        matrices contract across elements).
      leaf_op: the per-leaf elementwise ⊕, when one exists — the hook
        the Pallas executor lowers through the on-chip block-combine
        kernel.  None for structured monoids (affine's two leaves
        combine differently; matmul contracts) — those fall back to
        ``op``.
    """

    name: str
    op: Callable[[Any, Any], Any]
    identity_like: Callable[[Any], Any]
    commutative: bool = False
    op_cost: float = 1.0
    segmentable: bool = True
    leaf_op: Callable | None = None

    def fold(self, items):
        """Left fold; returns identity_like(items[0]) for empty input."""
        items = list(items)
        if not items:
            raise ValueError("fold of empty sequence needs a shape witness")
        acc = items[0]
        for x in items[1:]:
            acc = self.op(acc, x)
        return acc


def _zeros_like(x):
    return jax.tree.map(jnp.zeros_like, x)


def _ones_like(x):
    return jax.tree.map(jnp.ones_like, x)


def _full_like(value):
    def f(x):
        return jax.tree.map(lambda t: jnp.full_like(t, value), x)

    return f


def _min_identity(x):
    def one(t):
        if jnp.issubdtype(t.dtype, jnp.floating):
            return jnp.full_like(t, jnp.inf)
        return jnp.full_like(t, jnp.iinfo(t.dtype).max)

    return jax.tree.map(one, x)


def _max_identity(x):
    def one(t):
        if jnp.issubdtype(t.dtype, jnp.floating):
            return jnp.full_like(t, -jnp.inf)
        return jnp.full_like(t, jnp.iinfo(t.dtype).min)

    return jax.tree.map(one, x)


ADD = Monoid(
    name="add",
    op=lambda lo, hi: jax.tree.map(jnp.add, lo, hi),
    identity_like=_zeros_like,
    commutative=True,
    leaf_op=jnp.add,
)

MUL = Monoid(
    name="mul",
    op=lambda lo, hi: jax.tree.map(jnp.multiply, lo, hi),
    identity_like=_ones_like,
    commutative=True,
    leaf_op=jnp.multiply,
)

MAX = Monoid(
    name="max",
    op=lambda lo, hi: jax.tree.map(jnp.maximum, lo, hi),
    identity_like=_max_identity,
    commutative=True,
    leaf_op=jnp.maximum,
)

MIN = Monoid(
    name="min",
    op=lambda lo, hi: jax.tree.map(jnp.minimum, lo, hi),
    identity_like=_min_identity,
    commutative=True,
    leaf_op=jnp.minimum,
)

XOR = Monoid(
    name="xor",
    op=lambda lo, hi: jax.tree.map(jnp.bitwise_xor, lo, hi),
    identity_like=_zeros_like,
    commutative=True,
    leaf_op=jnp.bitwise_xor,
)


def affine_combine(lo, hi):
    """Composition of elementwise affine maps x -> a*x + b.

    ``lo`` is applied first (covers lower ranks), then ``hi``:
      (hi ∘ lo)(x) = a_hi * (a_lo * x + b_lo) + b_hi
                   = (a_hi*a_lo) * x + (a_hi*b_lo + b_hi)

    This is the state-composition monoid of diagonal SSM / linear-RNN
    chunk scans (RWKV, Mamba-style): associative, NON-commutative, and
    "expensive" relative to plain add — exactly the operator class the
    paper's q-1 ⊕-application bound targets.

    THE one definition: the Pallas scan engine
    (``kernels.scan_engine``), the SSM chunk kernel and the XLA-path
    model scans (``models.mamba``/``models.rwkv``) all import this —
    no private duplicates (a regression test enforces it).
    """
    a_lo, b_lo = lo
    a_hi, b_hi = hi
    return (a_hi * a_lo, a_hi * b_lo + b_hi)


_affine_op = affine_combine  # backwards-compatible private alias


def _affine_identity(x):
    a, b = x
    return (jnp.ones_like(a), jnp.zeros_like(b))


AFFINE = Monoid(
    name="affine",
    op=_affine_op,
    identity_like=_affine_identity,
    commutative=False,
    op_cost=2.0,  # 3 mul + 1 add over two leaves vs one add
)


def _matmul_op(lo, hi):
    """Matrix-product monoid (batched over leading dims); non-commutative."""
    return jax.tree.map(lambda l, h: jnp.matmul(h, l), lo, hi)


def _matmul_identity(x):
    def one(t):
        n = t.shape[-1]
        eye = jnp.eye(n, dtype=t.dtype)
        return jnp.broadcast_to(eye, t.shape)

    return jax.tree.map(one, x)


MATMUL = Monoid(
    name="matmul",
    op=_matmul_op,
    identity_like=_matmul_identity,
    commutative=False,
    op_cost=8.0,  # O(n) MACs per output element, nominal n=8 state
    # a leaf is one (…, n, n) operand; splitting it breaks the
    # contraction, so the planner never segments matmul payloads
    segmentable=False,
)


REGISTRY: dict[str, Monoid] = {
    m.name: m for m in (ADD, MUL, MAX, MIN, XOR, AFFINE, MATMUL)
}


def get(name_or_monoid) -> Monoid:
    if isinstance(name_or_monoid, Monoid):
        return name_or_monoid
    try:
        return REGISTRY[name_or_monoid]
    except KeyError:
        raise KeyError(
            f"unknown monoid {name_or_monoid!r}; known: {sorted(REGISTRY)}"
        ) from None


# Numpy twins for the message-schedule oracle (no jax involvement).
NUMPY_OPS: dict[str, Callable] = {
    "add": lambda lo, hi: jax.tree.map(np.add, lo, hi),
    "mul": lambda lo, hi: jax.tree.map(np.multiply, lo, hi),
    "max": lambda lo, hi: jax.tree.map(np.maximum, lo, hi),
    "min": lambda lo, hi: jax.tree.map(np.minimum, lo, hi),
    "xor": lambda lo, hi: jax.tree.map(np.bitwise_xor, lo, hi),
    "affine": lambda lo, hi: (hi[0] * lo[0], hi[0] * lo[1] + hi[1]),
    "matmul": lambda lo, hi: jax.tree.map(lambda l, h: h @ l, lo, hi),
}


def _np_extreme_identity(is_max: bool):
    def f(x):
        def one(t):
            t = np.asarray(t)
            if np.issubdtype(t.dtype, np.floating):
                return np.full_like(t, -np.inf if is_max else np.inf)
            lim = np.iinfo(t.dtype)
            return np.full_like(t, lim.min if is_max else lim.max)

        return jax.tree.map(one, x)

    return f


def _np_matmul_identity(x):
    def one(t):
        t = np.asarray(t)
        eye = np.eye(t.shape[-1], dtype=t.dtype)
        return np.broadcast_to(eye, t.shape).copy()

    return jax.tree.map(one, x)


# Numpy identity twins (schedule.SimulatorExecutor — no jax arrays).
NUMPY_IDENTITY: dict[str, Callable] = {
    "add": lambda x: jax.tree.map(lambda t: np.zeros_like(t), x),
    "mul": lambda x: jax.tree.map(lambda t: np.ones_like(t), x),
    "max": _np_extreme_identity(True),
    "min": _np_extreme_identity(False),
    "xor": lambda x: jax.tree.map(lambda t: np.zeros_like(t), x),
    "affine": lambda x: (np.ones_like(np.asarray(x[0])),
                         np.zeros_like(np.asarray(x[1]))),
    "matmul": _np_matmul_identity,
}
