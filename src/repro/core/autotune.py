"""Online self-tuning: streaming cost-profile refits with drift gates.

The planner's α/β/γ constants ARE the performance in the paper's
small-m regime — a stale profile silently picks the wrong algorithm
across the whole mid-m winner map, and the crossover points move
whenever the fabric does.  :mod:`repro.core.tune` fits those constants
offline; this module closes the loop **online**:

    execute ──▶ collect_stats ──▶ reservoir ──▶ NNLS refit
                                                     │
            re-warmup ◀── cache invalidate ◀── drift gate ◀─┘
                                 │
                              install

Every real execution (a :class:`~repro.serve.service.ScanService`
batch, a ``train.py`` probe, a :class:`~repro.dist.launcher.WorkerPool`
run) feeds one :class:`~repro.core.tune.Sample` — the IR-derived
features priced exactly like the planner prices them, plus measured
seconds — into a bounded per-tier reservoir.  Periodically the
controller re-runs the existing NNLS fit (:func:`tune.fit_tier`) and
installs a recalibrated :class:`~repro.core.scan_api.CostProfile`
**only** when the fitted constants drift past a configurable gate
relative to the installed profile AND the fit residual is below a
quality gate (a noisy fit never replaces working constants; stable
constants never thrash the cache).  Installation is atomic from the
planner's point of view: the plan cache is keyed by resolved pricing
constants, so the new profile changes every key, and the controller
flushes the stale generation via ``plan_cache_resize()`` (whose return
value reports how many plans the drift invalidated — distinct from
LRU pressure).  Subscribers (the serve layer) are notified so a warmed
service can re-``warmup()`` and keep its zero-post-warmup-compile
contract across the swap.

On the dist tier, per-rank execution timings from
:class:`~repro.dist.worker.RankExecutor` runs feed a
:class:`StragglerDetector`: ranks persistently slower than the median
inflate the "dci" α (every round of a synchronous collective completes
when its slowest participant does), and
:func:`replan_hierarchical` re-searches ``plan_hierarchical``'s
p_inter × p_intra factoring under the inflated pricing — stragglers
push the plan toward fewer inter-tier rounds.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core import monoid as monoid_lib
from repro.core import scan_api
from repro.core import schedule as schedule_lib
from repro.core import tune
from repro.core.scan_api import CostModel, CostProfile


# ---------------------------------------------------------------------------
# Drift gate + refit outcome
# ---------------------------------------------------------------------------


def relative_drift(old: CostModel, new: CostModel) -> float:
    """Symmetric relative change of the pricing constants, in [0, 1]:
    ``max over {α, β, γ} of |new − old| / max(|new|, |old|)`` (0/0
    counts as no drift).  A 4× shift scores 0.75; identical constants
    score 0.  Symmetric so growth and decay gate alike."""
    drift = 0.0
    for a, b in ((old.alpha, new.alpha), (old.beta, new.beta),
                 (old.gamma, new.gamma)):
        denom = max(abs(a), abs(b))
        if denom > 0.0:
            drift = max(drift, abs(a - b) / denom)
    return drift


@dataclasses.dataclass(frozen=True)
class DriftGate:
    """When does a refit replace the installed profile?

    drift: minimum :func:`relative_drift` of any refitted tier vs the
      installed profile (0.5 ≈ a 2× constant change) — below it the
      fit is confirmation, not news, and installing would only churn
      the plan cache.
    max_residual: maximum relative-RMS fit residual a tier may carry
      and still be trusted (a mixed-regime window mid-drift fits two
      fabrics at once and shows up here — the gate holds the old
      profile until the reservoir turns over to the new regime).
    min_samples: per-tier sample floor before fitting at all (3
      unknowns want feature spread, not just rows).
    """

    drift: float = 0.5
    max_residual: float = 0.25
    min_samples: int = 12


@dataclasses.dataclass(frozen=True)
class RefitResult:
    """One ``maybe_refit`` outcome (``AutoTuner.history`` keeps them).

    ``reason`` is machine-readable: "installed", "stable" (fit fine,
    drift under the gate), "noisy" (residual over the gate),
    "no_samples" (no tier met the floor), or "not_due" (refit cadence
    not reached).  ``plans_dropped`` is the stale-plan count the
    install flushed (0 unless installed)."""

    installed: bool
    reason: str
    profile: CostProfile | None = None
    drift: tuple = ()  # ((tier, relative_drift), ...)
    residuals: tuple = ()  # ((tier, fit_residual), ...)
    plans_dropped: int = 0


# ---------------------------------------------------------------------------
# Straggler detection (dist tier)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StragglerReport:
    """Per-rank timing summary: who is slow, and by how much.

    ``inflation`` is the factor a synchronous collective's round time
    grows by because of the slowest rank (max smoothed per-rank
    seconds / median), 1.0 when nobody straggles."""

    rank_seconds: tuple
    median: float
    slow_ranks: tuple
    inflation: float

    @property
    def straggling(self) -> bool:
        return bool(self.slow_ranks)


class StragglerDetector:
    """EWMA per-rank execution times → :class:`StragglerReport`.

    A rank is a straggler when its smoothed time exceeds
    ``threshold ×`` the median of all smoothed times.  The EWMA keeps
    one transient GC pause from triggering a replan while persistent
    slowness (an overheating host, a degraded link) accumulates."""

    def __init__(self, *, threshold: float = 1.5, smoothing: float = 0.5):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        if not 0.0 < smoothing <= 1.0:
            raise ValueError(f"smoothing must be in (0, 1], "
                             f"got {smoothing}")
        self.threshold = float(threshold)
        self.smoothing = float(smoothing)
        self._ewma: dict[int, float] = {}

    def observe(self, rank_seconds) -> StragglerReport:
        """Fold one execution's per-rank seconds (global-rank order)
        into the smoothed state and report."""
        for rank, sec in enumerate(rank_seconds):
            prev = self._ewma.get(rank)
            self._ewma[rank] = float(sec) if prev is None else \
                (1 - self.smoothing) * prev + self.smoothing * float(sec)
        return self.report()

    def report(self) -> StragglerReport:
        if not self._ewma:
            return StragglerReport(rank_seconds=(), median=0.0,
                                   slow_ranks=(), inflation=1.0)
        ranks = sorted(self._ewma)
        secs = tuple(self._ewma[r] for r in ranks)
        med = float(np.median(secs))
        if med <= 0.0:
            return StragglerReport(rank_seconds=secs, median=med,
                                   slow_ranks=(), inflation=1.0)
        slow = tuple(r for r, s in zip(ranks, secs)
                     if s > self.threshold * med)
        inflation = max(1.0, max(secs) / med) if slow else 1.0
        return StragglerReport(rank_seconds=secs, median=med,
                               slow_ranks=slow, inflation=inflation)

    def reset(self):
        self._ewma.clear()


def straggler_adjusted_profile(profile: CostProfile,
                               report: StragglerReport, *,
                               tier: str = "dci") -> CostProfile:
    """``profile`` with ``tier``'s α inflated by ``report.inflation``.

    A synchronous round across the slow tier completes when its
    slowest participant does, so a persistent straggler multiplies the
    effective per-round latency — exactly the α term.  β/γ are left
    alone: the link and the healthy ranks' compute did not change."""
    if report.inflation <= 1.0:
        return profile
    cm = profile.model(tier)
    inflated = dataclasses.replace(cm, alpha=cm.alpha * report.inflation)
    tiers = tuple((name, inflated if name == tier else m)
                  for name, m in profile.tiers)
    return dataclasses.replace(profile, tiers=tiers)


def _factorings(p: int) -> list[tuple[int, int]]:
    return [(d, p // d) for d in range(1, p + 1) if p % d == 0]


def replan_hierarchical(spec, p: int, *, nbytes: int,
                        cost_model=None,
                        report: StragglerReport | None = None,
                        inter_axis: str = "proc",
                        intra_axis: str = "local"):
    """Search every p_inter × p_intra factoring of ``p`` under
    (optionally straggler-inflated) pricing; returns the cheapest
    :class:`~repro.core.scan_api.ScanPlan`.

    With a straggling :class:`StragglerReport` the "dci" α is
    inflated first (:func:`straggler_adjusted_profile`), which pushes
    the winning factoring toward fewer inter-tier ranks — the
    controller's answer to "re-plan around the slow hosts".  Single-
    level factorings (p_inter == 1 or p_intra == 1) degenerate to the
    corresponding flat plan and compete on equal terms."""
    if p < 1:
        raise ValueError(f"need p >= 1, got {p}")
    cm = cost_model
    if cm is None:
        from repro.launch import mesh as mesh_lib  # lazy: no cycle

        cm = mesh_lib.current_profile()
    if report is not None and isinstance(cm, CostProfile):
        cm = straggler_adjusted_profile(cm, report)
    best = None
    for p_inter, p_intra in _factorings(p):
        if 1 in (p_inter, p_intra):
            axis = intra_axis if p_inter == 1 else inter_axis
            if isinstance(cm, CostProfile) and p_intra == 1 \
                    and inter_axis not in dict(cm.axis_tiers):
                prof = dataclasses.replace(
                    cm, axis_tiers=cm.axis_tiers + ((inter_axis,
                                                     "dci"),))
            else:
                prof = cm
            pl = scan_api.plan(spec.over(axis), p, nbytes=nbytes,
                               cost_model=prof)
        else:
            pl = scan_api.plan_hierarchical(
                spec, p_inter=p_inter, p_intra=p_intra, nbytes=nbytes,
                cost_model=cm, inter_axis=inter_axis,
                intra_axis=intra_axis)
        if best is None or pl.cost < best.cost:
            best = pl
    return best


# ---------------------------------------------------------------------------
# The streaming controller
# ---------------------------------------------------------------------------


class AutoTuner:
    """Streaming calibration controller: reservoirs → refit → gate →
    install → invalidate (see the module docstring's loop).

    Args:
      base: the profile the controller starts from and measures drift
        against (default: the currently installed launch-layer
        profile).  Its axis routing / default tier carry through every
        refit — the controller recalibrates constants, not topology.
      gate: the :class:`DriftGate` thresholds.
      capacity: per-tier reservoir bound (a sliding window — newest
        samples evict oldest, so the fit follows the fabric instead of
        averaging over its whole history).
      refit_every: executions between ``maybe_refit`` attempts (the
        NNLS is cheap, but fitting after every batch is pointless
        churn).
      install: when False the controller computes refits and gates but
        never touches the global profile or cache — observe-only mode
        for benchmarks comparing against an oracle.
      straggler_threshold: slow-rank multiple for the dist-tier
        :class:`StragglerDetector`.
    """

    def __init__(self, base: CostProfile | None = None, *,
                 gate: DriftGate | None = None, capacity: int = 128,
                 refit_every: int = 16, install: bool = True,
                 straggler_threshold: float = 1.5,
                 mesh_fingerprint: str = "online"):
        if capacity < 1:
            raise ValueError(f"need capacity >= 1, got {capacity}")
        if refit_every < 1:
            raise ValueError(f"need refit_every >= 1, "
                             f"got {refit_every}")
        if base is None:
            from repro.launch import mesh as mesh_lib  # lazy: no cycle

            base = mesh_lib.current_profile()
        self.profile = base
        self.gate = gate or DriftGate()
        self.capacity = int(capacity)
        self.refit_every = int(refit_every)
        self.install_enabled = bool(install)
        self.mesh_fingerprint = mesh_fingerprint
        self.stragglers = StragglerDetector(
            threshold=straggler_threshold)
        self._reservoirs: dict[str, deque] = {}
        self._since_refit = 0
        self._subscribers: list = []
        self.executions = 0
        self.refits = 0
        self.installs = 0
        self.plans_dropped = 0
        self.history: list[RefitResult] = []

    # -- sample intake -------------------------------------------------

    def reservoir(self, tier: str) -> deque:
        res = self._reservoirs.get(tier)
        if res is None:
            res = self._reservoirs[tier] = deque(maxlen=self.capacity)
        return res

    def reservoir_sizes(self) -> dict:
        return {t: len(r) for t, r in self._reservoirs.items()}

    def add_sample(self, sample: "tune.Sample"):
        """Feed one pre-featurized sample row (the dist calibration
        sweep and tests use this directly)."""
        self.reservoir(sample.tier).append(sample)
        self.executions += 1
        self._since_refit += 1

    def record(self, sched_or_scheds, nbytes, seconds: float, *,
               tier: str = "ici", monoid="add",
               stats: "schedule_lib.CollectiveStats | None" = None,
               algorithm: str = "online", kind: str = "exclusive"):
        """Turn one measured execution into a reservoir sample.

        ``sched_or_scheds`` is the executed schedule (or a list of
        schedules a serial batch ran back-to-back, with matching
        ``nbytes`` per schedule) — features are the planner's exact
        pricing regressors (:func:`tune.schedule_features`) summed
        over the executed schedules, against the one measured
        ``seconds``.  When ``stats`` (a ``collect_stats()`` recording
        of this execution) is passed, its measured round/⊕ counts are
        cross-checked against the IR-derived hop count; a mismatched
        recording is rejected rather than poisoning the fit."""
        scheds = sched_or_scheds if isinstance(sched_or_scheds,
                                               (list, tuple)) \
            else [sched_or_scheds]
        sizes = nbytes if isinstance(nbytes, (list, tuple)) \
            else [nbytes] * len(scheds)
        if len(sizes) != len(scheds):
            raise ValueError(f"{len(scheds)} schedules but "
                             f"{len(sizes)} payload sizes")
        mono = monoid_lib.get(monoid)
        op_cost = getattr(mono, "op_cost", 1.0)
        hops = wire = op_bytes = 0.0
        rounds = ops = 0
        for sched, m in zip(scheds, sizes):
            h, w, ob = tune.schedule_features(
                sched, int(m), op_cost, commutative=mono.commutative)
            hops += h
            wire += w
            op_bytes += ob
            rounds += sched.rounds
            ops += sched.op_count(mono.commutative)
        if stats is not None and (stats.rounds != rounds
                                  or stats.op_applications != ops):
            return None  # a foreign recording: do not poison the fit
        sample = tune.Sample(
            tier=tier, kind=kind, algorithm=algorithm,
            p=scheds[0].p, nbytes=int(sum(sizes)),
            segments=max(s.n_segments for s in scheds),
            hops=hops, serial_bytes=wire, op_bytes=op_bytes,
            seconds=float(seconds), clock="online")
        self.add_sample(sample)
        return sample

    def observe_dist(self, result, sched, nbytes, *, monoid="add",
                     tier: str = "dci") -> StragglerReport:
        """Fold one :class:`~repro.dist.launcher.DistResult` into the
        controller: the run's median walltime becomes a dci-tier
        sample, and its per-rank timings (when the pool reported
        them) feed the straggler detector."""
        self.record(sched, nbytes,
                    float(np.median(result.seconds)), tier=tier,
                    monoid=monoid, algorithm="dist", kind="exclusive")
        rank_seconds = getattr(result, "rank_seconds", None)
        if rank_seconds:
            per_rank = np.median(np.asarray(rank_seconds,
                                            dtype=np.float64), axis=0)
            return self.stragglers.observe(per_rank.tolist())
        return self.stragglers.report()

    def probe(self, spec, p, nbytes: int, *, executor=None,
              tier: str | None = None):
        """Plan-and-time one standalone execution at real-work cadence
        (``train.py``'s scans run inside a jitted step, so the online
        loop times the planned schedule out-of-band instead).  Returns
        the executed plan."""
        pl = scan_api.plan(spec, p, nbytes=nbytes,
                           cost_model=self.profile)
        mono = monoid_lib.get(spec.monoid)
        if executor is None:
            executor = schedule_lib.SimulatorExecutor()
        rng = np.random.default_rng(self.executions)
        x = rng.integers(0, 1 << 30,
                         size=(pl.p, max(1, nbytes // 8))) \
            .astype(np.int64)
        sched = pl.schedule()
        t0 = time.perf_counter()
        executor.execute(sched, x, mono)
        seconds = time.perf_counter() - t0
        self.record(sched, nbytes, seconds,
                    tier=tier or self.profile.tier_for_axis(
                        spec.axis_name),
                    monoid=spec.monoid, algorithm=pl.algorithm,
                    kind=spec.kind)
        return pl

    # -- refit + gate + install ----------------------------------------

    def subscribe(self, fn):
        """Register ``fn(profile)`` to run after every install (the
        serve layer re-warms its plan space here)."""
        self._subscribers.append(fn)
        return fn

    def maybe_refit(self, *, force: bool = False) -> RefitResult:
        """Refit when due; install only past the drift gate.

        The controller's one decision point: fit every tier with
        enough samples, measure drift vs the installed profile, and
        either install (notifying subscribers, flushing stale plans)
        or record why not.  ``force`` skips the cadence check only —
        the drift/residual gates always apply."""
        if not force and self._since_refit < self.refit_every:
            return self._log(RefitResult(installed=False,
                                         reason="not_due"))
        self._since_refit = 0
        fits: dict[str, tuple[CostModel, float]] = {}
        for tier, res in self._reservoirs.items():
            if len(res) >= self.gate.min_samples:
                fits[tier] = tune.fit_tier(list(res))
        if not fits:
            return self._log(RefitResult(installed=False,
                                         reason="no_samples"))
        self.refits += 1
        known = dict(self.profile.tiers)
        drift = tuple(sorted(
            (tier, relative_drift(known[tier], cm)
             if tier in known else 1.0)  # new tier: always news
            for tier, (cm, _) in fits.items()))
        residuals = tuple(sorted((tier, resid)
                                 for tier, (_, resid) in fits.items()))
        worst_resid = max(r for _, r in residuals)
        if worst_resid > self.gate.max_residual:
            return self._log(RefitResult(
                installed=False, reason="noisy", drift=drift,
                residuals=residuals))
        if max(d for _, d in drift) < self.gate.drift:
            return self._log(RefitResult(
                installed=False, reason="stable", drift=drift,
                residuals=residuals))
        profile = self._build_profile(fits)
        dropped = self.install(profile)
        return self._log(RefitResult(
            installed=True, reason="installed", profile=profile,
            drift=drift, residuals=residuals, plans_dropped=dropped))

    def _build_profile(self, fits: dict) -> CostProfile:
        tiers = tuple(
            (name, fits[name][0] if name in fits else cm)
            for name, cm in self.profile.tiers)
        known = {name for name, _ in tiers}
        tiers += tuple(sorted(
            (name, cm) for name, (cm, _) in fits.items()
            if name not in known))
        residuals = dict(self.profile.residuals)
        residuals.update({t: r for t, (_, r) in fits.items()})
        return CostProfile(
            tiers=tiers, source="calibrated",
            mesh_fingerprint=self.mesh_fingerprint,
            axis_tiers=self.profile.axis_tiers,
            default_tier=self.profile.default_tier,
            residuals=tuple(sorted(residuals.items())))

    def install(self, profile: CostProfile) -> int:
        """Make ``profile`` the pricing everywhere at once: the global
        launch-layer install changes every plan-cache key (stale plans
        can never be returned again), the ``plan_cache_resize`` flush
        drops their entries, and subscribers re-warm.  Returns the
        dropped-plan count."""
        self.profile = profile
        dropped = 0
        if self.install_enabled:
            from repro.launch import mesh as mesh_lib  # lazy: no cycle

            mesh_lib.install_profile(profile)
            dropped = scan_api.plan_cache_resize(
                scan_api.plan_cache_info()["maxsize"]
                or scan_api.PLAN_CACHE_MAXSIZE)
        self.installs += 1
        self.plans_dropped += dropped
        for fn in self._subscribers:
            fn(profile)
        return dropped

    def _log(self, result: RefitResult) -> RefitResult:
        self.history.append(result)
        return result
