"""Shared benchmark-metadata envelope for every ``BENCH_*.json``.

The BENCH files are the repo's perf trajectory — but a row is only
evidence if you know *which code on which machine at what time*
produced it.  Every ``benchmarks/*_bench.py`` stamps its JSON with one
common ``"meta"`` header from :func:`bench_metadata`:

    {"meta": {"meta_schema_version": 1, "git_sha": "...",
              "timestamp_utc": "2026-...Z", "platform": "cpu"},
     "schema_version": N, "benchmark": "...", ..., "rows": [...]}

``meta_schema_version`` versions the header itself, independently of
each benchmark's own row schema; the git sha + UTC timestamp make
cross-PR comparisons reconstructable, and the worker platform keys
which fabric the numbers describe (the same reason calibrated
profiles fingerprint their mesh).
"""

from __future__ import annotations

import datetime
import os
import subprocess

BENCH_META_SCHEMA_VERSION = 1


def git_sha(cwd: str | None = None) -> str:
    """The current commit sha, or "unknown" outside a git checkout
    (benchmarks must run from exported tarballs too)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def worker_platform() -> str:
    """The jax backend the measurements ran on, without forcing a jax
    init when the environment already pins one (the dist launcher's
    convention: first entry of JAX_PLATFORMS wins)."""
    env = os.environ.get("JAX_PLATFORMS", "")
    if env.strip():
        return env.split(",")[0].strip()
    try:
        import jax

        return jax.default_backend()
    except Exception:  # noqa: BLE001 - metadata must never fail a bench
        return "unknown"


def bench_metadata() -> dict:
    """The common ``"meta"`` header (see module docstring)."""
    return {
        "meta_schema_version": BENCH_META_SCHEMA_VERSION,
        "git_sha": git_sha(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"),
        "platform": worker_platform(),
    }
