"""Gemma-2-9B [arXiv:2408.00118].

Local(4096-window)/global alternation, attention-score softcap 50,
final-logit softcap 30, tied embeddings, head_dim 256.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    sliding_window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    sliding_window=32,
    dtype="float32",
)
