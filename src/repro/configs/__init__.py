"""Architecture registry: the 10 assigned configs (+ reduced variants).

``get(name)`` returns the full published config; ``get_smoke(name)``
returns a reduced same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHITECTURES = (
    "jamba_1_5_large_398b",
    "qwen2_moe_a2_7b",
    "granite_moe_3b_a800m",
    "rwkv6_1_6b",
    "llama3_8b",
    "gemma2_9b",
    "granite_3_2b",
    "starcoder2_3b",
    "pixtral_12b",
    "hubert_xlarge",
)

# CLI aliases (--arch accepts either form)
ALIASES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama3-8b": "llama3_8b",
    "gemma2-9b": "gemma2_9b",
    "granite-3-2b": "granite_3_2b",
    "starcoder2-3b": "starcoder2_3b",
    "pixtral-12b": "pixtral_12b",
    "hubert-xlarge": "hubert_xlarge",
}


def canonical(name: str) -> str:
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHITECTURES}")
    return name


def get(name: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg = mod.CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke(name: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg = mod.SMOKE
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def all_configs():
    return {n: get(n) for n in ARCHITECTURES}
