"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts (top-4) + 4 shared experts, every layer MoE.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,  # shared-expert path (4 x 1408)
    vocab=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_expert_ff=1408,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    d_expert_ff=32,
    vocab=256,
    n_experts=8,
    top_k=2,
    n_shared_experts=2,
    dtype="float32",
)
