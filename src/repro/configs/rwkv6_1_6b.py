"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892].  Attention-free."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # d_model / 64 wkv heads
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=128,  # 2 wkv heads
    n_heads=2,
    n_kv_heads=2,
    d_ff=256,
    vocab=256,
    dtype="float32",
)
