"""Jamba-1.5-Large (398B total / ~94B active) [arXiv:2403.19887].

Hybrid: 1 attention layer per 8 (1:7 attn:mamba), MoE (16 experts,
top-2) on every second layer.  Pattern unit = 8 layers, 9 repeats.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    d_expert_ff=24576,
    attn_period=8,
    d_state=16,
    d_conv=4,
    expand=2,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    d_expert_ff=128,
    vocab=256,
    n_experts=4,
    top_k=2,
    dtype="float32",
)
