"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409].

Mistral-Nemo backbone (head_dim 128); the pixtral ViT frontend is a
STUB per the assignment: input_specs provides precomputed patch
embeddings occupying the first ``n_prefix`` backbone positions.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1_000_000_000.0,
    frontend="vision",
    n_prefix=64,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    n_prefix=4,
    dtype="float32",
)
