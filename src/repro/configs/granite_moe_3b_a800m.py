"""Granite-3.0-3B-A800M MoE [hf:ibm-granite].

40 routed experts, top-8, no shared experts.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    d_expert_ff=512,
    head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=48,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    d_expert_ff=64,
    vocab=256,
    n_experts=8,
    top_k=4,
    head_dim=12,
    dtype="float32",
)
