"""HuBERT-XLarge [arXiv:2106.07447].

Encoder-only (bidirectional, no decode step); the CNN waveform
frontend is a STUB per the assignment: input_specs provides precomputed
frame embeddings; the head predicts 504 cluster targets.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    encoder_only=True,
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=64,
    dtype="float32",
)
