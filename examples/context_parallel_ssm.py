"""Context-parallel SSM prefill: the paper's headline scenario.

A 32k-token sequence is sharded over 8 devices; each device scans its
chunk locally and the cross-device carry-in states are computed with an
exclusive prefix scan under the (expensive, non-commutative) AFFINE
state-composition operator.  123-doubling does this in
q = ceil(log2(p-1) + log2 4/3) rounds with q-1 compositions.

    python examples/context_parallel_ssm.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402
import time  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

import repro.core.collectives as collectives  # noqa: E402
from repro.core.scan_api import ScanSpec  # noqa: E402
from repro.models.context_parallel import cp_ssm_scan  # noqa: E402
from repro.models.mamba import ssm_scan_chunked  # noqa: E402


def main():
    p = 8
    mesh = Mesh(np.array(jax.devices()).reshape(p), ("data",))
    B, S, D = 1, 32768, 512
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.9, 1.0, (B, S, D)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)

    ref, _ = ssm_scan_chunked(a, b, jnp.zeros((B, D)))

    for alg in ("auto", "123", "1doubling", "two_op"):
        spec = ScanSpec(kind="exclusive", monoid="affine", algorithm=alg)
        with collectives.collect_stats() as stats:
            with jax.set_mesh(mesh):
                f = jax.jit(lambda x, y, spec=spec: cp_ssm_scan(
                    x, y, mesh, spec=spec))
                out = f(a, b)
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                jax.block_until_ready(f(a, b))
                dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"{alg:>10s}: {stats.rounds} carry rounds, "
              f"{stats.op_applications} ⊕ compositions/device, "
              f"max err {err:.1e}, wall {dt*1e3:.1f} ms")

    print("\n(sequence length 32k sharded 8 ways; carry-in state per "
          "device reconstructed exactly — errs are f32 noise)")


if __name__ == "__main__":
    main()
