"""MoE dispatch with exscan-driven global capacity accounting.

Shows the paper's collective doing real work inside a model: a qwen-MoE
forward on a 2x4 (data x model) mesh, comparing all exscan algorithms —
the outputs are identical (same deterministic drop policy), the
communication schedules differ per Theorem 1.

    python examples/moe_dispatch_exscan.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.scan_api import ScanSpec  # noqa: E402
from repro.models.model import Model  # noqa: E402


def main():
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 256, (8, 64)), jnp.int32)

    outs = {}
    for alg in ("auto", "123", "1doubling", "two_op", "native"):
        cfg = configs.get_smoke(
            "qwen2_moe_a2_7b",
            scan=ScanSpec(kind="exclusive", algorithm=alg))
        model = Model(cfg, mesh)
        params = model.init_params(jax.random.PRNGKey(0))
        with jax.set_mesh(mesh):
            logits, aux = jax.jit(model.forward)(params, tokens)
        outs[alg] = np.asarray(logits)
        print(f"{alg:>10s}: logits[0,0,:3]={outs[alg][0,0,:3]} "
              f"load_balance={float(aux[0]):.4f} "
              f"dropped={float(aux[1]):.4%}")

    base = outs["auto"]
    for alg, o in outs.items():
        np.testing.assert_allclose(o, base, rtol=1e-4, atol=1e-4)
    print("\nall algorithms produce identical MoE outputs "
          "(drop policy is algorithm-independent) ✓")


if __name__ == "__main__":
    main()
