"""Quickstart: the paper's exclusive scan as a JAX collective.

Runs the three exclusive-scan algorithms from the paper (plus the
all-gather baseline) on a fake 8-device mesh, checks they agree, and
prints the round/⊕ counts from Theorem 1.

    python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax import shard_map  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

import repro.core.collectives as collectives  # noqa: E402
from repro.core import oracle  # noqa: E402


def main():
    p = 8
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("ranks",))
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(p, 4)).astype(np.int32)

    print(f"inputs V_r (p={p} ranks, m=4):\n{x}\n")
    expected = np.zeros_like(x)
    expected[1:] = np.cumsum(x[:-1], axis=0)

    for alg in collectives.ALGORITHMS:
        with collectives.collect_stats() as stats:
            fn = jax.jit(shard_map(
                lambda v: collectives.exscan(v, "ranks", "add", alg),
                mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks")))
            out = np.asarray(fn(x))
        assert np.array_equal(out, expected), alg
        print(f"{alg:>10s}: rounds={stats.rounds} "
              f"⊕/device={stats.op_applications} "
              f"(all-gathers={stats.allgathers})  ✓ correct")

    print("\nTheorem 1 at the paper's p=36 and at pod scale:")
    for p_ in (36, 256, 512):
        q = oracle.q_123(p_)
        print(f"  p={p_:4d}: 123-doubling {q} rounds / {q-1} ⊕ | "
              f"1-doubling {oracle.rounds_1doubling(p_)} rounds | "
              f"two-⊕ {oracle.rounds_two_op(p_)} rounds "
              f"/ ~{2*oracle.rounds_two_op(p_)-1} ⊕")


if __name__ == "__main__":
    main()
