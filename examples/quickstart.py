"""Quickstart: the paper's exclusive scan behind the planner API.

Builds a ScanSpec, lets the planner pick the algorithm for the payload
("auto" — the cost model weighs rounds vs bytes vs ⊕ cost), inspects
the resulting ScanPlan *before* tracing, then runs every registered
algorithm on a fake 8-device mesh and checks the predicted round/⊕
counts against trace-time measurements and Theorem 1.

    python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import repro  # noqa: E402,F401  (applies jax compat backfills)

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax import shard_map  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

import repro.core.collectives as collectives  # noqa: E402
from repro.core import oracle  # noqa: E402
from repro.core.scan_api import ScanSpec, algorithms, plan, scan  # noqa: E402


def main():
    p = 8
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("ranks",))
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(p, 4)).astype(np.int32)

    print(f"inputs V_r (p={p} ranks, m=4):\n{x}\n")
    expected = np.zeros_like(x)
    expected[1:] = np.cumsum(x[:-1], axis=0)

    # --- the planner API: describe WHAT, let the cost model pick HOW ---
    spec = ScanSpec(kind="exclusive", monoid="add", algorithm="auto",
                    axis_name="ranks")
    pl = plan(spec, p=p, nbytes=x[0].nbytes)  # inspectable, pre-tracing
    print("auto plan for this payload:")
    print(" ", pl.describe())

    # --- plans are executable schedules: inspect round-by-round peers,
    # masks and combine directions WITHOUT tracing anything ---
    print("\nits schedule IR (what the executors run):")
    print("  " + pl.schedule().describe().replace("\n", "\n  "))
    big = plan(spec, p=p, nbytes=1 << 20)
    print("\n1MB payload flips to the pipelined segmented ring "
          f"({big.algorithm}, S={big.segments}, p-2+S={big.rounds} "
          f"rounds, ~{big.bytes_on_wire / (1 << 20):.2f}·m serialized):")
    print("  " + "\n  ".join(
        big.schedule().describe().split("\n")[:4]) + "\n    ...\n")

    for alg in algorithms("exclusive") + ("auto",):
        aspec = spec.over("ranks", algorithm=alg)
        with collectives.collect_stats() as stats:
            fn = jax.jit(shard_map(
                lambda v: scan(v, aspec),
                mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks")))
            out = np.asarray(fn(x))
        assert np.array_equal(out, expected), alg
        apl = plan(aspec, p=p, nbytes=x[0].nbytes)
        assert stats.rounds == apl.rounds  # plans predict measurements
        print(f"{alg:>10s}: rounds={stats.rounds} "
              f"⊕/device={stats.op_applications} "
              f"(all-gathers={stats.allgathers})"
              f"{'  <- planned: ' + apl.algorithm if alg == 'auto' else ''}"
              f"  ✓ correct")

    # --- the legacy string API still works, but is deprecated ---
    import warnings

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        fn = jax.jit(shard_map(
            lambda v: collectives.exscan(v, "ranks", "add", "123"),
            mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks")))
        assert np.array_equal(np.asarray(fn(x)), expected)
    assert any(issubclass(w.category, DeprecationWarning)
               for w in caught)
    print("\nlegacy collectives.exscan(...) ✓ still works "
          "(with a DeprecationWarning pointing at ScanSpec)")

    print("\nTheorem 1 at the paper's p=36 and at pod scale:")
    for p_ in (36, 256, 512):
        q = oracle.q_123(p_)
        print(f"  p={p_:4d}: 123-doubling {q} rounds / {q-1} ⊕ | "
              f"1-doubling {oracle.rounds_1doubling(p_)} rounds | "
              f"two-⊕ {oracle.rounds_two_op(p_)} rounds "
              f"/ ~{2*oracle.rounds_two_op(p_)-1} ⊕")


if __name__ == "__main__":
    main()
