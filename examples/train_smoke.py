"""End-to-end training driver example: ~100M-param llama-family model,
a few hundred steps on CPU, with checkpoint/restart fault tolerance.

    python examples/train_smoke.py [--steps 200]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro import configs  # noqa: E402
from repro.checkpoint.store import CheckpointStore  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticLM  # noqa: E402
from repro.launch.steps import make_train_step  # noqa: E402
from repro.models import params as PD  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.optim import adamw_init  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_smoke")
    args = ap.parse_args()

    # ~100M params: llama3 family, scaled down
    cfg = dataclasses.replace(
        configs.get("llama3-8b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
        vocab=8192, dtype="float32")
    print(f"model: {PD.count_params(cfg)/1e6:.1f}M params")

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    model = Model(cfg, mesh)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    store = CheckpointStore(args.ckpt)
    start = store.latest_step() or 0
    if start:
        state = store.restore(start, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, mesh, lr_peak=1e-3,
                                      warmup=20, total_steps=args.steps),
                      donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=256,
                                  global_batch=8))
    with jax.set_mesh(mesh):
        for step in range(start, args.steps):
            b = data.batch(step)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            params, opt, m = step_fn(params, opt, batch, jnp.int32(step))
            if step % 20 == 0:
                print(f"step {step:4d} loss {float(m['loss']):.4f}")
            if (step + 1) % 100 == 0:
                store.save(step + 1, {"params": params, "opt": opt},
                           blocking=False)
    store.wait()
    store.save(args.steps, {"params": params, "opt": opt})
    print(f"done; final loss {float(m['loss']):.4f}; "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
